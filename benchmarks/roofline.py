"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape x mesh), TPU v5e constants:
  compute term    = HLO_FLOPs_per_chip / 197e12        [s]
  memory term     = HLO_bytes_per_chip / 819e9         [s]
  collective term = wire_bytes_per_chip / 50e9         [s]

HLO flops/bytes are trip-count-corrected (launch/dryrun.py calibration);
collective wire bytes come from the partitioned-HLO parse with ring-cost
weighting.  The per-chip formulation is equivalent to the global/chips form
since the partitioned module *is* the per-chip program.

MODEL_FLOPS (useful work, PaLM-style accounting):
  train   tokens * (6 N_active + 12 L H hd S_ctx)   (+ SSD term for SSM)
  prefill tokens * (2 N_active +  2 L H hd S)       (causal average ~S/2)
  decode  tokens * (2 N_active +  4 L H hd S_kv)    (S_kv = cache length)
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 FLOP/s per v5e chip
HBM_BW = 819e9           # B/s per chip
LINK_BW = 50e9           # B/s per ICI link

# V_inf critical-path constants for the epoch engines (paper §4.4.1): every
# host->device program launch and device->host scalar readback sits on the
# epoch critical path.  Calibrated to this container's measured jitted
# no-op dispatch / device_get round trips; on a real TPU host they are the
# PCIe/ICI launch+readback latencies.
DISPATCH_LATENCY_S = 40e-6   # per program launch
TRANSFER_LATENCY_S = 15e-6   # per scalar readback batch

ART_DIR = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def vinf_seconds(stats) -> float:
    """Critical-path overhead V_inf·T_inf implied by engine ``RunStats``.

    Consumes the engines' pluggable stats-collector output
    (``repro.core.scheduler.RunStats`` or anything with ``dispatches`` /
    ``scalar_transfers``): the §5.4 compacted dispatch pays one extra
    launch + one extra readback per epoch for its compaction pass, and this
    is the model that prices that trade against the lane-utilization win.
    """
    return (
        stats.dispatches * DISPATCH_LATENCY_S
        + stats.scalar_transfers * TRANSFER_LATENCY_S
    )


def model_flops(rec: dict, cfg) -> float:
    """Analytic useful FLOPs for the whole step (see module docstring)."""
    S, B = rec["seq_len"], rec["global_batch"]
    kind = rec["kind"]
    n_active = rec["n_params_active"]
    L = cfg.n_layers
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    attn_ctx = S
    if cfg.sliding_window > 0 and kind != "train":
        attn_ctx = min(S, cfg.sliding_window)
    ssd = 0.0
    if cfg.block in ("ssm", "hybrid"):
        di = cfg.ssm.d_inner(cfg.d_model)
        ssd = 5 * L * di * cfg.ssm.d_state  # fwd per token
    if cfg.block == "ssm":
        H = 0
    if kind == "train":
        tokens = B * S
        return tokens * (6 * n_active + 12 * L * H * hd * S + 3 * ssd)
    if kind == "prefill":
        tokens = B * S
        return tokens * (2 * n_active + 2 * L * H * hd * attn_ctx + ssd)
    # decode: one token per sequence against an S-long cache
    tokens = B
    return tokens * (2 * n_active + 4 * L * H * hd * attn_ctx + ssd)


def model_bytes(rec: dict, cfg) -> float:
    """Analytic minimal HBM traffic per step (whole job, bytes).

    XLA's ``bytes accessed`` counts every op's operands as if nothing fuses —
    a loose upper bound, especially on the CPU backend.  This lower bound is
    what a well-fused TPU program approaches:
      train:   28 B/param (bf16 fwd+bwd reads, f32 grad + Adam m/v r/w)
               + ~10 streams of (B,S,d) per layer, x3 for full remat
      prefill: 2 B/param + ~8 streams of (B,S,d) per layer + KV write
      decode:  2 B/active-param + KV cache read + state r/w
    """
    S, B = rec["seq_len"], rec["global_batch"]
    kind = rec["kind"]
    n_active = rec["n_params_active"]
    L, d = cfg.n_layers, cfg.d_model
    hd = cfg.resolved_head_dim
    kv_bytes = 2 * 2 * L * B * S * cfg.n_kv_heads_padded * hd  # bf16 k+v
    if cfg.block == "ssm":
        kv_bytes = 0
    act_stream = 2 * B * S * d  # one bf16 (B,S,d) pass
    if kind == "train":
        return 28.0 * n_active + 3 * 10 * L * act_stream
    if kind == "prefill":
        return 2.0 * n_active + 8 * L * act_stream + kv_bytes
    # decode: every active param + the whole cache, read once
    state_bytes = 0
    if cfg.block in ("ssm", "hybrid"):
        s = cfg.ssm
        nh = s.n_heads(d)
        state_bytes = 2 * 4 * B * nh * s.headdim * s.d_state
    return 2.0 * n_active + kv_bytes + state_bytes


@dataclasses.dataclass
class RooflinePoint:
    cell: str
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float          # XLA bytes-accessed bound (unfused upper bound)
    memory_min_s: float      # analytic minimal-traffic bound
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    fits_hbm: bool
    hbm_gb: float

    @property
    def bound_time(self) -> float:
        """Realistic bound: compute/collective from HLO, memory = geometric
        middle of the unfused upper bound and the fused lower bound."""
        mem = (self.memory_s * self.memory_min_s) ** 0.5
        return max(self.compute_s, mem, self.collective_s)

    @property
    def ideal_time(self) -> float:
        """What a perfect implementation needs: max of useful-compute time
        and minimal-traffic time (whichever resource truly binds)."""
        comp = self.model_flops / (PEAK_FLOPS * self._chips)
        return max(comp, self.memory_min_s)

    @property
    def roofline_fraction(self) -> float:
        """ideal time / realized bound time (the perf score, <= 1)."""
        return min(1.0, self.ideal_time / max(self.bound_time, 1e-30))

    _chips: int = 256


def analyze(rec: dict) -> Optional[RooflinePoint]:
    if rec.get("status") != "ok":
        return None
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
    from repro import configs
    from repro.models.common import finalize

    cfg = finalize(
        configs.get_config(rec["arch"]), rec["mesh"].get("model", 16)
    )
    chips = rec["n_devices"]
    comp = rec["flops_per_device"] / PEAK_FLOPS
    mem = rec["bytes_per_device"] / HBM_BW
    mem_min = model_bytes(rec, cfg) / (chips * HBM_BW)
    coll = rec["coll_bytes_per_device"] / LINK_BW
    mem_mid = (mem * mem_min) ** 0.5
    dominant = max(
        [("compute", comp), ("memory", mem_mid), ("collective", coll)],
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(rec, cfg)
    hlo_global = rec["flops_per_device"] * chips
    hbm = (
        rec["memory"]["argument_bytes"]
        + rec["memory"]["temp_bytes"]
        + rec["memory"]["output_bytes"]
    )
    p = RooflinePoint(
        cell=rec["cell"],
        arch=rec["arch"],
        shape=rec["shape"],
        mesh="2x16x16" if rec["multi_pod"] else "16x16",
        compute_s=comp,
        memory_s=mem,
        memory_min_s=mem_min,
        collective_s=coll,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=mf / max(hlo_global, 1e-30),
        fits_hbm=hbm < 16e9,
        hbm_gb=hbm / 1e9,
    )
    p._chips = chips
    return p


def load_all(art_dir: pathlib.Path = ART_DIR) -> List[RooflinePoint]:
    pts = []
    for f in sorted(art_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        p = analyze(rec)
        if p is not None:
            pts.append(p)
    return pts


def render_table(pts: List[RooflinePoint], mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute s | mem s (xla/min) | collective s "
        "| dominant | useful MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for p in pts:
        if p.mesh != mesh:
            continue
        rows.append(
            f"| {p.arch} | {p.shape} | {p.compute_s:.2e} "
            f"| {p.memory_s:.2e} / {p.memory_min_s:.2e} "
            f"| {p.collective_s:.2e} | **{p.dominant}** "
            f"| {p.useful_ratio:.2f} | {p.roofline_fraction:.2f} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    pts = load_all()
    print(render_table(pts, "16x16"))
    print()
    print(render_table(pts, "2x16x16"))
