"""Front-door load generator: deadline/priority admission vs plain FIFO.

Simulates a large tenant population offering the *same* load to the
:class:`~repro.service.api.JobService` twice — once with every job in one
undifferentiated class (the pre-§16 FIFO front door) and once with an
``interactive`` quota class carrying a priority and per-job deadlines
(EDF-within-priority admission plus chunk-boundary preemption) — and
emits one ``trees-bench-v2`` row per configuration with p50/p99 latency,
jobs per second, and the deadline/preemption scoreboard.

Time is **virtual**: the service runs on an injected deterministic clock
that advances a fixed tick per pump (one chunk boundary = one scheduling
quantum), so every latency percentile and counter in the artifact is
bit-reproducible across machines — the row is a property of the
*scheduling algorithm*, not of the CI container.  ``check.py --latency``
gates the self-contained claim (priority admission meets interactive
deadlines that FIFO misses under the same offered load) and, given a
baseline artifact, the exact counters + fuzzy percentiles.

Workload shape: a burst of batch jobs (fib(10), the backlog) arrives at
t=0; small interactive jobs (fib(7)) trickle in behind it with tight
deadlines.  FIFO packs strictly in arrival order, so every interactive
job waits out the backlog; the admission layer lets them jump the queue
and preempt running batch work at chunk boundaries.

Run:  PYTHONPATH=src python benchmarks/loadgen.py [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

import numpy as np


class VirtualClock:
    """Deterministic clock: advances only when the driver says so."""

    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def build_arrivals(
    n_jobs: int, interactive_every: int, deadline_s: float,
    batch_gap_s: float, interactive_gap_s: float,
) -> List[Tuple[float, dict]]:
    """The offered load: (arrival time, submit kwargs) per job, identical
    for both service configurations.  Batch jobs burst in nearly at once
    (the backlog); interactive jobs arrive spread behind them."""
    from repro.apps import fib

    arrivals: List[Tuple[float, dict]] = []
    t_batch = 0.0
    t_inter = interactive_gap_s
    for i in range(n_jobs):
        if interactive_every and i % interactive_every == 0:
            arrivals.append((t_inter, dict(
                program=fib.PROGRAM, initial=fib.initial(7), quota=256,
                name=f"i{i}", klass="interactive", deadline=deadline_s,
            )))
            t_inter += interactive_gap_s
        else:
            arrivals.append((t_batch, dict(
                program=fib.PROGRAM, initial=fib.initial(10), quota=256,
                name=f"b{i}", klass="batch",
            )))
            t_batch += batch_gap_s
    arrivals.sort(key=lambda a: a[0])
    return arrivals


def drive(svc, clock: VirtualClock, arrivals, tick_s: float):
    """Feed arrivals as virtual time crosses them; one pump per tick."""
    done = []
    i = 0
    while i < len(arrivals) or svc._pending():
        while i < len(arrivals) and arrivals[i][0] <= clock.t + 1e-12:
            svc.submit(**arrivals[i][1])
            i += 1
        if svc._pending():
            done.extend(svc._pump())
            clock.advance(tick_s)
        else:
            # idle: jump straight to the next arrival
            clock.t = max(clock.t, arrivals[i][0])
    return done


def run_config(
    name: str, arrivals, priority: bool, tick_s: float,
) -> Tuple[str, float, str]:
    """One configuration over the offered load; returns a bench row
    (name, us_per_job in virtual time, derived string)."""
    from repro.service import AdmissionController, JobService, QuotaClass

    class FifoAdmission(AdmissionController):
        """The pre-§16 front door: pack strictly in arrival order;
        deadlines are scored but never influence scheduling."""

        def order(self, queue):
            return sorted(queue, key=lambda h: h.job_id)

    clock = VirtualClock()
    classes = [
        QuotaClass("interactive", priority=(10 if priority else 0)),
        QuotaClass("batch", priority=0),
    ]
    admission = (AdmissionController if priority else FifoAdmission)(
        classes=classes, clock=clock
    )
    svc = JobService(
        capacity=1024, max_jobs=4, engine="device", chunk=2,
        admission=admission, preemption=priority,
    )
    done = drive(svc, clock, arrivals, tick_s)
    assert len(done) == len(arrivals), (len(done), len(arrivals))
    assert all(h.status.value == "done" for h in done)

    lat: Dict[str, List[float]] = {"interactive": [], "batch": []}
    for h in done:
        lat[h.klass].append((h.finished_at - h.submitted_at) * 1e3)
    adm = svc.admission
    stats = {
        "jobs": len(done),
        "misses_interactive": adm.deadline_misses.get("interactive", 0),
        "met_interactive": adm.deadline_met.get("interactive", 0),
        "preempts": sum(adm.preempted.values()),
    }
    for k in ("interactive", "batch"):
        xs = np.asarray(lat[k])
        stats[f"p50_{k}_ms"] = round(float(np.percentile(xs, 50)), 3)
        stats[f"p99_{k}_ms"] = round(float(np.percentile(xs, 99)), 3)
    v_total = clock.t
    stats["jobs_per_vsec"] = round(len(done) / v_total, 3)
    derived = ";".join(f"{k}={v}" for k, v in stats.items())
    us_per_job = v_total * 1e6 / len(done)
    print(f"{name},{us_per_job:.1f},0.0,{derived}", flush=True)
    return (name, us_per_job, derived)


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized population (48 jobs instead of 2048)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="override the tenant population size")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="artifact path (default BENCH_10.json, or "
                    "BENCH_10.smoke.json with --smoke)")
    args = ap.parse_args(argv)

    n_jobs = args.jobs or (48 if args.smoke else 2048)
    # one interactive job per four batch jobs; deadlines sized so the
    # FIFO backlog wait blows them but priority admission does not
    arrivals = build_arrivals(
        n_jobs,
        interactive_every=4,
        deadline_s=0.015,
        batch_gap_s=0.0,
        interactive_gap_s=0.010,
    )
    tick_s = 0.001  # one chunk boundary = 1 virtual ms

    print("name,us_per_call,compile_us,derived")
    rows = [
        run_config("loadgen_fifo", arrivals, priority=False,
                   tick_s=tick_s),
        run_config("loadgen_priority", arrivals, priority=True,
                   tick_s=tick_s),
    ]

    path = args.json or (
        "BENCH_10.smoke.json" if args.smoke else "BENCH_10.json"
    )
    payload = {
        "schema": "trees-bench-v2",
        "dispatch": "masked",
        "chunk": 2,
        "smoke": bool(args.smoke),
        "megakernel": False,
        "shards": 0,
        "groups": ["loadgen"],
        "rows": [
            {
                "name": n,
                "us_per_call": round(us, 1),
                "compile_us": 0.0,
                "derived": d,
            }
            for n, us, d in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
