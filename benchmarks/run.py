"""Benchmark harness — one function per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,compile_us,derived`` CSV rows.  All sizes are
scaled to run
on this CPU container in minutes; the *shape* of each comparison mirrors the
paper's (Fig. 5 Fibonacci overhead, Fig. 6 FFT, Fig. 7/8 BFS/SSSP vs
hand-coded worklists, Fig. 9 sort, plus the V1/V-inf overhead decomposition
of §4.4 and the TVM serving engine).  Roofline rows (§Roofline) are derived
from the dry-run artifacts, not timed here.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, List, Tuple

import numpy as np

ROWS: List[tuple] = []

# set by main() from --dispatch; every HostEngine below follows it so the
# whole harness can be A/B'd masked vs compacted (§5.4 contiguity) or run
# under the self-tuning controller ("auto", DESIGN.md §14)
DISPATCH = "masked"

# set by main() from --chunk: "auto" runs the device_service rows with the
# adaptive chunk-size controller and emits the *_kauto rows (DESIGN.md §14)
CHUNK = None

# set by main() from --smoke: shrink every group to a CI-sized subset so the
# workflow's benchmarks step can guard the rows against bit-rot in minutes
SMOKE = False

# set by main() from --megakernel: emit device_service_*_mega rows (the
# persistent Pallas epoch megakernel next to the while_loop K-ladder rows)
MEGAKERNEL = False

# set by main() from --shards: emit sharded_service_*_p{1..P} rows (the
# device-mesh fleet scale-out ladder, DESIGN.md §15); 0 skips the group
SHARDS = 0

# set by main() from --trace / --metrics: the obs tracer + metrics registry
# every service/engine below feeds when enabled (None = disabled, free)
TRACER = None
METRICS = None


def jax_backend() -> str:
    import jax

    return jax.default_backend()


class Timing(float):
    """Steady-state seconds per call, with the warmup's one-time cost kept
    on the side.  The value *is* the steady-state mean (so existing
    arithmetic on ``_time`` results is unchanged); ``compile_s`` carries
    the first call — tracing + XLA compilation — as its own number instead
    of letting it pollute the mean or vanish."""

    compile_s: float = 0.0


def _time(fn: Callable, repeats: int = 3) -> Timing:
    # first call pays tracing + compilation; time it separately so the
    # repeats measure steady-state and the compile cost stays visible.
    # NOTE: closures must reuse one engine/service across calls — a fresh
    # engine per call owns fresh jit caches and recompiles every "repeat",
    # which is exactly the bug this split makes diffable (compile_us ~ 0
    # on a row means its repeats really were steady-state).
    t0 = time.perf_counter()
    fn()  # warmup / compile
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    t = Timing((time.perf_counter() - t0) / repeats)
    t.compile_s = compile_s
    return t


def row(name: str, us: float, derived: str = "", stats=None):
    """Record one benchmark row.

    ``us`` may be a plain float (microseconds) or carry a ``Timing`` via
    the caller multiplying one by 1e6 — compile time is passed explicitly
    by callers that have it.  ``stats`` is an optional RunStats whose
    ``as_dict()`` lands structured in the JSON artifact (one metric
    vocabulary with obs/export.py)."""
    compile_us = 0.0
    base = us
    if isinstance(us, Timing):
        base = float(us) * 1e6
        compile_us = us.compile_s * 1e6
    ROWS.append((name, base, compile_us, derived, stats))
    print(f"{name},{base:.1f},{compile_us:.1f},{derived}", flush=True)


# ------------------------------------------------------------ Fig 5: fib
def bench_fib():
    from repro.apps import fib
    from repro.core import HostEngine, DeviceEngine, run_oracle, compare

    for n in (10,) if SMOKE else (12, 14, 16):
        _, _, ostats = run_oracle(fib.PROGRAM, fib.initial(n), capacity=1 << 14)

        # one engine across warmup + repeats: its jit caches persist, so
        # the repeats measure steady-state dispatch (a fresh engine per
        # call would retrace each "repeat" — the compile_us column guards
        # against that regressing)
        host_eng = HostEngine(
            fib.PROGRAM, capacity=1 << 14, collect_stats=False,
            dispatch=DISPATCH, tracer=TRACER,
        )

        def run_host():
            host_eng.run(fib.initial(n))

        eng = HostEngine(fib.PROGRAM, capacity=1 << 14, dispatch=DISPATCH)
        _, vals, hstats = eng.run(fib.initial(n))
        t_host = _time(run_host)
        rep = compare(ostats, hstats)
        row(
            f"fib{n}_trees_host", t_host,
            f"tasks={ostats.tasks_executed};epochs={ostats.epochs};"
            f"us_per_task={t_host*1e6/ostats.tasks_executed:.1f};"
            f"util={rep.utilization:.2f}",
            stats=hstats,
        )

        dev_eng = DeviceEngine(
            fib.PROGRAM, capacity=1 << 14, stack_depth=512, tracer=TRACER
        )

        def run_dev():
            dev_eng.run(fib.initial(n))

        t_dev = _time(run_dev)
        row(
            f"fib{n}_trees_device", t_dev,
            f"speedup_vs_host={t_host/t_dev:.2f}",
        )

        def run_seq():
            def f(k):
                return k if k < 2 else f(k - 1) + f(k - 2)
            return f(n)

        t_seq = _time(run_seq)
        row(
            f"fib{n}_sequential", t_seq,
            f"trees_overhead_x={t_host/max(t_seq,1e-9):.1f}",
        )


# ------------------------------------------------------------ Fig 6: fft
def bench_fft():
    from repro.apps import fft
    from repro.core import HostEngine
    import jax.numpy as jnp
    import jax

    for n in (64, 256):
        xr, xi = fft.random_input(n)
        prog = fft.make_program(n)
        eng = HostEngine(
            prog, capacity=1 << 13, collect_stats=False, dispatch=DISPATCH
        )

        def run_trees():
            eng.run(fft.initial(n), heap_init=dict(xr=xr, xi=xi))

        t_trees = _time(run_trees)

        xc = xr + 1j * xi

        @jax.jit
        def native(v):
            return jnp.fft.fft(v)

        t_native = _time(lambda: np.asarray(native(xc)))
        row(
            f"fft{n}_trees", t_trees,
            f"native_fft_us={t_native*1e6:.1f};"
            f"generality_cost_x={t_trees/max(t_native,1e-9):.1f}",
        )


# ------------------------------------------------- Fig 7/8: bfs and sssp
def bench_graph():
    from repro.apps import bfs, sssp
    from repro.apps.baselines import worklist
    from repro.core import HostEngine

    n = 256
    adj_off, adj = bfs.random_graph(n, avg_degree=4, seed=0)
    bfs_eng = HostEngine(
        bfs.make_program(n, len(adj)), capacity=1 << 15,
        collect_stats=False, dispatch=DISPATCH,
    )

    def run_trees_bfs():
        bfs_eng.run(bfs.initial(0), heap_init=bfs.heap_init(adj_off, adj, n))

    t_trees = _time(run_trees_bfs)

    def run_wl_bfs():
        worklist.bfs_worklist(adj_off, adj, 0, n)

    t_wl = _time(run_wl_bfs)
    row(
        f"bfs_n{n}_trees", t_trees,
        f"worklist_us={t_wl*1e6:.1f};overhead_vs_native_x={t_trees/t_wl:.2f}",
    )

    wgt = sssp.random_weights(len(adj), seed=1)
    sssp_eng = HostEngine(
        sssp.make_program(n, len(adj)), capacity=1 << 16,
        collect_stats=False, dispatch=DISPATCH,
    )

    def run_trees_sssp():
        sssp_eng.run(
            sssp.initial(0), heap_init=sssp.heap_init(adj_off, adj, wgt, n)
        )

    t_trees = _time(run_trees_sssp)

    def run_wl_sssp():
        worklist.sssp_worklist(adj_off, adj, wgt, 0, n)

    t_wl = _time(run_wl_sssp)
    row(
        f"sssp_n{n}_trees", t_trees,
        f"worklist_us={t_wl*1e6:.1f};overhead_vs_native_x={t_trees/t_wl:.2f}",
    )


# ------------------------------------------------------------ Fig 9: sort
def bench_sort():
    from repro.apps import mergesort
    from repro.apps.baselines import bitonic
    from repro.core import HostEngine
    import jax.numpy as jnp

    n = 64
    x = mergesort.random_input(n)
    engs = {
        use_map: HostEngine(
            mergesort.make_program(n, use_map=use_map), capacity=1 << 13,
            collect_stats=False, dispatch=DISPATCH,
        )
        for use_map in (False, True)
    }

    def run(use_map):
        engs[use_map].run(mergesort.initial(n), heap_init=dict(inp=x))

    t_naive = _time(lambda: run(False), repeats=1)
    t_map = _time(lambda: run(True), repeats=1)
    xj = jnp.asarray(x)
    t_bitonic = _time(lambda: np.asarray(bitonic.bitonic_sort(xj)))
    row(f"sort{n}_trees_naive", t_naive,
        f"vs_bitonic_x={t_naive/max(t_bitonic,1e-9):.1f}")
    row(f"sort{n}_trees_map", t_map,
        f"map_speedup_vs_naive_x={t_naive/t_map:.2f};"
        f"vs_bitonic_x={t_map/max(t_bitonic,1e-9):.1f}")
    row(f"sort{n}_bitonic_native", t_bitonic, "")


# --------------------------------------- §4.4: V1 / V_inf decomposition
def bench_overhead():
    from repro.apps import nqueens
    from repro.core import HostEngine, run_oracle, compare

    prog = nqueens.make_program(7)
    _, _, ostats = run_oracle(prog, nqueens.initial(), capacity=1 << 14)
    eng = HostEngine(prog, capacity=1 << 14, dispatch=DISPATCH)
    timed_eng = HostEngine(
        prog, capacity=1 << 14, collect_stats=False, dispatch=DISPATCH
    )
    t = _time(lambda: timed_eng.run(nqueens.initial()), repeats=1)
    _, _, st = eng.run(nqueens.initial())
    rep = compare(ostats, st)
    row(
        "nqueens7_overhead", t,
        f"T1={rep.t1_tasks};Tinf={rep.t_inf_epochs};"
        f"parallelism={rep.parallelism:.1f};"
        f"V1_lanes={rep.v1_lane_factor:.2f};"
        f"Vinf_dispatches={rep.v_inf_dispatches};"
        f"greedy_bound_P256={rep.greedy_bound(256):.0f}",
    )


# ------------------- §5.4 / §11: masked vs compacted vs gather dispatch
def bench_dispatch():
    """Lane utilization + time per app across all three dispatch modes.

    The compacted rows realize §5.4's contiguity principle (dense per-type
    launches); the gather rows realize §11's dense-frontier pack (one
    lane-exact launch, no per-type splitting, hole lanes skipped).  The
    derived column carries the utilization of *all* policies so the wins
    are visible in one row, plus the V_inf critical-path estimate from the
    roofline dispatch model and the gather path's skipped hole lanes.
    """
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    from roofline import vinf_seconds

    from repro.apps import get_case
    from repro.core import HostEngine

    statics = ("masked", "compacted", "gather")
    for name in ("fib", "nqueens", "bfs"):
        case = get_case(name)
        stats = {}
        times = {}
        decisions = {}
        policies = statics + ("auto",) if DISPATCH == "auto" else statics
        for policy in policies:
            eng = HostEngine(
                case.program, capacity=case.capacity, dispatch=policy
            )
            _, _, stats[policy] = eng.run(
                case.initial, heap_init=dict(case.heap_init) or None
            )
            times[policy] = _time(
                lambda e=eng: e.run(
                    case.initial, heap_init=dict(case.heap_init) or None
                ),
                repeats=1,
            )
            if policy == "auto":
                decisions = dict(eng.controller.decisions)
        sm, sc, sg = stats["masked"], stats["compacted"], stats["gather"]
        occ = ";".join(
            f"occ_{t}={o:.2f}" for t, o in sorted(sc.occupancy_by_type.items())
        )
        # under --dispatch auto: the controller's per-epoch decision
        # counts, the auto leg's own clock, and the static envelope it is
        # gated against (check.py --auto: us_per_call <= worst static)
        auto = ""
        if DISPATCH == "auto":
            static_us = {p: times[p] * 1e6 for p in statics}
            dec = ";".join(
                f"auto_{m}={c}" for m, c in sorted(decisions.items())
            )
            auto = (
                f";util_auto={stats['auto'].utilization:.2f};"
                f"us_best_static={min(static_us.values()):.1f};"
                f"us_worst_static={max(static_us.values()):.1f};{dec}"
            )
        row(
            f"dispatch_{name}_{DISPATCH}", times[DISPATCH],
            f"util_masked={sm.utilization:.2f};"
            f"util_compacted={sc.utilization:.2f};"
            f"util_gather={sg.utilization:.2f};"
            f"us_masked={times['masked']*1e6:.1f};"
            f"us_compacted={times['compacted']*1e6:.1f};"
            f"us_gather={times['gather']*1e6:.1f};"
            f"lanes_masked={sm.lanes_launched};"
            f"lanes_compacted={sc.lanes_launched};"
            f"lanes_gather={sg.lanes_launched};"
            f"hole_lanes_skipped={sg.hole_lanes_skipped};"
            f"vinf_masked_us={vinf_seconds(sm)*1e6:.0f};"
            f"vinf_compacted_us={vinf_seconds(sc)*1e6:.0f};"
            f"vinf_gather_us={vinf_seconds(sg)*1e6:.0f};{occ}{auto}",
            stats=stats[DISPATCH],
        )


# ------------------------------ epoch-multiplexing job service (DESIGN §8)
def bench_service():
    """Multi-tenant co-scheduling: fleet V_inf vs the sum of solo runs.

    ``service_mixed3`` runs the registered mixed fleet (fib + treewalk +
    bfs) through one shared TVM and reports the fused dispatch/readback
    totals against the sum of the three solo runs — the work-together
    principle extended across tenants.  ``service_fibxN`` scales a
    homogeneous fleet to show throughput vs concurrency: fused dispatches
    grow like the *max* of the members, not the sum.
    """
    from repro.apps import get_fleet
    from repro.core import HostEngine
    from repro.service import JobService

    def run_service(fleet, n_jobs=None):
        svc = JobService(
            capacity=sum(q for _, q in fleet), dispatch=DISPATCH,
            max_jobs=n_jobs or len(fleet),
            metrics=METRICS, tracer=TRACER,
        )
        for case, quota in fleet:
            svc.submit_case(case, quota=quota)
        svc.drain()
        return svc

    if SMOKE:
        # smoke: only the x2 homogeneous point (compile-light, still
        # exercises fusion + the V_inf accounting)
        base = get_fleet("fib_fleet")[0]
        svc = run_service([base] * 2)
        fs = svc.stats()
        t = _time(lambda: run_service([base] * 2), repeats=1)
        row(
            f"service_fibx2_{DISPATCH}", t,
            f"jobs=2;fleet_dispatches={fs.dispatches};"
            f"dispatches_per_job={fs.dispatches / 2:.1f}",
            stats=fs,
        )
        return

    # mixed fleet vs sum-of-solo
    fleet = get_fleet("mixed3")
    solo_disp = solo_xfer = 0
    for case, quota in fleet:
        eng = HostEngine(case.program, capacity=quota, dispatch=DISPATCH)
        _, _, s = eng.run(case.initial, heap_init=dict(case.heap_init) or None)
        solo_disp += s.dispatches
        solo_xfer += s.scalar_transfers
    svc = run_service(fleet)
    fs = svc.stats()
    t = _time(lambda: run_service(get_fleet("mixed3")), repeats=1)
    row(
        f"service_mixed3_{DISPATCH}", t,
        f"jobs={len(fleet)};fleet_dispatches={fs.dispatches};"
        f"solo_dispatches={solo_disp};"
        f"fleet_transfers={fs.scalar_transfers};solo_transfers={solo_xfer};"
        f"vinf_saving_x={(solo_disp + solo_xfer) / max(1, fs.dispatches + fs.scalar_transfers):.2f};"
        f"util={fs.utilization:.2f};"
        f"hole_lanes_skipped={fs.hole_lanes_skipped}",
        stats=fs,
    )

    # throughput vs number of concurrent jobs (homogeneous fib fleet)
    base = get_fleet("fib_fleet")[0]
    for n in (1, 2, 4, 8):
        fleet_n = [base] * n
        svc = run_service(fleet_n)
        fs = svc.stats()
        t = _time(lambda f=fleet_n: run_service(f), repeats=1)
        row(
            f"service_fibx{n}_{DISPATCH}", t,
            f"jobs={n};fleet_dispatches={fs.dispatches};"
            f"us_per_job={t * 1e6 / n:.1f};"
            f"dispatches_per_job={fs.dispatches / n:.1f}",
        )


# ------------- chunked device-resident fleet execution (DESIGN.md §9–10)
def bench_device_service():
    """Resident fleet vs host-mux vs sum-of-solo, plus the K-epoch ladder.

    Each ``device_service_*`` row runs the same fleet three ways — N solo
    ``HostEngine`` runs (V_inf paid per job per epoch), the host-loop
    multiplexer (paid once per fused global epoch), and the resident
    ``lax.while_loop`` wave (paid once per *wave*: one dispatch + one
    readback, O(1)) — and reports all three dispatch+transfer totals plus
    the resident path's map-lane waste (its measurable work overhead).

    The ``device_service_*_k{K}`` rows sweep the chunk knob between the
    two endpoints: the wave re-enters the compiled loop every K epochs, so
    measured readbacks per wave must equal ⌈epochs/K⌉ (both numbers are
    emitted so the invariant is diffable); the timed re-run reuses the
    wave-template cache, so ``template_hits`` also guards compiled-loop
    reuse across identical consecutive waves.

    With ``--megakernel`` the ``device_service_*_mega_*`` rows run the
    same waves through the persistent Pallas epoch megakernel
    (``kernels/epoch_megakernel.py``) under masked and gather dispatch,
    next to their while_loop twins: same ⌈E/K⌉ readback invariant, same
    ``template_hits`` guard, plus ``lanes_launched``/
    ``hole_lanes_skipped`` so the gather rows' lane-volume win over the
    span ladder is diffable.  On CPU the kernel executes through the
    Pallas interpreter under ``--smoke`` (the bit-rot guard) and falls
    back to the jnp oracle otherwise (interpret mode is a simulator, not
    a perf number); on TPU it is the native kernel either way.
    """
    import math

    from repro.apps import get_fleet
    from repro.core import HostEngine
    from repro.service import JobService, WaveTemplateCache

    def run_svc(fleet, engine, chunk=None, cache=None, dispatch=None,
                megakernel=False, megakernel_impl="auto"):
        svc = JobService(
            capacity=sum(q for _, q in fleet), engine=engine,
            dispatch=(dispatch or "masked") if engine == "device"
            else DISPATCH,
            chunk=chunk if engine == "device" else None,
            template_cache=cache,
            megakernel=megakernel, megakernel_impl=megakernel_impl,
            metrics=METRICS, tracer=TRACER,
        )
        for case, quota in fleet:
            svc.submit_case(case, quota=quota)
        svc.drain()
        return svc

    if SMOKE:
        fleets = [("fibx2", [get_fleet("fib_fleet")[0]] * 2)]
        ladder = (4, None)  # one finite-K smoke row + the resident endpoint
    else:
        fleets = [
            ("mixed3", get_fleet("mixed3")),
            ("fibx4", get_fleet("fib_fleet")),
        ]
        ladder = (1, 4, 16, None)
    for fname, fleet in fleets:
        solo_vinf = 0
        for case, quota in fleet:
            eng = HostEngine(case.program, capacity=quota, dispatch=DISPATCH)
            _, _, s = eng.run(
                case.initial, heap_init=dict(case.heap_init) or None
            )
            solo_vinf += s.dispatches + s.scalar_transfers
        # one template cache across the stats pass + warmup + repeats: a
        # fresh service per call is the measurement (queue + wave build),
        # but re-tracing the chunk loop per call is not — without the
        # shared cache the "steady-state" repeats each paid a full
        # retrace, double-counting compile into us_per_call on top of the
        # compile_us column
        cache_d = WaveTemplateCache()
        hs = run_svc(fleet, "host").stats()
        ds = run_svc(fleet, "device", cache=cache_d).stats()
        t_host = _time(lambda f=fleet: run_svc(f, "host"), repeats=1)
        t_dev = _time(
            lambda f=fleet: run_svc(f, "device", cache=cache_d), repeats=1
        )
        host_vinf = hs.dispatches + hs.scalar_transfers
        dev_vinf = ds.dispatches + ds.scalar_transfers
        row(
            f"device_service_{fname}", t_dev,
            f"jobs={len(fleet)};resident_vinf={dev_vinf};"
            f"hostmux_vinf={host_vinf};solo_vinf={solo_vinf};"
            f"vinf_vs_hostmux_x={host_vinf / max(1, dev_vinf):.1f};"
            f"vinf_vs_solo_x={solo_vinf / max(1, dev_vinf):.1f};"
            f"host_mux_us={t_host * 1e6:.1f};"
            f"map_lanes_wasted={ds.map_lanes_wasted};"
            f"map_util={ds.map_utilization:.3f};"
            f"util={ds.utilization:.3f};"
            f"hole_lanes_skipped={ds.hole_lanes_skipped}",
            stats=ds,
        )

        # the K-ladder: readback cadence between host-mux and resident
        k_times = {}
        for K in ladder:
            cache = WaveTemplateCache()
            ks = run_svc(fleet, "device", chunk=K, cache=cache).stats()
            t_k = _time(
                lambda f=fleet, K=K, c=cache: run_svc(
                    f, "device", chunk=K, cache=c
                ),
                repeats=1,
            )
            k_times[K] = float(t_k)
            expected = 1 if K is None else math.ceil(ks.epochs / K)
            row(
                f"device_service_{fname}_k{'inf' if K is None else K}",
                t_k,
                f"jobs={len(fleet)};chunk={'inf' if K is None else K};"
                f"epochs={ks.epochs};readbacks={ks.scalar_transfers};"
                f"expected_readbacks={expected};dispatches={ks.dispatches};"
                f"template_hits={cache.hits};"
                f"map_lanes_wasted={ks.map_lanes_wasted};"
                f"hole_lanes_skipped={ks.hole_lanes_skipped}",
                stats=ks,
            )

        if CHUNK == "auto":
            # self-tuning endpoint: dispatch="auto" + chunk="auto" through
            # the service front door, timed against the static K-ladder's
            # envelope (check.py --auto gates us_per_call <= worst static;
            # the acceptance target is within 10% of the best)
            cache = WaveTemplateCache()
            holder = {}

            def run_auto(f=fleet, c=cache):
                holder["svc"] = run_svc(
                    f, "device", chunk="auto", cache=c, dispatch="auto"
                )

            run_auto()
            as_ = holder["svc"].stats()
            t_a = _time(run_auto, repeats=1)
            svc_a = holder["svc"]
            kctl, dctl = svc_a.chunk_controller, svc_a.controller
            dec = ";".join(
                f"auto_{m}={c}"
                for m, c in sorted(dctl.decisions.items())
            ) if dctl is not None else ""
            row(
                f"device_service_{fname}_kauto", t_a,
                f"jobs={len(fleet)};chunk=auto;epochs={as_.epochs};"
                f"readbacks={as_.scalar_transfers};"
                f"dispatches={as_.dispatches};"
                f"k_final={kctl.current()};k_widened={kctl.widened};"
                f"k_shrunk={kctl.shrunk};{dec};"
                f"template_hits={cache.hits};"
                f"us_best_static={min(k_times.values())*1e6:.1f};"
                f"us_worst_static={max(k_times.values())*1e6:.1f};"
                f"hole_lanes_skipped={as_.hole_lanes_skipped}",
                stats=as_,
            )

        if not MEGAKERNEL:
            continue
        # megakernel rows next to their while_loop twins: same fleet, same
        # K, masked + gather, with the while_loop baseline wall-clock in
        # the derived column so the comparison is one row wide
        impl = "interpret" if (SMOKE and jax_backend() != "tpu") else "auto"
        mega_ladder = (4,) if SMOKE else (4, None)
        for dispatch in ("masked", "gather"):
            for K in mega_ladder:
                cache = WaveTemplateCache()
                ms = run_svc(
                    fleet, "device", chunk=K, cache=cache,
                    dispatch=dispatch, megakernel=True,
                    megakernel_impl=impl,
                ).stats()
                t_m = _time(
                    lambda f=fleet, K=K, c=cache, d=dispatch: run_svc(
                        f, "device", chunk=K, cache=c, dispatch=d,
                        megakernel=True, megakernel_impl=impl,
                    ),
                    repeats=1,
                )
                cache_b = WaveTemplateCache()
                t_b = _time(
                    lambda f=fleet, K=K, c=cache_b, d=dispatch: run_svc(
                        f, "device", chunk=K, cache=c, dispatch=d,
                    ),
                    repeats=1,
                )
                expected = 1 if K is None else math.ceil(ms.epochs / K)
                row(
                    f"device_service_{fname}_mega_{dispatch}"
                    f"_k{'inf' if K is None else K}",
                    t_m,
                    f"jobs={len(fleet)};chunk={'inf' if K is None else K};"
                    f"impl={impl};epochs={ms.epochs};"
                    f"readbacks={ms.scalar_transfers};"
                    f"expected_readbacks={expected};"
                    f"while_loop_us={t_b * 1e6:.1f};"
                    f"lanes_launched={ms.lanes_launched};"
                    f"hole_lanes_skipped={ms.hole_lanes_skipped};"
                    f"template_hits={cache.hits};"
                    f"map_lanes_wasted={ms.map_lanes_wasted};"
                    f"util={ms.utilization:.3f}",
                    stats=ms,
                )


# ------------- sharded fleet execution across a device mesh (DESIGN §15)
def bench_sharded_service():
    """Scale-out ladder: the same job stream through P TVM shards.

    Each ``sharded_service_<fleet>_pP`` row drains R copies of the fleet
    through ``JobService(engine="sharded", shards=P)`` — P full device
    waves on a 1-D ``"fleet"`` mesh, ONE fused launch + ONE stacked
    readback per collective chunk — and reports jobs/sec against the
    ``p1`` baseline, the collective V_inf totals, rebalance-migration
    counts, and the per-shard work split (``shard_tasks``/``shard_forks``
    pipe-joined, which ``check.py --shards`` gates: their sums must equal
    the ``p1`` row's totals exactly — sharding moves work, never changes
    it).  ``mesh=1`` marks rows that ran on a real device mesh; ``mesh=0``
    is the single-device vmap simulation (bit-identical, not parallel —
    CI forces 8 host devices so the smoke row exercises the real path).

    One :class:`~repro.service.jobs.WaveTemplateCache` is shared across
    the whole ladder: the template is deliberately not keyed on P, so
    ``p1`` compiles the chunk body once and every later P reuses it
    (``template_hits`` makes that diffable per row).
    """
    import jax

    from repro.apps import get_fleet
    from repro.service import JobService, WaveTemplateCache

    fleet = get_fleet("mixed3")
    reps = 6 if SMOKE else 8  # 18 / 24 queued jobs (acceptance: >= 16)
    n_jobs = reps * len(fleet)
    chunk = 4  # finite K: rebalancing needs chunk boundaries
    ladder = [p for p in (1, 2, 4, 8) if p <= SHARDS] or [1]
    if SMOKE and SHARDS > 1:
        ladder = [1, SHARDS]  # the smoke row: baseline + full width

    def run_sharded(shards, cache):
        svc = JobService(
            capacity=sum(q for _, q in fleet), engine="sharded",
            shards=shards, chunk=chunk, dispatch="masked",
            max_jobs=len(fleet), template_cache=cache,
            metrics=METRICS, tracer=TRACER,
        )
        for r in range(reps):
            for case, quota in fleet:
                svc.submit_case(case, quota=quota, name=f"{case.name}#{r}")
        svc.drain()
        return svc

    cache = WaveTemplateCache()
    t_p1 = None
    for P in ladder:
        svc = run_sharded(P, cache)
        fs = svc.stats()
        fl = svc._mux  # the last (only) wave's fleet, post-drain
        t = _time(lambda P=P: run_sharded(P, cache), repeats=1)
        if t_p1 is None:
            t_p1 = float(t)
        shard_stats = fl.shard_stats() if fl is not None else []
        shard_tasks = "|".join(
            str(s.tasks_executed) for s in shard_stats
        )
        shard_forks = "|".join(str(s.total_forks) for s in shard_stats)
        row(
            f"sharded_service_mixed3_p{P}", t,
            f"jobs={n_jobs};shards={P};chunk={chunk};"
            f"jobs_per_sec={n_jobs / max(float(t), 1e-9):.1f};"
            f"speedup_vs_p1={t_p1 / max(float(t), 1e-9):.2f};"
            f"vinf={fs.dispatches + fs.scalar_transfers};"
            f"collective_steps={getattr(fl, 'collective_steps', 0)};"
            f"migrations={getattr(fl, 'migrations', 0)};"
            f"util_spread="
            f"{fl.utilization_spread() if fl is not None else 0:.3f};"
            f"mesh={1 if getattr(fl, 'mesh', None) is not None else 0};"
            f"devices={jax.device_count()};"
            f"template_hits={cache.hits};"
            f"shard_tasks={shard_tasks};shard_forks={shard_forks}",
            stats=fs,
        )


# --------------------------------------------------- TVM serving engine
def bench_serving():
    import jax
    import numpy as np
    from repro import configs
    from repro.models.model import init_model
    from repro.serving import EpochServer, Request

    cfg = configs.get_reduced("granite_3_8b")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    def serve(slots):
        srv = EpochServer(cfg, params, n_slots=slots, max_len=64)
        for _ in range(8):
            srv.submit(
                Request(
                    prompt=rng.randint(3, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=8,
                )
            )
        done = srv.run_to_completion()
        return sum(len(r.output) for r in done), srv.epochs

    # warm (each serve() builds its own server, so the warm call pays the
    # jit tracing shared by the later calls; record it as compile time)
    t0 = time.perf_counter()
    serve(4)
    warm_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    n_tok, epochs = serve(4)
    dt = Timing(time.perf_counter() - t0)
    dt.compile_s = warm_s
    t0 = time.perf_counter()
    n1, e1 = serve(1)
    dt1 = time.perf_counter() - t0
    row(
        "serve_8req_slots4", dt,
        f"tokens={n_tok};epochs={epochs};"
        f"batch_speedup_vs_slots1={dt1/dt:.2f}",
    )


# ----------------------------------------------------- roofline summary
def bench_roofline():
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    from roofline import load_all

    pts = load_all()
    if not pts:
        row("roofline", 0.0, "no dry-run artifacts; run repro.launch.dryrun")
        return
    for p in pts:
        if p.mesh != "16x16":
            continue
        row(
            f"roofline_{p.arch}_{p.shape}",
            p.bound_time * 1e6,
            f"dominant={p.dominant};useful={p.useful_ratio:.2f};"
            f"frac={p.roofline_fraction:.2f}",
        )


BENCHES = {
    "fib": bench_fib,
    "fft": bench_fft,
    "graph": bench_graph,
    "sort": bench_sort,
    "overhead": bench_overhead,
    "dispatch": bench_dispatch,
    "service": bench_service,
    "device_service": bench_device_service,
    "sharded_service": bench_sharded_service,
    "serving": bench_serving,
    "roofline": bench_roofline,
}

# the CI-sized subset --smoke restricts to (each group also shrinks its own
# sizes when SMOKE is set)
SMOKE_GROUPS = ("fib", "service", "device_service")


def write_json(path: str, dispatch: str, smoke: bool, groups) -> None:
    """Machine-readable artifact alongside the CSV stdout, so the perf
    trajectory (V_inf ladders, utilization, map waste) is diffable across
    PRs instead of living only in scrollback.  ``groups`` records which
    benchmark groups actually ran — two artifacts are only comparable row
    set to row set, never across different group selections.  Rows that
    carried a RunStats serialize it via ``RunStats.as_dict()`` — the same
    metric vocabulary ``obs/export.py`` exports — so ``check.py`` gates on
    structured counters, not just the derived string."""
    rows = []
    for n, us, cus, d, s in ROWS:
        r = {
            "name": n,
            "us_per_call": round(us, 1),
            "compile_us": round(cus, 1),
            "derived": d,
        }
        if s is not None:
            r["stats"] = s.as_dict()
        rows.append(r)
    payload = {
        "schema": "trees-bench-v2",
        "dispatch": dispatch,
        "chunk": CHUNK,
        "smoke": smoke,
        "megakernel": MEGAKERNEL,
        "shards": SHARDS,
        "groups": sorted(groups),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")


def main(argv=None) -> None:
    global DISPATCH, CHUNK, SMOKE, MEGAKERNEL, SHARDS, TRACER, METRICS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--dispatch", choices=("masked", "compacted", "gather", "auto"),
        default="masked",
        help="HostEngine dispatch policy for every benchmark "
        "(masked = seed full-width vmap; compacted = §5.4 dense "
        "per-type launches; gather = §11 dense-frontier pack, hole "
        "lanes skipped; auto = §14 telemetry-driven per-epoch choice)",
    )
    ap.add_argument(
        "--chunk", choices=("auto",), default=None,
        help="device_service chunk policy: 'auto' adds the *_kauto rows "
        "(adaptive-K controller, DESIGN.md §14) next to the static "
        "K-ladder",
    )
    ap.add_argument(
        "--only", nargs="+", choices=sorted(BENCHES), default=None,
        help="run only these benchmark groups",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized subset: tiny problem sizes, groups "
        f"{SMOKE_GROUPS} only (unless --only overrides)",
    )
    ap.add_argument(
        "--megakernel", action="store_true",
        help="emit device_service_*_mega rows: the persistent Pallas "
        "epoch megakernel (masked + gather) next to the while_loop "
        "K-ladder rows (interpret mode on CPU under --smoke, native "
        "kernel on TPU)",
    )
    ap.add_argument(
        "--shards", type=int, default=0, metavar="P",
        help="emit the sharded_service_*_p{1..P} scale-out ladder "
        "(DESIGN.md §15); rows run on a real 'fleet' device mesh when "
        "the host exposes >= P devices (CI forces 8 via "
        "--xla_force_host_platform_device_count), else on the "
        "bit-identical single-device vmap fallback (mesh=0 in derived)",
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="write the rows as a machine-readable JSON artifact; defaults "
        "to BENCH_9.json for full runs, off for --only subset or --smoke "
        "runs (pass a path to force, '' to disable)",
    )
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="run the service benchmarks with the obs span tracer on and "
        "write the Chrome-trace-event JSON (perfetto-loadable) here",
    )
    ap.add_argument(
        "--metrics", default=None, metavar="PATH",
        help="run with the obs metrics registry on and write its samples "
        "as JSONL here (plus Prometheus text exposition at PATH.prom)",
    )
    args = ap.parse_args(argv)
    DISPATCH = args.dispatch
    CHUNK = args.chunk
    SMOKE = args.smoke
    MEGAKERNEL = args.megakernel
    SHARDS = args.shards
    if args.trace:
        from repro.obs import SpanTracer

        TRACER = SpanTracer()
    if args.metrics:
        from repro.obs import MetricsRegistry

        METRICS = MetricsRegistry()
    only = args.only or (list(SMOKE_GROUPS) if args.smoke else None)
    if args.shards:
        # --shards opts the scale-out ladder in, whatever the selection
        if only is not None and "sharded_service" not in only:
            only = list(only) + ["sharded_service"]
    elif only is None:
        # the ladder only means something with a shard count: skip the
        # group on plain full runs rather than emitting a p1-only row set
        only = [n for n in BENCHES if n != "sharded_service"]
    ran = []
    print("name,us_per_call,compile_us,derived")
    for name, fn in BENCHES.items():
        if only and name not in only:
            continue
        ran.append(name)
        fn()
    json_path = args.json
    if json_path is None:
        # don't silently clobber the cross-PR artifact with a subset or
        # smoke run (CI's smoke job passes --json explicitly)
        json_path = "" if (args.only or args.smoke) else "BENCH_9.json"
    if json_path:
        write_json(json_path, args.dispatch, args.smoke, ran)
    if args.trace:
        TRACER.write(args.trace)
    if args.metrics:
        from repro.obs import write_jsonl, write_prometheus

        write_jsonl(METRICS, args.metrics)
        write_prometheus(METRICS, args.metrics + ".prom")


if __name__ == "__main__":
    main()
