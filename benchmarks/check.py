"""Benchmark regression gate: fresh run vs committed BENCH_*.json.

The bench artifact carries two kinds of numbers and the gate treats them
differently, mirroring the paper's accounting split:

* **V_inf terms and other deterministic counters are exact.**  Epochs,
  tasks, dispatches, readbacks, lane volumes, template hits — these are
  properties of the *algorithm*, not the machine; any drift is a real
  semantic change (a scheduler regression, an accounting bug) and fails
  the gate outright.  They are read from the derived ``k=v`` string
  (integer-valued keys) and, for trees-bench-v2 artifacts, from the
  structured ``stats`` block (``RunStats.as_dict()``).

* **Wall-clock is fuzzy.**  ``us_per_call`` only fails when the fresh run
  is more than ``--time-factor`` times *slower* than the baseline — a
  shared CI container is noisy, and a speedup (e.g. from fixing the
  compile-in-the-mean ``_time`` bug) must never fail the gate.  Pass
  ``--strict`` to also flag implausible speedups beyond the same factor
  (catches rows that silently stopped doing the work), or
  ``--ignore-time`` to gate on counters alone.

Rows are matched by name; the gate compares the intersection so a subset
run (``--only``/``--smoke``) can still be checked against a full
baseline.  An *empty* intersection is an error — it means the two
artifacts describe disjoint row sets and "pass" would be vacuous.

* **Self-tuning rows are gated against their own static envelope.**  Rows
  produced under ``--dispatch auto`` / ``--chunk auto`` embed the static
  modes' wall-clock measured *in the same run* as ``us_best_static`` /
  ``us_worst_static``; ``--auto`` asserts, one-sided and fuzzy
  (``--auto-factor``), that the controller's row is no slower than the
  worst static choice — the "never lose" contract of DESIGN.md §14.
  This gate is self-contained (no baseline artifact needed), so the
  baseline argument is optional when ``--auto`` is given.

* **Sharded ladders are gated on conservation.**  ``--shards`` checks
  every ``sharded_service_*_pP`` row's pipe-joined per-shard counters:
  they must sum to the row's own merged stats and match the ``p1`` row's
  totals exactly — sharding moves work between shards, never changes it
  (DESIGN.md §15).  Self-contained, like ``--auto``.

Usage::

    python benchmarks/check.py FRESH.json BASELINE.json [options]
    python benchmarks/check.py FRESH.json --auto            # envelope only
    python benchmarks/check.py FRESH.json --shards          # conservation

Exit status 0 = within tolerance, 1 = drift, 2 = unusable inputs.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Optional, Tuple

# derived keys with these patterns are wall-clock-derived (or ratios of
# wall-clock) no matter how integer-like their value prints
_TIME_LIKE = re.compile(
    r"(_us$|_x$|^us_|util|occ_|frac|parallelism|speedup|overhead"
    r"|saving|_s$|_wait|lanes_wasted_ratio)"
)

_INT = re.compile(r"^-?\d+$")

# RunStats counters that must be bit-identical run to run (scheduling is
# deterministic); float derived fields (utilization, map_utilization) and
# the host-measured peak are checked for presence only
_STATS_EXACT = (
    "epochs",
    "tasks_executed",
    "lanes_launched",
    "dispatches",
    "scalar_transfers",
    "total_forks",
    "hole_lanes_skipped",
    "map_launches",
    "map_lanes_launched",
    "peak_tv_slots",
    "tasks_by_type",
    "lanes_by_type",
)


def parse_derived(derived: str) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, _, v = part.partition("=")
        out[k.strip()] = v.strip()
    return out


def exact_keys(pairs: Dict[str, str]) -> Dict[str, str]:
    """The deterministic subset of a derived dict: integer-valued keys
    that are not wall-clock-derived."""
    return {
        k: v
        for k, v in pairs.items()
        if _INT.match(v) and not _TIME_LIKE.search(k)
    }


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    schema = doc.get("schema", "")
    if not str(schema).startswith("trees-bench-"):
        raise ValueError(f"{path}: not a trees-bench artifact ({schema!r})")
    if not isinstance(doc.get("rows"), list):
        raise ValueError(f"{path}: missing rows[]")
    return doc


def _rows_by_name(doc: dict) -> Dict[str, dict]:
    return {r["name"]: r for r in doc["rows"]}


def check_row(
    name: str,
    fresh: dict,
    base: dict,
    time_factor: float,
    strict: bool,
    ignore_time: bool,
) -> List[str]:
    problems: List[str] = []

    if not ignore_time:
        f_us = float(fresh.get("us_per_call", 0.0))
        b_us = float(base.get("us_per_call", 0.0))
        if b_us > 0 and f_us > b_us * time_factor:
            problems.append(
                f"{name}: us_per_call {f_us:.1f} is "
                f"{f_us / b_us:.1f}x slower than baseline {b_us:.1f} "
                f"(tolerance {time_factor:g}x)"
            )
        if strict and f_us > 0 and b_us > f_us * time_factor:
            problems.append(
                f"{name}: us_per_call {f_us:.1f} is implausibly "
                f"{b_us / f_us:.1f}x faster than baseline {b_us:.1f} "
                f"(--strict tolerance {time_factor:g}x)"
            )

    fd = exact_keys(parse_derived(fresh.get("derived", "")))
    bd = exact_keys(parse_derived(base.get("derived", "")))
    for k in sorted(set(fd) & set(bd)):
        if fd[k] != bd[k]:
            problems.append(
                f"{name}: derived {k}={fd[k]} != baseline {bd[k]}"
            )

    fs, bs = fresh.get("stats"), base.get("stats")
    if isinstance(fs, dict) and isinstance(bs, dict):
        for k in _STATS_EXACT:
            if k in fs and k in bs and fs[k] != bs[k]:
                problems.append(
                    f"{name}: stats.{k}={fs[k]!r} != baseline {bs[k]!r}"
                )
    return problems


def run_auto_check(
    fresh_path: str,
    auto_factor: float = 1.25,
    out=sys.stdout,
) -> int:
    """Gate self-tuning rows against the static envelope they embed.

    A row participates when its derived string carries
    ``us_worst_static`` (emitted only by ``--dispatch auto`` /
    ``--chunk auto`` runs, measured in the same process on the same
    machine — so the comparison needs no cross-run fuzz, only a noise
    factor).  One-sided: auto being *faster* than every static mode can
    never fail.
    """
    try:
        fresh = load(fresh_path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check: {e}", file=out)
        return 2

    gated = 0
    problems: List[str] = []
    for r in fresh["rows"]:
        d = parse_derived(r.get("derived", ""))
        if "us_worst_static" not in d:
            continue
        gated += 1
        name = r["name"]
        f_us = float(r.get("us_per_call", 0.0))
        worst = float(d["us_worst_static"])
        best = float(d.get("us_best_static", worst))
        if worst > 0 and f_us > worst * auto_factor:
            problems.append(
                f"{name}: auto us_per_call {f_us:.1f} is slower than the "
                f"worst static mode {worst:.1f} (x{auto_factor:g} "
                f"tolerance) — the controller is losing"
            )
        vs_best = f_us / best if best > 0 else float("inf")
        print(
            f"  auto {name}: {f_us:.1f}us vs static "
            f"[{best:.1f}, {worst:.1f}]us ({vs_best:.2f}x best)",
            file=out,
        )
    if gated == 0:
        print(
            f"check: {fresh_path} has no us_worst_static rows — was it "
            "run with --dispatch auto / --chunk auto?",
            file=out,
        )
        return 2
    print(f"check: {gated} auto row(s) gated against their static "
          f"envelope (tolerance {auto_factor:g}x worst)", file=out)
    for p in problems:
        print(f"  FAIL {p}", file=out)
    if problems:
        print(f"check: {len(problems)} failure(s)", file=out)
        return 1
    print("check: auto OK", file=out)
    return 0


def run_shards_check(fresh_path: str, out=sys.stdout) -> int:
    """Gate the sharded scale-out ladder's conservation invariant.

    Self-contained (no baseline needed): for every
    ``sharded_service_<fleet>_pP`` row, the pipe-joined per-shard counters
    (``shard_tasks``/``shard_forks``) must have exactly P entries and sum
    to the row's own merged stats — and every row of one fleet's ladder
    must agree with the ``p1`` row's totals *exactly*.  Sharding (and
    chunk-boundary rebalancing) moves work between shards; it must never
    create, lose, or re-execute any of it (DESIGN.md §15).
    """
    try:
        fresh = load(fresh_path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check: {e}", file=out)
        return 2

    ladder = re.compile(r"^(sharded_service_.+)_p(\d+)$")
    groups: Dict[str, Dict[int, dict]] = {}
    for r in fresh["rows"]:
        m = ladder.match(r["name"])
        if m:
            groups.setdefault(m.group(1), {})[int(m.group(2))] = r
    if not groups:
        print(
            f"check: {fresh_path} has no sharded_service_*_p<P> rows — "
            "was it run with --shards?",
            file=out,
        )
        return 2

    problems: List[str] = []
    gated = 0
    for gname in sorted(groups):
        rows = groups[gname]
        totals: Dict[str, Dict[int, int]] = {"tasks": {}, "forks": {}}
        for p in sorted(rows):
            r = rows[p]
            d = parse_derived(r.get("derived", ""))
            stats = r.get("stats") or {}
            for key, stat_key, tot in (
                ("shard_tasks", "tasks_executed", "tasks"),
                ("shard_forks", "total_forks", "forks"),
            ):
                if key not in d:
                    problems.append(f"{r['name']}: derived lacks {key}")
                    continue
                gated += 1
                parts = [int(v) for v in d[key].split("|") if v != ""]
                if len(parts) != p:
                    problems.append(
                        f"{r['name']}: {key} has {len(parts)} entries, "
                        f"expected {p} (one per shard)"
                    )
                s = sum(parts)
                totals[tot][p] = s
                if stat_key in stats and s != stats[stat_key]:
                    problems.append(
                        f"{r['name']}: sum({key})={s} != "
                        f"stats.{stat_key}={stats[stat_key]} — per-shard "
                        "accounting leaks work"
                    )
        for tot, per_p in totals.items():
            base_p = min(per_p) if per_p else None
            for p, s in sorted(per_p.items()):
                if s != per_p[base_p]:
                    problems.append(
                        f"{gname}_p{p}: total {tot}={s} != p{base_p} "
                        f"baseline {per_p[base_p]} — sharding changed the "
                        "work, not just its placement"
                    )
    print(
        f"check: {gated} per-shard counter list(s) gated across "
        f"{len(groups)} sharded ladder(s)",
        file=out,
    )
    for p in problems:
        print(f"  FAIL {p}", file=out)
    if problems:
        print(f"check: {len(problems)} failure(s)", file=out)
        return 1
    print("check: shards OK", file=out)
    return 0


def run_latency_check(
    fresh_path: str,
    base_path: Optional[str] = None,
    latency_factor: float = 1.25,
    out=sys.stdout,
) -> int:
    """Gate the front-door loadgen rows (DESIGN.md §16).

    Self-contained part (no baseline needed): the ``loadgen_fifo`` /
    ``loadgen_priority`` pair must show the admission layer *winning* —
    the offered load makes FIFO packing miss interactive deadlines
    (``misses_interactive > 0``, otherwise the scenario gates nothing)
    and priority admission misses strictly fewer, with an interactive p99
    no worse than FIFO's.  Loadgen runs on a virtual clock, so these are
    deterministic properties of the scheduling algorithm.

    With a baseline artifact: the deterministic scoreboard (job count,
    deadline misses/met, preemptions, jobs per virtual second) must match
    *exactly*, and the latency percentiles are gated one-sided and fuzzy
    (``--latency-factor``) — they are virtual-time too, but small packing
    changes legitimately move them a little.
    """
    try:
        fresh = load(fresh_path)
        base = load(base_path) if base_path is not None else None
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check: {e}", file=out)
        return 2

    fr = _rows_by_name(fresh)
    if "loadgen_fifo" not in fr or "loadgen_priority" not in fr:
        print(
            f"check: {fresh_path} lacks loadgen_fifo/loadgen_priority "
            "rows — was it produced by benchmarks/loadgen.py?",
            file=out,
        )
        return 2
    fifo = parse_derived(fr["loadgen_fifo"].get("derived", ""))
    prio = parse_derived(fr["loadgen_priority"].get("derived", ""))

    problems: List[str] = []
    f_miss = int(fifo.get("misses_interactive", -1))
    p_miss = int(prio.get("misses_interactive", -1))
    if f_miss <= 0:
        problems.append(
            f"loadgen_fifo: misses_interactive={f_miss} — the offered "
            "load no longer stresses FIFO packing, the comparison is "
            "vacuous"
        )
    if p_miss < 0 or p_miss >= max(f_miss, 0):
        problems.append(
            f"loadgen_priority: misses_interactive={p_miss} is not "
            f"strictly fewer than FIFO's {f_miss} — the admission layer "
            "stopped winning"
        )
    f_p99 = float(fifo.get("p99_interactive_ms", 0.0))
    p_p99 = float(prio.get("p99_interactive_ms", 0.0))
    if p_p99 > f_p99:
        problems.append(
            f"loadgen_priority: p99_interactive_ms={p_p99} exceeds "
            f"FIFO's {f_p99}"
        )
    print(
        f"check: loadgen interactive deadlines — fifo misses {f_miss}, "
        f"priority misses {p_miss}; p99 {f_p99}ms -> {p_p99}ms",
        file=out,
    )

    if base is not None:
        br = _rows_by_name(base)
        exact = (
            "jobs", "misses_interactive", "met_interactive", "preempts",
            "jobs_per_vsec",
        )
        for name in ("loadgen_fifo", "loadgen_priority"):
            if name not in br:
                problems.append(f"{name}: missing from baseline")
                continue
            fd = parse_derived(fr[name].get("derived", ""))
            bd = parse_derived(br[name].get("derived", ""))
            for k in exact:
                if k in fd and k in bd and fd[k] != bd[k]:
                    problems.append(
                        f"{name}: {k}={fd[k]} != baseline {bd[k]} "
                        "(virtual-time counters are deterministic — this "
                        "is a scheduling change, not noise)"
                    )
            for k in sorted(bd):
                if not k.startswith(("p50_", "p99_")):
                    continue
                if k not in fd:
                    problems.append(f"{name}: derived lacks {k}")
                    continue
                f_v, b_v = float(fd[k]), float(bd[k])
                if b_v > 0 and f_v > b_v * latency_factor:
                    problems.append(
                        f"{name}: {k}={f_v} is {f_v / b_v:.2f}x the "
                        f"baseline {b_v} (tolerance {latency_factor:g}x)"
                    )

    for p in problems:
        print(f"  FAIL {p}", file=out)
    if problems:
        print(f"check: {len(problems)} failure(s)", file=out)
        return 1
    print("check: latency OK", file=out)
    return 0


def run_check(
    fresh_path: str,
    base_path: str,
    time_factor: float = 25.0,
    strict: bool = False,
    ignore_time: bool = False,
    out=sys.stdout,
) -> int:
    try:
        fresh = load(fresh_path)
        base = load(base_path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"check: {e}", file=out)
        return 2

    if fresh.get("dispatch") != base.get("dispatch"):
        print(
            f"check: dispatch mismatch "
            f"({fresh.get('dispatch')} vs {base.get('dispatch')}); "
            "rows are not comparable",
            file=out,
        )
        return 2

    fr, br = _rows_by_name(fresh), _rows_by_name(base)
    common = sorted(set(fr) & set(br))
    missing = sorted(set(br) - set(fr))
    extra = sorted(set(fr) - set(br))
    if not common:
        print(
            f"check: no common rows between {fresh_path} ({len(fr)} rows) "
            f"and {base_path} ({len(br)} rows) — nothing to gate",
            file=out,
        )
        return 2

    problems: List[str] = []
    for name in common:
        problems += check_row(
            name, fr[name], br[name], time_factor, strict, ignore_time
        )

    print(
        f"check: {len(common)} rows compared "
        f"({len(missing)} baseline-only, {len(extra)} fresh-only), "
        f"time tolerance {time_factor:g}x"
        f"{' (strict)' if strict else ''}"
        f"{' (time ignored)' if ignore_time else ''}",
        file=out,
    )
    if strict and missing:
        problems.append(
            "rows present in baseline but missing from fresh run: "
            + ", ".join(missing)
        )
    for p in problems:
        print(f"  FAIL {p}", file=out)
    if problems:
        print(f"check: {len(problems)} failure(s)", file=out)
        return 1
    print("check: OK", file=out)
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("fresh", help="JSON artifact from the run under test")
    ap.add_argument(
        "baseline", nargs="?", default=None,
        help="committed BENCH_*.json to gate against (optional with "
        "--auto: the envelope gate is self-contained)",
    )
    ap.add_argument(
        "--time-factor", type=float, default=25.0,
        help="fail when us_per_call exceeds baseline by this factor "
        "(default %(default)s; slowdowns only unless --strict)",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="also fail on speedups beyond --time-factor and on rows "
        "missing from the fresh run",
    )
    ap.add_argument(
        "--ignore-time", action="store_true",
        help="gate only on deterministic counters, not wall-clock",
    )
    ap.add_argument(
        "--auto", action="store_true",
        help="also gate self-tuning rows against the static envelope "
        "they embed (us_per_call <= us_worst_static * --auto-factor)",
    )
    ap.add_argument(
        "--auto-factor", type=float, default=1.25,
        help="one-sided noise tolerance for the --auto envelope gate "
        "(default %(default)s)",
    )
    ap.add_argument(
        "--shards", action="store_true",
        help="also gate the sharded_service ladder's conservation "
        "invariant: per-shard counter sums must equal the single-shard "
        "baseline's totals exactly (self-contained, no baseline needed)",
    )
    ap.add_argument(
        "--latency", action="store_true",
        help="gate the loadgen front-door rows: priority admission must "
        "beat FIFO on interactive deadlines (self-contained), plus exact "
        "virtual-time counters and fuzzy percentiles vs the baseline "
        "when one is given",
    )
    ap.add_argument(
        "--latency-factor", type=float, default=1.25,
        help="one-sided tolerance for p50/p99 vs the baseline under "
        "--latency (default %(default)s)",
    )
    args = ap.parse_args(argv)
    if args.baseline is None and not (
        args.auto or args.shards or args.latency
    ):
        ap.error(
            "baseline artifact required unless --auto/--shards/--latency "
            "is given"
        )
    rc = 0
    if args.baseline is not None and not args.latency:
        rc = run_check(
            args.fresh, args.baseline,
            time_factor=args.time_factor,
            strict=args.strict,
            ignore_time=args.ignore_time,
        )
    if args.auto:
        rc = max(rc, run_auto_check(args.fresh, args.auto_factor))
    if args.shards:
        rc = max(rc, run_shards_check(args.fresh))
    if args.latency:
        rc = max(rc, run_latency_check(
            args.fresh, args.baseline, args.latency_factor
        ))
    return rc


if __name__ == "__main__":
    sys.exit(main())
