"""Sharded fleet execution across a device mesh (DESIGN.md §15).

Everything below §15 runs one TVM on one device; this module is the step
to a *fleet*: P independent TVM shards — each a full scheduler-stack +
arena + :class:`~repro.core.engine.ResidentCarry` block, i.e. exactly one
:class:`~repro.service.multiplexer.DeviceMultiplexer` wave — stacked on a
leading "fleet" axis and advanced together:

* **One fused launch per collective chunk.**
  :meth:`~repro.core.engine.EpochLoop.run_chunk_fleet` runs every shard's
  resident chunk inside one compiled program — ``shard_map`` over the 1-D
  ``"fleet"`` mesh (:func:`repro.launch.mesh.make_fleet_mesh`) when
  enough devices are attached, a bit-identical ``vmap`` simulation
  otherwise — with each shard bounded by its *own* dynamic epoch limit.

* **One readback per collective chunk.**  The per-shard
  :class:`~repro.core.engine.ChunkSummary` scalars come back stacked in a
  single ``device_get`` (:meth:`EpochLoop.fleet_chunk_summaries`), so a
  fleet advancing K epochs pays ⌈E/K⌉ launches + readbacks *total*, not
  per shard.

* **Chunk-boundary work rebalancing.**  Jobs are placed on shards by a
  policy (``round_robin`` / ``least_loaded`` / ``sticky``); at each
  boundary, queued jobs stuck on a *hot* shard (no free compatible
  region) migrate to an *idle* shard (free region, least load measured
  from the stacked summaries: live regions, queue depth, sp-derived
  remaining stack work) and seat through the existing
  ``_seed_region`` / ``arena_reset_region`` reseed path — the same path
  mid-flight admission has always used, so migration cannot introduce a
  second seeding semantics.

Every shard shares ONE wave template (same fused program, slot layout,
and compiled loop): shards are *structurally* identical and differ only
in runtime state, which is what lets the collective step be a single
compiled program.  A shard region left without a tenant is *vacant*
(``handle=None``, sp=0 — inert by the TMS epoch-number guard) until a
job seats into it.  Per-job execution inside a shard region is exactly
the solo region execution, so per-job results stay bit-identical to a
solo run at every P, every placement, and every migration history.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import resolve_resident_dispatch
from ..core.scheduler import RunStats
from ..obs.trace import NULL_TRACER
from ..service.jobs import (
    Job,
    JobHandle,
    WaveTemplate,
    canonical_wave_order,
)
from ..service.multiplexer import DeviceMultiplexer, fuse_programs

PLACEMENTS = ("round_robin", "least_loaded", "sticky")


def _type_key(job: Job) -> int:
    """Stable integer from a job's structural hash (sticky placement)."""
    h = job.program.structural_hash()
    try:
        return int(h, 16)
    except ValueError:
        return abs(hash(h))


class ShardWave(DeviceMultiplexer):
    """One shard: a DeviceMultiplexer wave whose regions all start vacant.

    Construction seats nobody — the fleet seats every tenant (initial and
    migrated alike) through :meth:`~repro.service.multiplexer._FleetBase.
    admit`'s reseed path against the eagerly-built all-vacant carry.  The
    chunk itself is *not* driven here: the fleet stacks the shard carries
    and runs them through one collective ``run_chunk_fleet`` launch, then
    hands each shard its own summary via ``_finish_chunk``.
    """

    def __init__(self, template: WaveTemplate, **kw):
        super().__init__(
            handles=[None] * len(template.slots), template=template, **kw
        )
        self._ensure_carry()
        # admission gating is the fleet's job: shards only ever seat
        # tenants at collective boundaries, where every region is either
        # mid-flight-with-finite-chunk or fully drained — both safe
        self._admit_ok = True

    def _admits_midflight(self) -> bool:
        return self._carry is not None and self._admit_ok

    @property
    def live_regions(self) -> int:
        return sum(1 for r in self._regions if r.running)


class ShardedFleet:
    """P TVM shards advancing together: one launch, one readback, per
    collective chunk (DESIGN.md §15).

    ``handles`` is the *anchor wave*: its jobs (in canonical order)
    define the per-shard slot layout replicated across all P shards, and
    are then placed like any later admission.  ``admit`` accepts any job
    structurally compatible with that layout — placement queues it on a
    shard, seating happens at collective boundaries through the reseed
    path.  Drive with :meth:`step` / :meth:`run`; completions stream per
    boundary exactly like a single ``DeviceMultiplexer`` wave.

    ``mesh="auto"`` takes a real ``"fleet"`` device mesh when the host
    has >= P devices (each shard's resident loop runs on its own device)
    and falls back to the single-device ``vmap`` simulation otherwise —
    same bits either way.  ``rebalance=False`` pins every job to its
    placed shard (sticky affinity); the default migrates queued jobs off
    hot shards at boundaries and counts each move in ``migrations``.
    """

    def __init__(
        self,
        handles: Sequence[JobHandle],
        shards: int,
        *,
        dispatch: Any = "masked",
        stack_depth: int = 1 << 10,
        chunk: Any = None,
        placement: str = "round_robin",
        placement_controller=None,
        rebalance: bool = True,
        collect_stats: bool = True,
        stats_factory: Optional[Callable[[int], Any]] = None,
        template: Optional[WaveTemplate] = None,
        megakernel: bool = False,
        megakernel_impl: str = "auto",
        tracer=None,
        controller=None,
        chunk_controller=None,
        queue_probe=None,
        mesh: Any = "auto",
    ):
        if shards < 1:
            raise ValueError(f"a fleet needs >= 1 shard, got {shards}")
        if placement not in PLACEMENTS + ("auto",):
            raise ValueError(
                f"placement must be one of {PLACEMENTS + ('auto',)}, "
                f"got {placement!r}"
            )
        if not handles:
            raise ValueError("ShardedFleet needs at least one anchor job")
        self.shards = int(shards)
        self.placement = placement
        self._pctl = None
        if placement == "auto":
            from ..control.controller import PlacementController

            self._pctl = (
                placement_controller or PlacementController()
            )
        self.rebalance = bool(rebalance)
        self.tracer = tracer or NULL_TRACER
        self.migrations = 0
        self.collective_steps = 0

        order = canonical_wave_order([h.job for h in handles])
        anchors = [handles[i] for i in order]
        jobs = [h.job for h in anchors]
        self.capacity = sum(j.quota for j in jobs)  # per shard

        dispatch = resolve_resident_dispatch(
            dispatch, controller, self.capacity
        )
        if template is None:
            from ..core.engine import EpochLoop

            program, slots = fuse_programs(
                [j.program for j in jobs], [j.quota for j in jobs]
            )
            template = WaveTemplate(
                key=("fleet-anon",),
                program=program,
                slots=slots,
                loop=EpochLoop(
                    program, dispatch, skip_idle_types=True,
                    megakernel=megakernel,
                    megakernel_impl=megakernel_impl,
                ),
            )
        self.template = template
        self._loop = template.loop
        self.chunk = chunk
        self._kctl = None
        if chunk == "auto":
            from ..control.controller import ChunkController

            self._kctl = chunk_controller or ChunkController()
        self._queue_probe = queue_probe
        self._shards: List[ShardWave] = [
            ShardWave(
                template,
                dispatch=dispatch,
                stack_depth=stack_depth,
                chunk=chunk,
                collect_stats=collect_stats,
                stats_factory=(
                    None if stats_factory is None
                    else (lambda _p=p: stats_factory(_p))
                ),
                megakernel=megakernel,
                megakernel_impl=megakernel_impl,
                controller=controller,
                chunk_controller=self._kctl,
            )
            for p in range(self.shards)
        ]
        self.policy = self._shards[0].policy
        self._slot_types = [
            (s.program.structural_hash(), s.quota) for s in template.slots
        ]
        if mesh == "auto":
            from ..launch.mesh import make_fleet_mesh

            mesh = make_fleet_mesh(self.shards)
        self.mesh = mesh
        self._pending: List[List[JobHandle]] = [
            [] for _ in range(self.shards)
        ]
        self._rr = 0
        # fleet-carry bookkeeping (see _view/_stacked): the stacked carry
        # is the single source of truth between boundaries; shards get
        # host-side views of it ONLY when the host actually needs to
        # touch their state (a completion to finalize, a job to seat) —
        # never as a per-step eager slice of device-sharded arrays, which
        # on a real mesh would be a cross-device gather per leaf per
        # shard per chunk
        self._fcarry = None
        self._host = None  # lazy device_get snapshot of _fcarry
        self._fresh = [True] * self.shards
        self._attached: List[Any] = [
            sh._carry for sh in self._shards
        ]
        self._last_sp: List[Optional[np.ndarray]] = [None] * self.shards
        # fleet-level V_inf: ONE fused launch + ONE stacked readback per
        # collective chunk, however many shards rode it
        self._dispatches = 0
        self._transfers = 0

        for h in anchors:
            if not self.admit(h):
                raise ValueError(
                    f"anchor job {h.job.name!r} does not fit the fleet "
                    "layout it defined"
                )

    # ----------------------------------------------------------- placement
    def compatible(self, job: Job) -> bool:
        """Whether this layout can ever run the job (structural equality
        with some slot template, quota within the slot)."""
        h = job.program.structural_hash()
        return any(h == sh and job.quota <= q for sh, q in self._slot_types)

    def _load(self, p: int):
        """Shard load, least-first comparable: queued jobs, live regions,
        and the last summary's sp-derived remaining stack work."""
        sp = self._last_sp[p]
        return (
            len(self._pending[p]),
            self._shards[p].live_regions,
            0 if sp is None else int(sp.sum()),
        )

    def _place(self, job: Job) -> int:
        policy = self.placement
        if self._pctl is not None:
            # placement="auto": the controller re-picks the concrete
            # policy per job from the observed workload mix
            self._pctl.observe_job(_type_key(job))
            policy = self._pctl.choose()
        if policy == "sticky":
            return _type_key(job) % self.shards
        if policy == "least_loaded":
            return min(range(self.shards), key=self._load)
        p = self._rr
        self._rr = (self._rr + 1) % self.shards
        return p

    def admit(self, handle: JobHandle) -> bool:
        """Queue a job on its placed shard (False if the layout can never
        run it).  Seating — including any rebalancing migration — happens
        at the next collective boundary."""
        if not self.compatible(handle.job):
            return False
        self._pending[self._place(handle.job)].append(handle)
        return True

    def _free_region(self, p: int, job: Job) -> bool:
        h = job.program.structural_hash()
        return any(
            r.handle is None
            and job.quota <= r.slot.quota
            and (
                r.slot.program is job.program
                or r.slot.program.structural_hash() == h
            )
            for r in self._shards[p]._regions
        )

    def _seat_pending(self) -> int:
        """Seat queued jobs on their shards; then (rebalance) migrate jobs
        stuck on hot shards to idle shards with free compatible regions.
        Every seat goes through the shard's admit → ``_seed_region``
        reseed path."""
        seated = 0
        for p, sh in enumerate(self._shards):
            if not self._pending[p]:
                continue
            self._view(p)  # reseed mutates the carry: need the real one
            rest: List[JobHandle] = []
            for h in self._pending[p]:
                if sh.admit(h):
                    seated += 1
                else:
                    rest.append(h)
            self._pending[p] = rest
        if self.rebalance:
            for p in range(self.shards):
                if not self._pending[p]:
                    continue
                rest = []
                for h in self._pending[p]:
                    cands = [
                        q for q in range(self.shards)
                        if q != p and self._free_region(q, h.job)
                    ]
                    tgt = min(cands, key=self._load) if cands else None
                    if tgt is not None:
                        self._view(tgt)
                    if tgt is not None and self._shards[tgt].admit(h):
                        self.migrations += 1
                        seated += 1
                    else:
                        rest.append(h)
                self._pending[p] = rest
        return seated

    # ------------------------------------------------------------- driving
    @property
    def live(self) -> bool:
        return (
            any(sh.live for sh in self._shards)
            or any(self._pending)
        )

    @property
    def loop(self):
        return self._loop

    @property
    def slots(self):
        return list(self.template.slots)

    def _ensure_host(self):
        """The host snapshot of the fleet carry — ONE bulk ``device_get``
        per boundary that needs any host interaction, shared by every
        shard viewed at that boundary."""
        if self._host is None:
            self._host = jax.device_get(self._fcarry)
        return self._host

    def _view(self, p: int) -> None:
        """Attach shard ``p``'s carry as a host-side slice of the fleet
        carry.  Deliberately NOT an eager ``x[p]`` on the collective
        output: on a real mesh that is a cross-device gather per leaf
        per shard (and can wedge XLA CPU's collective rendezvous); a
        ``device_get`` of the addressable shards costs no collective."""
        if self._fresh[p] or self._fcarry is None:
            return
        host = self._ensure_host()
        view = jax.tree.map(lambda x, _p=p: jnp.asarray(x[_p]), host)
        self._shards[p]._attach_carry(view)
        self._attached[p] = view
        self._fresh[p] = True

    def _stacked(self):
        """The fleet carry: per-shard carries stacked on the leading axis.
        Steady-state chunks reuse the previous collective output directly
        (its leaves ARE the stacked arrays); only a boundary that reseeded
        some shard's carry pays a restack, and only the reseeded shards'
        host-attached carries feed it — untouched shards come from the
        host snapshot, never from a stale attachment."""
        if self._fcarry is None:
            # first collective step: every shard's carry is authoritative
            # (built vacant, anchors seated through admit)
            parts = [sh._carry for sh in self._shards]
        elif any(
            self._fresh[p] and self._shards[p]._carry is not self._attached[p]
            for p in range(self.shards)
        ):
            host = self._ensure_host()
            parts = [
                sh._carry if self._fresh[p]
                else jax.tree.map(lambda x, _p=p: jnp.asarray(x[_p]), host)
                for p, sh in enumerate(self._shards)
            ]
        else:
            return self._fcarry
        self._fcarry = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
        self._host = None
        for p, sh in enumerate(self._shards):
            if self._fresh[p]:
                self._attached[p] = sh._carry
        return self._fcarry

    def step(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        """One collective chunk: seat/rebalance queued jobs, advance every
        live shard by (at most) K epochs in ONE fused launch, read the
        stacked summaries back ONCE, then settle each shard's riders."""
        self._seat_pending()
        riders = [
            [j for j, r in enumerate(sh._regions) if r.running]
            for sh in self._shards
        ]
        if not any(riders):
            return []
        limits = np.asarray(
            [
                sh._chunk_limit(max_epochs) if riders[p] else 0
                for p, sh in enumerate(self._shards)
            ],
            np.int32,
        )
        fc = self._stacked()
        J = len(self.template.slots)
        self.collective_steps += 1
        tr = self.tracer
        if tr.enabled:
            tr.thread(3, "fleet")
            for p in range(self.shards):
                tr.thread(10 + p, f"shard{p}")
        with tr.span(
            "collective_chunk", "fleet", tid=3,
            seq=self.collective_steps, shards=self.shards,
            jobs=sum(len(r) for r in riders),
            mode=self.policy.name,
            mesh=self.mesh is not None,
        ):
            with tr.span("dispatch", "fleet", tid=3), tr.annotation(
                "trees:fleet_chunk"
            ):
                out = self._loop.run_chunk_fleet(
                    fc, limits, n_regions=J, n_shards=self.shards,
                    mesh=self.mesh,
                )
            self._fcarry = out
            self._host = None
            self._fresh = [False] * self.shards
            with tr.span("readback", "fleet", tid=3):
                summaries = self._loop.fleet_chunk_summaries(
                    out, self.shards
                )
        self._dispatches += 1
        self._transfers += 1
        done: List[JobHandle] = []
        for p, sh in enumerate(self._shards):
            s = summaries[p]
            self._last_sp[p] = s.sp
            if not riders[p]:
                continue
            # a shard's carry is only pulled to the host when settling
            # will actually touch it (a rider drained, failed, or hit the
            # guard); quiet shards ride the next chunk without any host
            # traffic on their state
            if any(
                bool(s.failed[j]) or int(s.sp[j]) == 0
                or s.n_epochs >= max_epochs
                for j in riders[p]
            ):
                self._view(p)
            shard_done = sh._finish_chunk(s, riders[p], max_epochs)
            done.extend(shard_done)
            if tr.enabled:
                with tr.span(
                    "chunk", "fleet", tid=10 + p, shard=p,
                    jobs=len(riders[p]), **sh.last_deltas,
                ):
                    pass
        # controller feedback, ONCE per collective boundary: the fleet
        # queue is its internal shard queues plus whatever external queue
        # the service reports (the probe's optional third element is the
        # admission layer's nearest-deadline slack)
        if self._pctl is not None:
            loads = [len(q) for q in self._pending]
            self._pctl.observe_imbalance(
                self.utilization_spread(), max(loads) - min(loads)
            )
        if self._kctl is not None:
            queued = sum(len(q) for q in self._pending)
            oldest, slack = 0.0, None
            if self._queue_probe is not None:
                probe = self._queue_probe()
                queued += probe[0]
                oldest = probe[1]
                if len(probe) > 2:
                    slack = probe[2]
            if slack is None:
                self._kctl.observe(len(done), queued, oldest)
            else:
                self._kctl.observe(
                    len(done), queued, oldest, deadline_slack=slack
                )
        return done

    # ---------------------------------------------------------- preemption
    def running_handles(self) -> List[JobHandle]:
        out: List[JobHandle] = []
        for sh in self._shards:
            out.extend(sh.running_handles())
        return out

    def preempt(self, handle: JobHandle) -> bool:
        """Lift a running job off whichever shard holds it into its
        engine-agnostic checkpoint (the region goes vacant).  Works only
        at collective boundaries — exactly when the service calls it —
        because the shard's carry must be host-attached to capture."""
        for p, sh in enumerate(self._shards):
            if any(
                r.handle is handle and r.running for r in sh._regions
            ):
                self._view(p)  # capture/vacate mutate the carry
                return sh.preempt(handle)
        return False

    def run(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        out: List[JobHandle] = []
        while self.live:
            got = self.step(max_epochs=max_epochs)
            out.extend(got)
            if not got and not any(sh.live for sh in self._shards):
                # queued jobs nobody can seat — impossible by construction
                # (compatible() gates admit), but never spin silently
                raise RuntimeError(
                    "sharded fleet wedged: queued jobs but no live or "
                    "seatable region"
                )
        return out

    # ----------------------------------------------------------- reporting
    def stats(self) -> RunStats:
        """Fleet totals: per-shard work counters summed, V_inf terms
        counted per *collective* step — P shards ride ONE launch and ONE
        readback per chunk, which is the entire point."""
        total = RunStats()
        for sh in self._shards:
            total.merge(sh.stats())
        total.dispatches = self._dispatches
        total.scalar_transfers = self._transfers
        return total

    def shard_stats(self) -> List[RunStats]:
        """Per-shard solo-comparable stats (each shard accounted as if it
        were its own DeviceMultiplexer wave)."""
        return [sh.stats() for sh in self._shards]

    def utilization_spread(self) -> float:
        """Max-min per-shard lane utilization — the load-imbalance signal
        the benchmark rows carry."""
        utils = [s.utilization for s in self.shard_stats()
                 if s.lanes_launched > 0]
        if not utils:
            return 0.0
        return max(utils) - min(utils)

    @property
    def pending_jobs(self) -> int:
        return sum(len(q) for q in self._pending)

    @property
    def trace_count(self) -> int:
        return self._loop.trace_count
