# Distributed-optimization substrate: gradient compression (error-feedback
# int8 / bf16 all-reduce), GPipe pipeline parallelism over the 'pod' axis,
# and sharded TVM fleet execution over a 1-D "fleet" mesh (DESIGN.md §15).
from .compression import CompressionState, compressed_grad_allreduce  # noqa: F401
from .fleet import PLACEMENTS, ShardedFleet, ShardWave  # noqa: F401
from .pipeline import gpipe_apply  # noqa: F401
