"""Gradient compression for the data-parallel all-reduce.

``compressed_grad_allreduce`` runs under ``shard_map`` over the data axes:
each shard quantizes its local gradient to int8 (per-tensor max scale) with
an error-feedback residual [Seide et al., 1-bit SGD; Karimireddy et al.
EF-SGD], all-reduces the int32 sums (4x fewer wire bytes than f32; 2x vs
bf16), and dequantizes.  The residual carries the quantization error into
the next step, which is what keeps convergence intact.

``bf16`` mode simply casts before the all-reduce (2x compression, no state).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    """Per-parameter error-feedback residuals (same shapes as grads)."""

    residual: Dict[str, jnp.ndarray]


def init_compression(params: Dict[str, jnp.ndarray]) -> CompressionState:
    return CompressionState(
        residual={k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()}
    )


def compressed_grad_allreduce(
    grads: Dict[str, jnp.ndarray],
    axis_names: Tuple[str, ...],
    method: str = "int8",
    state: Optional[CompressionState] = None,
) -> Tuple[Dict[str, jnp.ndarray], Optional[CompressionState]]:
    """All-reduce (mean) local grads over ``axis_names`` with compression.

    Must be called inside shard_map with ``axis_names`` bound.  Returns the
    averaged grads and the updated error-feedback state (int8 mode).
    """
    n = 1
    for a in axis_names:
        n *= jax.lax.axis_size(a)

    if method == "none":
        return {
            k: jax.lax.pmean(g, axis_names) for k, g in grads.items()
        }, state
    if method == "bf16":
        out = {}
        for k, g in grads.items():
            gc = g.astype(jnp.bfloat16)
            out[k] = (
                jax.lax.psum(gc.astype(jnp.float32), axis_names) / n
            ).astype(g.dtype)
        return out, state
    if method != "int8":
        raise ValueError(f"unknown compression method {method!r}")

    assert state is not None, "int8 compression needs error-feedback state"
    new_resid = {}
    out = {}
    for k, g in grads.items():
        gf = g.astype(jnp.float32) + state.residual[k]
        # per-tensor symmetric scale; shared across shards via max-reduce so
        # the integer sums are exact
        local_amax = jnp.max(jnp.abs(gf))
        amax = jax.lax.pmax(local_amax, axis_names)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        new_resid[k] = gf - q * scale  # what quantization dropped
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        out[k] = (qsum.astype(jnp.float32) * scale / n).astype(g.dtype)
    return out, CompressionState(residual=new_resid)
