"""GPipe-style pipeline parallelism over a mesh axis (default: "pod").

The layer stack is split into ``n_stages`` contiguous stages; microbatches
flow through stages with ``collective_permute`` between neighbours.  The
schedule is the classic GPipe loop: ``n_micro + n_stages - 1`` ticks, each
tick every stage processes (its params, the activation it holds), then
activations shift one stage to the right.  Bubble fraction =
(n_stages - 1) / (n_micro + n_stages - 1) — reported by ``gpipe_bubble``.

Implemented with shard_map so the stage dimension *is* the mesh axis: stage
i's parameters live only on pod i (true pipeline memory scaling).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe_bubble(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe_apply(
    stage_fn: Callable,      # (stage_params, x) -> x
    stage_params,            # pytree with leading stage dim == axis size
    x: jnp.ndarray,          # (n_micro, micro_batch, ...) microbatched input
    mesh,
    axis: str = "pod",
):
    """Run the pipeline; returns outputs with microbatch leading dim.

    ``stage_params`` leaves have leading dim = n_stages (sharded over
    ``axis``); ``x`` is microbatched on dim 0 (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    n_ticks = n_micro + n_stages - 1

    def per_stage(params, xs):
        # params: this stage's slice (leading dim 1); xs: all microbatches
        params = jax.tree.map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        # mark buffers device-varying so scan carries typecheck under vma
        xs = jax.lax.pvary(xs, (axis,))
        buf = jnp.zeros_like(xs[0])  # activation currently held

        def tick(carry, t):
            buf, ys = carry
            # stage 0 ingests microbatch t (while t < n_micro)
            mb = xs[jnp.clip(t, 0, n_micro - 1)]
            buf = jnp.where(sid == 0, jnp.where(t < n_micro, mb, buf), buf)
            out = stage_fn(params, buf)
            # last stage emits microbatch (t - n_stages + 1)
            emit_idx = t - (n_stages - 1)
            ys = jax.lax.cond(
                (sid == n_stages - 1) & (emit_idx >= 0),
                lambda ys: jax.lax.dynamic_update_index_in_dim(
                    ys, out, jnp.clip(emit_idx, 0, n_micro - 1), 0
                ),
                lambda ys: ys,
                ys,
            )
            # shift activations one stage right
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(out, axis, perm)
            return (buf, ys), ()

        ys0 = jnp.zeros_like(xs)
        (_, ys), _ = jax.lax.scan(
            tick, (buf, ys0), jnp.arange(n_ticks)
        )
        # results live on the last stage; broadcast to all stages
        ys = jax.lax.psum(
            jnp.where(sid == n_stages - 1, ys, jnp.zeros_like(ys)), axis
        )
        return ys

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = jax.shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
    )
    return fn(stage_params, x)
