"""Model configuration, logical-axis sharding rules, and parameter helpers.

Every parameter is created through :func:`Params.add`, which records a tuple
of *logical* axis names alongside the array.  ``ShardingRules`` maps logical
axes to mesh axes (Megatron TP over "model", DP/ZeRO over "data", pipeline
over "pod"), and :func:`logical_to_physical` produces the PartitionSpec used
by pjit.  Head counts / vocab / ff dims that don't divide the mesh axis are
*padded* (function-preserving, see DESIGN.md §5); both logical and padded
sizes live in the config so the roofline can report padding waste.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ----------------------------------------------------------------- configs
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    dispatch: str = "sort"     # sort (contiguity compaction) | cumsum (GShard)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    d_conv: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    block: str = "attn"                    # attn | ssm | hybrid
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: bool = False                   # whisper-style encoder-decoder
    n_encoder_layers: int = 0
    encoder_len: int = 1500                # whisper audio frames
    sliding_window: int = 0                # 0 = full attention
    global_layer_every: int = 0            # hymba: every k-th layer is global
    parallel_block: bool = False           # command-r: attn ∥ mlp
    qk_norm: bool = False                  # chameleon
    tie_embeddings: bool = False
    norm: str = "rms"                      # rms | ln
    rope_theta: float = 10000.0
    frontend: str = "none"                 # none | audio | vq
    max_seq_len: int = 8192
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"                    # full | dots | none
    # ---- physical padding (set by finalize()) ----
    pad_heads_to: int = 1
    pad_vocab_to: int = 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_heads_padded(self) -> int:
        return _round_up(self.n_heads, self.pad_heads_to)

    @property
    def n_kv_heads_padded(self) -> int:
        """KV heads padded to the TP degree (so the kv_heads axis shards).

        Both q and kv head counts are rounded up to pad_heads_to, giving an
        integer grouped-query ratio; padded q heads are output-masked, so
        capacity is preserved and the FLOP/byte overhead shows up honestly
        in the MODEL_FLOPS / HLO_FLOPs roofline ratio (DESIGN.md §5).
        """
        kv = _round_up(self.n_kv_heads, self.pad_heads_to)
        assert self.n_heads_padded % kv == 0, (
            f"padded heads {self.n_heads_padded} not divisible by "
            f"padded kv heads {kv}"
        )
        return kv

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab, self.pad_vocab_to)

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is O(1)/O(window) per token."""
        return self.block in ("ssm", "hybrid") or self.sliding_window > 0

    def n_params(self) -> int:
        """Logical (unpadded) parameter count for MODEL_FLOPS."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        hd = self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.moe:
            mlp = 3 * d * self.moe.d_ff_expert * (
                self.moe.n_experts + self.moe.n_shared_experts
            ) + d * self.moe.n_experts
        else:
            mlp = 3 * d * self.d_ff
        if self.block == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            attn = 0
            mlp = d * (2 * di + 2 * s.d_state + s.n_heads(d)) + di * d \
                + s.d_conv * (di + 2 * s.d_state)
        elif self.block == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            mlp += d * (2 * di + 2 * s.d_state + s.n_heads(d)) + di * d
        body = L * (attn + mlp + 2 * d)
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.encdec:
            enc_attn = 4 * d * hd * self.n_heads
            body += self.n_encoder_layers * (enc_attn + 3 * d * self.d_ff)
            body += L * (enc_attn + 2 * d)  # cross-attention
        return body + emb

    def active_params(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        dense = self.n_params() - L * 3 * d * self.moe.d_ff_expert * (
            self.moe.n_experts + self.moe.n_shared_experts
        )
        act = L * 3 * d * self.moe.d_ff_expert * (
            self.moe.top_k + self.moe.n_shared_experts
        )
        return dense + act


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def finalize(cfg: ModelConfig, model_axis_size: int) -> ModelConfig:
    """Pad head/vocab dims for a given tensor-parallel degree."""
    return dataclasses.replace(
        cfg,
        pad_heads_to=model_axis_size,
        pad_vocab_to=max(256, model_axis_size),
    )


# --------------------------------------------------------- sharding rules
@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axis (or None = replicated)."""

    rules: Tuple[Tuple[str, Any], ...] = (
        ("batch", ("pod", "data")),
        ("seq", None),              # sequence-parallel flips this to "model"
        ("embed", None),
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("head_dim", None),
        ("mlp", "model"),
        ("experts", "model"),
        ("expert_mlp", None),
        ("ssm_inner", "model"),
        ("ssm_state", None),
        ("ssm_heads", None),   # hymba: 50 heads do not divide TP=16; tiny arrays
        ("conv", None),
        ("layers", None),
        ("kv_seq", None),
        ("zero", "data"),           # ZeRO-1 optimizer-state sharding
    )

    def mesh_axis(self, logical: str):
        for k, v in self.rules:
            if k == logical:
                return v
        raise KeyError(f"unknown logical axis {logical!r}")

    def replace(self, **kw) -> "ShardingRules":
        rules = tuple((k, kw.get(k, v)) for k, v in self.rules)
        extra = set(kw) - {k for k, _ in self.rules}
        if extra:
            raise KeyError(f"unknown logical axes: {extra}")
        return ShardingRules(rules=rules)


def logical_to_physical(axes: Tuple[Optional[str], ...], rules: ShardingRules):
    spec = []
    for a in axes:
        m = rules.mesh_axis(a) if a is not None else None
        spec.append(m)
    return P(*spec)


# ------------------------------------------------------------- parameters
class Params:
    """Builds a params pytree and a parallel pytree of logical-axis tags."""

    def __init__(self, key: jax.Array, dtype: Any):
        self._key = key
        self.dtype = dtype
        self.values: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}

    def _split(self):
        self._key, k = jax.random.split(self._key)
        return k

    def add(
        self,
        name: str,
        shape: Tuple[int, ...],
        axes: Tuple[Optional[str], ...],
        init: str = "normal",
        scale: Optional[float] = None,
    ):
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "normal":
            s = scale if scale is not None else (shape[0] ** -0.5 if shape else 1.0)
            v = jax.random.normal(self._split(), shape, self.dtype) * s
        elif init == "zeros":
            v = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            v = jnp.ones(shape, self.dtype)
        else:
            raise ValueError(init)
        self.values[name] = v
        self.axes[name] = axes
        return v

    def scope(self, name: str) -> "ParamScope":
        return ParamScope(self, name)


class ParamScope:
    def __init__(self, params: Params, prefix: str):
        self._p = params
        self._prefix = prefix

    def add(self, name: str, *a, **kw):
        return self._p.add(f"{self._prefix}/{name}", *a, **kw)

    def scope(self, name: str) -> "ParamScope":
        return ParamScope(self._p, f"{self._prefix}/{name}")


def params_pspecs(axes_tree: Dict[str, Any], rules: ShardingRules):
    """Map the axes pytree to PartitionSpecs."""
    return {
        k: logical_to_physical(v, rules) for k, v in axes_tree.items()
    }


# -------------------------------------------------- activation constraints
import contextlib
import threading

_SHARDING_CTX = threading.local()


@contextlib.contextmanager
def sharding_ctx(mesh, rules: ShardingRules):
    """Install (mesh, rules) so model code can annotate activations."""
    prev = getattr(_SHARDING_CTX, "value", None)
    _SHARDING_CTX.value = (mesh, rules)
    try:
        yield
    finally:
        _SHARDING_CTX.value = prev


def constrain(x, *logical_axes):
    """with_sharding_constraint via logical axes; no-op outside sharding_ctx."""
    ctx = getattr(_SHARDING_CTX, "value", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_physical(logical_axes, rules)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )


# --------------------------------------------------- loop-unroll calibration
_UNROLL_CTX = threading.local()


@contextlib.contextmanager
def unroll_ctx(**factors: int):
    """Per-loop unroll factors, used by the dry-run's trip-count calibration
    (XLA's cost_analysis counts while-loop bodies once; the dry-run lowers
    each cell twice per loop — unroll=1 and unroll=2 — and differences the
    counts to recover true per-trip costs).  Loop names: layer, enc, chunk,
    kv_self, kv_cross, kv_enc, ssd."""
    prev = getattr(_UNROLL_CTX, "value", None)
    _UNROLL_CTX.value = dict(prev or {}, **factors)
    try:
        yield
    finally:
        _UNROLL_CTX.value = prev


def get_unroll(name: str) -> int:
    ctx = getattr(_UNROLL_CTX, "value", None) or {}
    return int(ctx.get(name, 1))
