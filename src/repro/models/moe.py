"""Mixture-of-Experts layer with *work-together* dispatch.

Token->expert routing is exactly the paper's scheduling problem: tokens are
tasks, the expert id is the task type, and efficient execution requires all
tasks of one type to run contiguously ("cores that perform the same task
types ... run on contiguous cores", §5.4).  The dispatch below is the same
machinery as the engine's fork allocation: a prefix-sum over per-expert
one-hots assigns each token its *contiguous* slot in its expert's buffer
(no atomics, deterministic), then one dense grouped GEMM per expert runs on
the MXU.  Capacity overflow drops tokens (standard GShard semantics) — the
residual connection carries them through.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamScope, constrain


def init_moe(s: ParamScope, cfg: ModelConfig, n_layers: Optional[int] = None):
    m = cfg.moe
    d, L = cfg.d_model, (cfg.n_layers if n_layers is None else n_layers)
    e, f = m.n_experts, m.d_ff_expert
    s.add("router", (L, d, e), ("layers", "embed", "experts"))
    s.add("w_gate", (L, e, d, f), ("layers", "experts", "embed", "expert_mlp"))
    s.add("w_up", (L, e, d, f), ("layers", "experts", "embed", "expert_mlp"))
    s.add("w_down", (L, e, f, d), ("layers", "experts", "expert_mlp", "embed"))
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        s.add("ws_gate", (L, d, fs), ("layers", "embed", "mlp"))
        s.add("ws_up", (L, d, fs), ("layers", "embed", "mlp"))
        s.add("ws_down", (L, fs, d), ("layers", "mlp", "embed"))


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * m.top_k * n_tokens / m.n_experts)
    return max(128, -(-c // 128) * 128)  # pad to a lane multiple


def _n_groups(T: int) -> int:
    """Dispatch groups = data-parallel shards (GShard grouping).

    Group-local dispatch keeps each group's expert buffer sharded over the
    data axes, so cross-shard traffic is the token all-to-all instead of a
    full buffer all-gather.  Outside a sharding context: one group.
    """
    from .common import _SHARDING_CTX

    ctx = getattr(_SHARDING_CTX, "value", None)
    if ctx is None:
        return 1
    mesh, rules = ctx
    g = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            g *= mesh.shape[ax]
    while g > 1 and T % g:
        g //= 2
    return max(g, 1)


def apply_moe(
    p: Dict[str, Any], prefix: str, cfg: ModelConfig, x: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    dt = cfg.compute_dtype
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = _capacity(cfg, T)
    xt = x.reshape(T, d)

    logits = (xt @ p[f"{prefix}/router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)             # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)     # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    flat_e = expert_idx.reshape(-1)                      # (T*K,) task types
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1, mode="drop")

    # load-balancing auxiliary loss (Switch/GShard)
    me = probs.mean(axis=0)                              # (E,)
    ce = counts.astype(jnp.float32) / (T * K)
    aux = E * jnp.sum(me * ce)

    # ---- group-local dispatch (GShard grouping): positions are computed
    # within each data-parallel group, so the expert buffers stay sharded
    # and the cross-shard traffic is the token all-to-all
    G = _n_groups(T)
    Tg = (T * K) // G
    Cg = max(128, -(-C // G // 128) * 128)
    eg = flat_e.reshape(G, Tg)
    if m.dispatch == "cumsum":
        # GShard-style one-hot exclusive scan — the paper-faithful
        # work-together prefix sum (engine fork allocation), per group.
        onehot = jax.nn.one_hot(eg, E, dtype=jnp.int32)   # (G, Tg, E)
        pos = jnp.cumsum(onehot, axis=1) - onehot
        my_pos = jnp.take_along_axis(pos, eg[..., None], axis=2)[..., 0]
        cnt_g = None
    else:
        # sort-based compaction: group same task types contiguously (the
        # paper's §5.4 contiguity principle), then rank within the group.
        # O(Tg log Tg) sort + an E-wide scan instead of a (Tg, E) scan.
        order = jnp.argsort(eg, axis=1, stable=True)      # (G, Tg)
        e_sorted = jnp.take_along_axis(eg, order, axis=1)
        cnt_g = jax.vmap(
            lambda e: jnp.zeros((E,), jnp.int32).at[e].add(1, mode="drop")
        )(eg)
        starts = jnp.cumsum(cnt_g, axis=1) - cnt_g        # (G, E)
        pos_sorted = (
            jnp.arange(Tg, dtype=jnp.int32)[None]
            - jnp.take_along_axis(starts, e_sorted, axis=1)
        )
        my_pos = jnp.zeros((G, Tg), jnp.int32)
        my_pos = jax.vmap(lambda mp, o, ps: mp.at[o].set(ps))(
            my_pos, order, pos_sorted
        )
    keep = my_pos < Cg
    slot = jnp.where(keep, eg * Cg + my_pos, E * Cg)      # E*Cg = dropped

    xrep = jnp.repeat(xt, K, axis=0).reshape(G, Tg, d)
    buf = jax.vmap(
        lambda s, xg: jnp.zeros((E * Cg, d), dt).at[s].set(xg, mode="drop")
    )(slot, xrep)
    buf = constrain(
        buf.reshape(G, E, Cg, d), "batch", "experts", None, "embed"
    )

    # ---- per-expert SwiGLU (grouped GEMMs; experts sharded over "model",
    # groups over the data axes — the g<->e reshard is the all-to-all)
    wg = p[f"{prefix}/w_gate"].astype(dt)
    wu = p[f"{prefix}/w_up"].astype(dt)
    wd = p[f"{prefix}/w_down"].astype(dt)
    g = jnp.einsum("gecd,edf->gecf", buf, wg)
    u = jnp.einsum("gecd,edf->gecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    yb = jnp.einsum("gecf,efd->gecd", h, wd)
    yb = constrain(yb, "batch", "experts", None, "embed")
    yb = yb.reshape(G, E * Cg, d)

    # ---- combine: gather back, weight by gate, sum over K ----------------
    gathered = jax.vmap(
        lambda y_, s, kp: jnp.where(
            kp[:, None], y_[jnp.clip(s, 0, E * Cg - 1)], 0.0
        )
    )(yb, slot, keep)
    y = (
        gathered.reshape(T, K, d)
        * gate_vals.astype(dt)[..., None]
    ).sum(axis=1)

    if m.n_shared_experts:
        gs = xt @ p[f"{prefix}/ws_gate"].astype(dt)
        us = xt @ p[f"{prefix}/ws_up"].astype(dt)
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(dt) * us
        y = y + hs @ p[f"{prefix}/ws_down"].astype(dt)

    return y.reshape(B, S, d), aux
