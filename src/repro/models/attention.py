"""GQA attention block: train path (flash / ref dispatch), decode path
(ragged KV-cache update + decode kernel), sliding-window and QK-norm options,
head padding for tensor-parallel divisibility (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import ModelConfig, ParamScope
from .layers import rope


def init_attn(
    s: ParamScope,
    cfg: ModelConfig,
    n_layers: Optional[int] = None,
    cross: bool = False,
):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads_padded, cfg.n_kv_heads_padded
    L = cfg.n_layers if n_layers is None else n_layers
    s.add("wq", (L, d, hq * hd), ("layers", "embed", "heads"))
    s.add("wk", (L, d, hkv * hd), ("layers", "embed", "kv_heads"))
    s.add("wv", (L, d, hkv * hd), ("layers", "embed", "kv_heads"))
    s.add("wo", (L, hq * hd, d), ("layers", "heads", "embed"))
    if cfg.qk_norm:
        s.add("q_scale", (L, hd), ("layers", "head_dim"), init="ones")
        s.add("k_scale", (L, hd), ("layers", "head_dim"), init="ones")
    del cross  # same parameter structure; K/V source differs at apply time


def _qk_norm(x, scale):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _head_mask(cfg: ModelConfig, x):
    """Zero padded q heads so head padding is function-preserving."""
    hq = cfg.n_heads_padded
    if hq == cfg.n_heads:
        return x
    mask = (jnp.arange(hq) < cfg.n_heads).astype(x.dtype)
    return x * mask[..., None]


def _project_qkv(p, prefix, cfg, xq, xkv, positions_q, positions_kv, use_rope):
    dt = cfg.compute_dtype
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads_padded, cfg.n_kv_heads_padded
    q = (xq @ p[f"{prefix}/wq"].astype(dt)).reshape(*xq.shape[:-1], hq, hd)
    k = (xkv @ p[f"{prefix}/wk"].astype(dt)).reshape(*xkv.shape[:-1], hkv, hd)
    v = (xkv @ p[f"{prefix}/wv"].astype(dt)).reshape(*xkv.shape[:-1], hkv, hd)
    if cfg.qk_norm:
        q = _qk_norm(q, p[f"{prefix}/q_scale"])
        k = _qk_norm(k, p[f"{prefix}/k_scale"])
    if use_rope:
        q = rope(q, positions_q, cfg.rope_theta)
        k = rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


def apply_attn(
    p: Dict[str, Any],
    prefix: str,
    cfg: ModelConfig,
    x: jnp.ndarray,                 # (B, S, d)
    causal: bool = True,
    window: int = 0,
    use_rope: bool = True,
    kv_source: Optional[jnp.ndarray] = None,  # cross-attn context (B, Skv, d)
    return_kv: bool = False,
    site: str = "kv_self",
):
    """Training / prefill attention.  With ``return_kv`` also returns the
    rotary-applied (k, v) in cache layout (B, Hkv, S, hd)."""
    B, S, _ = x.shape
    xkv = x if kv_source is None else kv_source
    Skv = xkv.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos_kv = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    q, k, v = _project_qkv(p, prefix, cfg, x, xkv, pos_q, pos_kv, use_rope)
    q = q.transpose(0, 2, 1, 3)  # (B, H, S, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    out = ops.attention(q, k, v, causal=causal, window=window, site=site)
    out = _head_mask(cfg, out.transpose(0, 2, 1, 3))  # (B, S, H, hd)
    out = out.reshape(B, S, -1)
    proj = out @ p[f"{prefix}/wo"].astype(cfg.compute_dtype)
    if return_kv:
        return proj, (k, v)
    return proj


def apply_attn_decode(
    p: Dict[str, Any],
    prefix: str,
    cfg: ModelConfig,
    x: jnp.ndarray,                  # (B, 1, d) new token activations
    cache_k: jnp.ndarray,            # (B, Hkv, S, hd)
    cache_v: jnp.ndarray,
    lengths: jnp.ndarray,            # (B,) tokens already in cache
    window: int = 0,
    use_rope: bool = True,
    cross: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode.  Returns (out (B,1,d), new_cache_k, new_cache_v).

    For cross-attention (``cross=True``) the cache holds precomputed encoder
    K/V and is not updated; ``lengths`` is the encoder length.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = lengths[:, None]  # (B, 1) absolute position of the new token
    if cross:
        q, _, _ = _project_qkv(p, prefix, cfg, x, x, pos, pos, use_rope=False)
        new_k, new_v = cache_k, cache_v
        att_len = lengths
    else:
        q, k, v = _project_qkv(p, prefix, cfg, x, x, pos, pos, use_rope)
        bidx = jnp.arange(B)
        new_k = cache_k.at[bidx, :, lengths].set(k[:, 0].astype(cache_k.dtype))
        new_v = cache_v.at[bidx, :, lengths].set(v[:, 0].astype(cache_v.dtype))
        att_len = lengths + 1
    out = ops.gqa_decode(q[:, 0], new_k, new_v, att_len, window=window)
    out = _head_mask(cfg, out)
    out = out.reshape(B, 1, -1)
    return out @ p[f"{prefix}/wo"].astype(cfg.compute_dtype), new_k, new_v
