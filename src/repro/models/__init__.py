# Unified model stack: GQA / SSM / hybrid / enc-dec / MoE transformer
# definitions with logical-axis sharding and scanned layer stacks.
from .common import (  # noqa: F401
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShardingRules,
    finalize,
    logical_to_physical,
    sharding_ctx,
)
from .model import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
)
