"""Model assembly: unified decoder-only / encoder-decoder transformer with
attn | ssm | hybrid blocks, dense or MoE FFN, scanned layer stacks, chunked
cross-entropy, and a single-token decode step over ragged caches.

The layer stack is a ``jax.lax.scan`` over stacked per-layer parameters —
keeps the HLO size O(1) in depth (95-layer deepseek-67b compiles in the same
graph size as 24-layer granite-moe) and gives remat a natural boundary.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import apply_attn, apply_attn_decode, init_attn
from .common import ModelConfig, Params, constrain, get_unroll
from .layers import (
    apply_mlp,
    apply_norm,
    embed_tokens,
    init_embeddings,
    init_mlp,
    init_norm,
    logits_fn,
)
from .moe import apply_moe, init_moe
from .ssm import apply_ssm, apply_ssm_decode, init_ssm, init_ssm_cache


# ------------------------------------------------------------------- init
def init_model(cfg: ModelConfig, key: jax.Array):
    """Returns (params: dict[name -> array], axes: dict[name -> tuple])."""
    pb = Params(key, cfg.param_dtype)
    init_embeddings(pb.scope("embed"), cfg)
    lyr = pb.scope("layers")
    if cfg.block in ("attn", "hybrid"):
        init_attn(lyr.scope("attn"), cfg)
    if cfg.block in ("ssm", "hybrid"):
        init_ssm(lyr.scope("ssm"), cfg)
    init_norm(lyr.scope("norm1"), cfg)
    has_ffn = cfg.moe is not None or (cfg.d_ff > 0 and cfg.block != "ssm")
    if has_ffn and not cfg.parallel_block:
        init_norm(lyr.scope("norm2"), cfg)
    if cfg.moe is not None:
        init_moe(lyr.scope("moe"), cfg)
    elif has_ffn:
        init_mlp(lyr.scope("mlp"), cfg)
    if cfg.encdec:
        enc = pb.scope("encoder")
        init_attn(enc.scope("attn"), cfg, n_layers=cfg.n_encoder_layers)
        init_mlp(enc.scope("mlp"), cfg)
        # encoder norms need their own layer count
        Lc = dataclasses.replace(cfg, n_layers=cfg.n_encoder_layers)
        init_norm(enc.scope("norm1"), Lc)
        init_norm(enc.scope("norm2"), Lc)
        init_norm(pb.scope("enc_final_norm"), cfg, layered=False)
        pb.add(
            "enc_pos_embed", (cfg.encoder_len, cfg.d_model),
            ("kv_seq", "embed"), scale=0.02,
        )
        init_attn(lyr.scope("cross"), cfg)
        init_norm(lyr.scope("norm_cross"), cfg)
    init_norm(pb.scope("final_norm"), cfg, layered=False)
    return pb.values, pb.axes


def _layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (0 = full/global)."""
    L = cfg.n_layers
    if cfg.sliding_window <= 0:
        return jnp.zeros((L,), jnp.int32)
    win = jnp.full((L,), cfg.sliding_window, jnp.int32)
    if cfg.global_layer_every > 0:
        is_global = (jnp.arange(L) % cfg.global_layer_every) == 0
        win = jnp.where(is_global, 0, win)
    return win


def _split_layer_params(params: Dict[str, Any], prefix: str = "layers/"):
    stacked = {
        k[len(prefix):]: v for k, v in params.items() if k.startswith(prefix)
    }
    rest = {k: v for k, v in params.items() if not k.startswith(prefix)}
    return stacked, rest


# ---------------------------------------------------------------- forward
def _decoder_layer(
    cfg: ModelConfig,
    p: Dict[str, Any],      # per-layer slice
    x: jnp.ndarray,         # (B, S, d)
    window: jnp.ndarray,    # scalar i32, 0 = full
    enc_out: Optional[jnp.ndarray],
    collect_kv: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (x', aux_loss, kv) — kv nonempty only when collect_kv."""
    aux = jnp.float32(0.0)
    kv: Dict[str, jnp.ndarray] = {}
    h = apply_norm(p, "norm1", cfg, x)
    mix = jnp.zeros_like(x)
    if cfg.block in ("attn", "hybrid"):
        if collect_kv:
            a, (k_, v_) = apply_attn(
                p, "attn", cfg, h, causal=True, window=window, return_kv=True
            )
            kv["k"], kv["v"] = k_, v_
        else:
            a = apply_attn(p, "attn", cfg, h, causal=True, window=window)
        mix = mix + a
    if cfg.block in ("ssm", "hybrid"):
        if collect_kv:
            y_, st_, tail_ = apply_ssm(p, "ssm", cfg, h, return_state=True)
            kv["ssm_state"], kv["ssm_conv"] = st_, tail_
        else:
            y_ = apply_ssm(p, "ssm", cfg, h)
        mix = mix + y_
    if cfg.block == "hybrid":
        mix = 0.5 * mix
    if cfg.parallel_block and cfg.moe is None and cfg.d_ff > 0:
        mix = mix + apply_mlp(p, "mlp", cfg, h)  # attn ∥ mlp, shared norm
        x = x + mix
        return x, aux, kv
    x = x + mix
    x = constrain(x, "batch", "seq", "embed")
    if cfg.encdec and enc_out is not None:
        hc = apply_norm(p, "norm_cross", cfg, x)
        x = x + apply_attn(
            p, "cross", cfg, hc, causal=False, use_rope=False,
            kv_source=enc_out, site="kv_cross",
        )
    if cfg.moe is not None:
        h2 = apply_norm(p, "norm2", cfg, x)
        y, aux = apply_moe(p, "moe", cfg, h2)
        x = x + y
    elif cfg.d_ff > 0 and cfg.block != "ssm":
        h2 = apply_norm(p, "norm2", cfg, x)
        x = x + apply_mlp(p, "mlp", cfg, h2)
    x = constrain(x, "batch", "seq", "embed")
    return x, aux, kv


def encode(params: Dict[str, Any], cfg: ModelConfig, frames: jnp.ndarray):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend): frames (B, T_enc, d) -> (B, T_enc, d)."""
    x = frames.astype(cfg.compute_dtype)
    x = x + params["enc_pos_embed"].astype(cfg.compute_dtype)[None]
    stacked, _ = _split_layer_params(params, "encoder/")

    def body(h, pl):
        a = apply_norm(pl, "norm1", cfg, h)
        h = h + apply_attn(
            pl, "attn", cfg, a, causal=False, use_rope=False, site="kv_enc"
        )
        m = apply_norm(pl, "norm2", cfg, h)
        h = h + apply_mlp(pl, "mlp", cfg, m)
        return h, ()

    x, _ = jax.lax.scan(body, x, stacked, unroll=get_unroll("enc"))
    return apply_norm(params, "enc_final_norm", cfg, x)


def forward(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jnp.ndarray,                      # (B, S) i32
    enc_frames: Optional[jnp.ndarray] = None,  # (B, T_enc, d) for enc-dec
    remat: bool = True,
    collect_kv: bool = False,
):
    """Token ids -> final hidden states (B, S, d), plus summed MoE aux loss.
    With ``collect_kv``, also returns the stacked per-layer cache entries
    (dict of (L, ...) arrays) for prefill->decode handoff."""
    x = embed_tokens(params, cfg, tokens)
    x = constrain(x, "batch", "seq", "embed")
    enc_out = None
    if cfg.encdec:
        assert enc_frames is not None, "enc-dec model needs encoder frames"
        enc_out = encode(params, cfg, enc_frames)
    stacked, _ = _split_layer_params(params)
    wins = _layer_windows(cfg)

    def body(h, xs):
        pl, win = xs
        h, aux, kv = _decoder_layer(cfg, pl, h, win, enc_out, collect_kv)
        return h, (aux, kv)

    if remat and not collect_kv and cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat == "full"
            else jax.checkpoint_policies.checkpoint_dots
        )
        body = jax.checkpoint(body, policy=policy)
    x, (auxs, kvs) = jax.lax.scan(
        body, x, (stacked, wins), unroll=get_unroll("layer")
    )
    x = apply_norm(params, "final_norm", cfg, x)
    if collect_kv:
        return x, auxs.sum(), kvs
    return x, auxs.sum()


# ------------------------------------------------------------------- loss
def chunked_xent(
    params: Dict[str, Any],
    cfg: ModelConfig,
    hidden: jnp.ndarray,   # (B, S, d)
    labels: jnp.ndarray,   # (B, S) i32, -1 = ignore
    chunk: int = 512,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy without materializing (B, S, V) logits: scan over
    sequence chunks (peak activation = B x chunk x V)."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    hp = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = hp.shape[1] // chunk
    hp = hp.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lp = lp.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        h, y = xs
        logits = logits_fn(params, cfg, h)           # (B, c, Vp) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        yc = jnp.clip(y, 0, cfg.vocab_padded - 1)
        picked = jnp.take_along_axis(
            logits, yc[..., None], axis=-1
        )[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        loss = ((lse - picked) * valid).sum()
        return (acc[0] + loss, acc[1] + valid.sum()), ()

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hp, lp),
        unroll=get_unroll("chunk"),
    )
    return tot / jnp.maximum(cnt, 1.0), cnt


def prefill(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jnp.ndarray,                       # (B, S) i32
    max_len: Optional[int] = None,
    enc_frames: Optional[jnp.ndarray] = None,
    last_positions: Optional[jnp.ndarray] = None,  # (B,) for ragged prompts
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Serving prefill: run the full prompt, return (last-token logits,
    decode cache).  This is what the ``prefill_32k`` dry-run cells lower.

    Ragged right-padded prompts: pass ``last_positions`` (= prompt_len - 1)
    and set the returned cache's ``lengths`` to the true prompt lengths —
    pad rows beyond a request's length are never read back (decode masks by
    length), so right padding is harmless."""
    B, S = tokens.shape
    max_len = max_len or S
    x, _, kvs = forward(
        params, cfg, tokens, enc_frames=enc_frames, remat=False,
        collect_kv=True,
    )
    cache: Dict[str, jnp.ndarray] = {
        "lengths": jnp.full((B,), S, jnp.int32)
    }
    if "k" in kvs:
        pad = max_len - S
        cache["k"] = jnp.pad(kvs["k"], ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        cache["v"] = jnp.pad(kvs["v"], ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    if "ssm_state" in kvs:
        cache["ssm_state"] = kvs["ssm_state"]
        cache["ssm_conv"] = kvs["ssm_conv"]
    if cfg.encdec:
        assert enc_frames is not None
        cache["enc_out"] = encode(params, cfg, enc_frames)
        cache["cross_k"], cache["cross_v"] = build_cross_cache(
            params, cfg, cache["enc_out"]
        )
    if last_positions is None:
        last = x[:, -1]
    else:
        last = x[jnp.arange(B), last_positions]
        cache["lengths"] = last_positions.astype(jnp.int32) + 1
    logits = logits_fn(params, cfg, last)
    return logits, cache


def loss_fn(
    params: Dict[str, Any],
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    aux_weight: float = 0.01,
):
    hidden, aux = forward(
        params, cfg, batch["tokens"], enc_frames=batch.get("enc_frames")
    )
    loss, n_tok = chunked_xent(params, cfg, hidden, batch["labels"])
    total = loss + aux_weight * aux
    return total, dict(xent=loss, aux=aux, n_tokens=n_tok)


# ----------------------------------------------------------------- decode
def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=None
) -> Dict[str, jnp.ndarray]:
    """Ragged decode cache for all layers (attention KV and/or SSM state)."""
    dtype = dtype or cfg.compute_dtype
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    cache: Dict[str, jnp.ndarray] = {
        "lengths": jnp.zeros((batch,), jnp.int32)
    }
    if cfg.block in ("attn", "hybrid"):
        kv_len = max_len if cfg.sliding_window <= 0 else max_len
        cache["k"] = jnp.zeros(
            (L, batch, cfg.n_kv_heads_padded, kv_len, hd), dtype
        )
        cache["v"] = jnp.zeros_like(cache["k"])
    if cfg.block in ("ssm", "hybrid"):
        s = init_ssm_cache(cfg, batch, dtype)
        cache["ssm_conv"] = jnp.broadcast_to(
            s["conv"][None], (L,) + s["conv"].shape
        )
        cache["ssm_state"] = jnp.broadcast_to(
            s["state"][None], (L,) + s["state"].shape
        )
    if cfg.encdec:
        cache["enc_out"] = jnp.zeros((batch, cfg.encoder_len, cfg.d_model), dtype)
        # cross-attention K/V precomputed once per request (pure projections
        # of enc_out) instead of recomputed every decode step
        cache["cross_k"] = jnp.zeros(
            (L, batch, cfg.n_kv_heads_padded, cfg.encoder_len, hd), dtype
        )
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def build_cross_cache(params: Dict[str, Any], cfg: ModelConfig, enc_out):
    """Per-layer cross-attn K/V from encoder output: (L, B, Hkv, T_enc, hd)."""
    stacked, _ = _split_layer_params(params)
    hd = cfg.resolved_head_dim
    hkv = cfg.n_kv_heads_padded
    dt = cfg.compute_dtype

    def body(_, pl):
        k = (enc_out @ pl["cross/wk"].astype(dt)).reshape(
            enc_out.shape[0], enc_out.shape[1], hkv, hd
        )
        v = (enc_out @ pl["cross/wv"].astype(dt)).reshape(
            enc_out.shape[0], enc_out.shape[1], hkv, hd
        )
        return (), (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    _, (ks, vs) = jax.lax.scan(body, (), stacked)
    return ks, vs


def decode_step(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jnp.ndarray,              # (B, 1) i32 newest token
    cache: Dict[str, jnp.ndarray],
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step for the whole batch; returns (logits (B, Vp), cache')."""
    x = embed_tokens(params, cfg, tokens)
    x = constrain(x, "batch", None, "embed")
    stacked, _ = _split_layer_params(params)
    wins = _layer_windows(cfg)
    lengths = cache["lengths"]
    enc_out = cache.get("enc_out")

    def body(h, xs):
        pl, win, kv = xs
        aux_out = {}
        mix = jnp.zeros_like(h)
        hn = apply_norm(pl, "norm1", cfg, h)
        if cfg.block in ("attn", "hybrid"):
            a, nk, nv = apply_attn_decode(
                pl, "attn", cfg, hn, kv["k"], kv["v"], lengths, window=win
            )
            mix = mix + a
            aux_out["k"], aux_out["v"] = nk, nv
        if cfg.block in ("ssm", "hybrid"):
            sc = dict(conv=kv["ssm_conv"], state=kv["ssm_state"])
            sy, nc_ = apply_ssm_decode(pl, "ssm", cfg, hn, sc)
            mix = mix + sy
            aux_out["ssm_conv"], aux_out["ssm_state"] = nc_["conv"], nc_["state"]
        if cfg.block == "hybrid":
            mix = 0.5 * mix
        if cfg.parallel_block and cfg.moe is None and cfg.d_ff > 0:
            mix = mix + apply_mlp(pl, "mlp", cfg, hn)
            return h + mix, aux_out
        h = h + mix
        if cfg.encdec and enc_out is not None:
            hc = apply_norm(pl, "norm_cross", cfg, h)
            # cached cross K/V: pure gather + decode attention, no per-token
            # projection of the 1500-frame encoder output
            enc_lens = jnp.full((h.shape[0],), cfg.encoder_len, jnp.int32)
            c, _, _ = apply_attn_decode(
                pl, "cross", cfg, hc, kv["cross_k"], kv["cross_v"],
                enc_lens, use_rope=False, cross=True,
            )
            h = h + c
        if cfg.moe is not None:
            h2 = apply_norm(pl, "norm2", cfg, h)
            y, _ = apply_moe(pl, "moe", cfg, h2)
            h = h + y
        elif cfg.d_ff > 0 and cfg.block != "ssm":
            h2 = apply_norm(pl, "norm2", cfg, h)
            h = h + apply_mlp(pl, "mlp", cfg, h2)
        return h, aux_out

    kv_slices = {}
    for name in ("k", "v", "ssm_conv", "ssm_state", "cross_k", "cross_v"):
        if name in cache:
            kv_slices[name] = cache[name]
    x, new_kv = jax.lax.scan(
        body, x, (stacked, wins, kv_slices), unroll=get_unroll("layer")
    )
    x = apply_norm(params, "final_norm", cfg, x)
    logits = logits_fn(params, cfg, x[:, 0])
    new_cache = dict(cache)
    for name, v in new_kv.items():
        new_cache[name] = v
    new_cache["lengths"] = lengths + 1
    return logits, new_cache
