"""Mamba-2 (SSD) block: fused zxbcdt projection, short causal conv, SSD scan
(Pallas kernel on TPU / oracle elsewhere), gated output projection.

Decode keeps O(1) state per sequence: a (d_conv-1)-deep conv window and the
(H, P, N) SSM state — this is why the ``long_500k`` cell runs for SSM/hybrid
archs while full-attention archs skip it.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import ModelConfig, ParamScope


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    return s, di, nh, s.d_state, s.headdim, s.d_conv


def init_ssm(s_: ParamScope, cfg: ModelConfig, n_layers: Optional[int] = None):
    s, di, nh, N, P, K = _dims(cfg)
    d = cfg.d_model
    L = cfg.n_layers if n_layers is None else n_layers
    # fused input projection: [z (gate), x, B, C, dt]
    s_.add("w_in_zx", (L, d, 2 * di), ("layers", "embed", "ssm_inner"))
    s_.add("w_in_bc", (L, d, 2 * N), ("layers", "embed", "ssm_state"))
    s_.add("w_in_dt", (L, d, nh), ("layers", "embed", "ssm_heads"))
    s_.add("conv_w", (L, K, di + 2 * N), ("layers", "conv", "ssm_inner"))
    s_.add("a_log", (L, nh), ("layers", "ssm_heads"), init="zeros")
    s_.add("dt_bias", (L, nh), ("layers", "ssm_heads"), init="zeros")
    s_.add("d_skip", (L, nh), ("layers", "ssm_heads"), init="ones")
    s_.add("w_out", (L, di, d), ("layers", "ssm_inner", "embed"))


def _split_proj(p, prefix, cfg, u):
    """u (B, S, d) -> z, x, B, C, dt (pre-conv, pre-activation)."""
    dt_ = cfg.compute_dtype
    s, di, nh, N, P, K = _dims(cfg)
    zx = u @ p[f"{prefix}/w_in_zx"].astype(dt_)
    bc = u @ p[f"{prefix}/w_in_bc"].astype(dt_)
    dt_raw = u @ p[f"{prefix}/w_in_dt"].astype(dt_)
    z, x = zx[..., :di], zx[..., di:]
    return z, x, bc, dt_raw


def _conv_scan_inputs(x, bc):
    """Concat the conv-filtered channels: (B, S, di + 2N)."""
    return jnp.concatenate([x, bc], axis=-1)


def apply_ssm(
    p: Dict[str, Any], prefix: str, cfg: ModelConfig, u: jnp.ndarray,
    return_state: bool = False,
):
    """Training / prefill path.  u: (B, S, d) -> (B, S, d).
    With ``return_state`` also returns (ssm_state (B,nh,P,N),
    conv_tail (B, K-1, di+2N)) for cache handoff to decode."""
    s, di, nh, N, P, K = _dims(cfg)
    dt_ = cfg.compute_dtype
    B_, S, _ = u.shape
    z, x, bc, dt_raw = _split_proj(p, prefix, cfg, u)

    # depthwise causal conv over [x, B, C]
    xbc = _conv_scan_inputs(x, bc)
    w = p[f"{prefix}/conv_w"].astype(dt_)  # (K, di+2N)
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + S, :] * w[i][None, None, :] for i in range(K)
    )
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(dt_)
    xc, bcc = conv[..., :di], conv[..., di:]
    Bm, Cm = bcc[..., :N], bcc[..., N:]

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p[f"{prefix}/dt_bias"].astype(jnp.float32)
    )  # (B, S, nh)
    A = -jnp.exp(p[f"{prefix}/a_log"].astype(jnp.float32))  # (nh,)
    xh = xc.reshape(B_, S, nh, P)

    def one_seq(xs, dts, bs, cs):
        return ops.ssd(xs, dts, A, bs, cs)

    y, hfinal = jax.vmap(one_seq)(xh, dt.astype(dt_), Bm, Cm)  # (B,S,nh,P)
    y = y + xh * p[f"{prefix}/d_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(B_, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    out = y @ p[f"{prefix}/w_out"].astype(dt_)
    if return_state:
        K_ = K - 1
        tail = jnp.pad(xbc, ((0, 0), (K_, 0), (0, 0)))[:, S : S + K_]
        return out, hfinal, tail
    return out


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    s, di, nh, N, P, K = _dims(cfg)
    return dict(
        conv=jnp.zeros((batch, K - 1, di + 2 * N), dtype),
        state=jnp.zeros((batch, nh, P, N), jnp.float32),
    )


def apply_ssm_decode(
    p: Dict[str, Any],
    prefix: str,
    cfg: ModelConfig,
    u: jnp.ndarray,           # (B, 1, d)
    cache: Dict[str, jnp.ndarray],
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode: O(1) state update (the SSD recurrence directly)."""
    s, di, nh, N, P, K = _dims(cfg)
    dt_ = cfg.compute_dtype
    B_ = u.shape[0]
    z, x, bc, dt_raw = _split_proj(p, prefix, cfg, u)

    xbc = _conv_scan_inputs(x, bc)[:, 0]  # (B, di+2N)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,.)
    w = p[f"{prefix}/conv_w"].astype(dt_)  # (K, di+2N)
    conv = (hist * w[None]).sum(axis=1)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(dt_)
    xc, bcc = conv[..., :di], conv[..., di:]
    Bm, Cm = bcc[..., :N], bcc[..., N:]

    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32)
        + p[f"{prefix}/dt_bias"].astype(jnp.float32)
    )  # (B, nh)
    A = -jnp.exp(p[f"{prefix}/a_log"].astype(jnp.float32))
    xh = xc.reshape(B_, nh, P)
    decay = jnp.exp(A[None] * dt)[..., None, None]          # (B,nh,1,1)
    upd = (dt[..., None] * xh)[..., None] * Bm[:, None, None, :]
    state = decay * cache["state"] + upd                     # (B,nh,P,N)
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y.astype(dt_) + xh * p[f"{prefix}/d_skip"].astype(dt_)[None, :, None]
    y = y.reshape(B_, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    out = y @ p[f"{prefix}/w_out"].astype(dt_)
    return out, dict(conv=hist[:, 1:], state=state)
