"""Primitive layers: norms, rotary embedding, SwiGLU MLP, embeddings.

All parameters are created through ``ParamScope.add`` with logical axis tags
(see common.py); apply functions are pure and take the param dict slice.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamScope


# ------------------------------------------------------------------ norms
def init_norm(s: ParamScope, cfg: ModelConfig, layered: bool = True):
    lead = (cfg.n_layers,) if layered else ()
    lax = ("layers",) if layered else ()
    s.add("scale", lead + (cfg.d_model,), lax + ("embed",), init="ones")
    if cfg.norm == "ln":
        s.add("bias", lead + (cfg.d_model,), lax + ("embed",), init="zeros")


def apply_norm(p: Dict[str, Any], prefix: str, cfg: ModelConfig, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    y = y * p[f"{prefix}/scale"].astype(jnp.float32)
    if cfg.norm == "ln":
        y = y + p[f"{prefix}/bias"].astype(jnp.float32)
    return y.astype(cfg.compute_dtype)


# ----------------------------------------------------------------- rotary
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ------------------------------------------------------------------- MLP
def init_mlp(s: ParamScope, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, L = cfg.d_model, cfg.n_layers
    f = d_ff or cfg.d_ff
    s.add("w_gate", (L, d, f), ("layers", "embed", "mlp"))
    s.add("w_up", (L, d, f), ("layers", "embed", "mlp"))
    s.add("w_down", (L, f, d), ("layers", "mlp", "embed"))


def apply_mlp(p: Dict[str, Any], prefix: str, cfg: ModelConfig, x):
    dt = cfg.compute_dtype
    g = x @ p[f"{prefix}/w_gate"].astype(dt)
    u = x @ p[f"{prefix}/w_up"].astype(dt)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return h @ p[f"{prefix}/w_down"].astype(dt)


# ------------------------------------------------------------- embeddings
def init_embeddings(s: ParamScope, cfg: ModelConfig):
    vp, d = cfg.vocab_padded, cfg.d_model
    s.add("tok_embed", (vp, d), ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        s.add("unembed", (d, vp), ("embed", "vocab"))


def embed_tokens(p: Dict[str, Any], cfg: ModelConfig, tokens):
    emb = p["embed/tok_embed"]
    return emb[tokens].astype(cfg.compute_dtype)


def logits_fn(p: Dict[str, Any], cfg: ModelConfig, x):
    """x (..., d) -> logits (..., vocab_padded); padded entries masked."""
    if cfg.tie_embeddings:
        w = p["embed/tok_embed"].astype(cfg.compute_dtype).T
    else:
        w = p["embed/unembed"].astype(cfg.compute_dtype)
    logits = (x @ w).astype(jnp.float32)
    vp, v = cfg.vocab_padded, cfg.vocab
    if vp != v:
        mask = jnp.arange(vp) < v
        logits = jnp.where(mask, logits, -1e30)
    return logits
