"""Self-tuning controllers: telemetry in, dispatch/chunk decisions out.

This module closes the runtime's first feedback loop (ROADMAP
"self-tuning runtime", DESIGN.md §14): the §13 telemetry substrate
measures hole fraction, lane utilization and queue heat, and nothing
consumed them until now.  Two independent controllers turn those series
into online decisions:

* :class:`DispatchController` — per fused epoch, pick ``masked`` /
  ``compacted`` / ``gather`` from the observed frontier fill (rolling
  window of ``active / full_span`` readbacks) priced against a
  :class:`CostModel`.  All three modes are bit-identical by construction
  (DESIGN.md §5.4/§11), so the choice only moves *overhead*, never
  results — which is what makes an online controller safe to ship
  inside the epoch loop.
* :class:`ChunkController` — between resident chunks, adapt the epoch
  bound K: widen while no completions surface (each readback that finds
  nothing finished was a wasted device->host sync), shrink when the job
  queue runs hot (``trees_job_queue_wait_seconds`` — a long K starves
  admission at the next boundary).  ``run_chunk``'s epoch bound is a
  dynamic argument of one compiled template per (regions, capacity,
  depth), so K adaptation re-enters the cached template and can never
  retrace.  It also folds in deadline slack from the admission layer
  (DESIGN.md §16): a tightening nearest deadline shrinks K so the
  boundaries — the only preemption yield points — come sooner.
* :class:`PlacementController` — per submitted job on a sharded fleet,
  pick ``round_robin`` / ``least_loaded`` / ``sticky`` from the observed
  workload mix (structural-type diversity vs per-shard imbalance),
  closing the ROADMAP note that placement policy was still static.

The :class:`CostModel` defaults to the roofline constants in
``benchmarks/roofline.py`` (V_inf critical-path prices); a one-shot
:meth:`CostModel.calibrated` micro-probe measures this host's actual
dispatch round-trip and per-lane slope instead, cached process-wide (and
optionally on disk) so steady state never pays probing.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

from ..obs.metrics import RollingWindow

# Roofline constants are the calibration fallback: pulled from
# benchmarks/roofline.py when importable (the benchmarks/ directory is not
# a package on sys.path in library use), else the same literals.
_DISPATCH_LATENCY_S = 40e-6
_TRANSFER_LATENCY_S = 15e-6
try:  # pragma: no cover - import path depends on caller's sys.path
    from benchmarks.roofline import DISPATCH_LATENCY_S as _DISPATCH_LATENCY_S
    from benchmarks.roofline import TRANSFER_LATENCY_S as _TRANSFER_LATENCY_S
except Exception:
    pass

AUTO_MODES = ("masked", "compacted", "gather")
RESIDENT_AUTO_MODES = ("masked", "gather")


def _bucket(n: int, minimum: int = 8) -> int:
    """Power-of-2 launch rounding (mirror of scheduler.launch_bucket,
    kept dependency-free so the cost model imports nothing heavy)."""
    if n <= minimum:
        return minimum
    return 1 << (int(n) - 1).bit_length()


# process-wide calibration cache: one probe per backend per process
_CALIBRATION_CACHE: Dict[str, "CostModel"] = {}


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-epoch critical-path price of each dispatch mode (seconds).

    ``dispatch_s``/``transfer_s`` are the V_inf launch/readback latencies
    (roofline defaults); ``lane_s`` the marginal phase-2/3 cost of one
    launched task lane; ``pack_lane_s`` the per-lane cost of the rank/
    scan pack pass; ``per_type_s`` the per-live-type overhead of the §5.4
    compacted step's dense slices.  With the default symmetric lane
    costs, compacted is dominated by gather (same pack price, extra
    per-type slices) — DESIGN.md §14 spells out when to bias it back in.
    """

    dispatch_s: float = _DISPATCH_LATENCY_S
    transfer_s: float = _TRANSFER_LATENCY_S
    lane_s: float = 60e-9
    pack_lane_s: float = 8e-9
    per_type_s: float = 2e-6
    source: str = "roofline"

    # ------------------------------------------------------------ pricing
    def epoch_costs(self, span_bucket: int, fill: float,
                    n_types: int = 1) -> Dict[str, float]:
        """Predicted cost of one fused epoch under each mode.

        ``span_bucket`` is the full-frontier launch width P (what masked
        pays); ``fill`` the predicted active fraction of that span.  The
        gather/compacted prediction launches the rung covering the
        predicted live count, and both pay the extra pack dispatch + count
        readback (DESIGN.md §11: ``2*dispatch + transfer`` vs masked's
        ``dispatch + transfer``).
        """
        P = max(1, int(span_bucket))
        fill = min(1.0, max(0.0, float(fill)))
        pred_active = max(1, int(round(fill * P)))
        dense = _bucket(pred_active)
        masked = self.dispatch_s + self.transfer_s + P * self.lane_s
        pack = self.dispatch_s + self.transfer_s + P * self.pack_lane_s
        gather = masked - (P - min(P, dense)) * self.lane_s + pack
        compacted = gather + max(1, n_types) * self.per_type_s
        return {"masked": masked, "compacted": compacted, "gather": gather}

    # -------------------------------------------------------- calibration
    @classmethod
    def calibrated(cls, capacity: int = 4096, repeats: int = 5,
                   path: Optional[str] = None) -> "CostModel":
        """One-shot micro-probe of this host's actual constants.

        Measures (a) the jitted no-op dispatch + ``device_get`` round trip
        (splits it 2:1 into dispatch vs transfer, matching the roofline
        ratio), (b) the per-lane slope of an elementwise step at two
        widths, and (c) the per-lane cost of ``lane_pack``.  The result is
        cached per backend for the life of the process — and persisted to
        ``path`` (JSON) when given — so a steady-state controller never
        probes again ("one-shot" is the contract, not a rate limit).
        """
        import jax
        import jax.numpy as jnp

        backend = jax.default_backend()
        cached = _CALIBRATION_CACHE.get(backend)
        if cached is not None:
            return cached
        if path is not None:
            loaded = cls.load(path, backend=backend)
            if loaded is not None:
                _CALIBRATION_CACHE[backend] = loaded
                return loaded

        def _min_time(fn, *args) -> float:
            fn(*args)  # compile outside the timed reps
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                best = min(best, time.perf_counter() - t0)
            return best

        # (a) dispatch + scalar readback round trip
        noop = jax.jit(lambda x: x + 1)
        zero = jnp.zeros((), jnp.int32)
        rtt = _min_time(lambda x: jax.device_get(noop(x)), zero)
        dispatch_s = rtt * (2.0 / 3.0)
        transfer_s = rtt * (1.0 / 3.0)

        # (b) per-lane slope of a masked-step-shaped elementwise pass
        def _lanes(v):
            return (v * 3 + 1) % 7

        small = jnp.zeros((max(64, capacity // 8),), jnp.int32)
        large = jnp.zeros((capacity,), jnp.int32)
        stepper = jax.jit(_lanes)
        t_small = _min_time(stepper, small)
        t_large = _min_time(stepper, large)
        dlanes = large.shape[0] - small.shape[0]
        lane_s = max(1e-10, (t_large - t_small) / max(1, dlanes))

        # (c) per-lane cost of the pack pass
        from ..kernels.ops import lane_pack

        mask = jnp.arange(capacity) % 2 == 0
        packer = jax.jit(lambda m: lane_pack(m)[0])
        pack_lane_s = max(1e-10, _min_time(packer, mask) / capacity)

        model = cls(dispatch_s=dispatch_s, transfer_s=transfer_s,
                    lane_s=lane_s, pack_lane_s=pack_lane_s,
                    source=f"calibrated:{backend}")
        _CALIBRATION_CACHE[backend] = model
        if path is not None:
            model.save(path, backend=backend)
        return model

    def save(self, path: str, backend: str = "any") -> None:
        payload = dataclasses.asdict(self)
        payload["backend"] = backend
        pathlib.Path(path).write_text(json.dumps(payload, indent=2))

    @classmethod
    def load(cls, path: str, backend: str = "any") -> Optional["CostModel"]:
        p = pathlib.Path(path)
        if not p.exists():
            return None
        try:
            payload = json.loads(p.read_text())
        except (OSError, ValueError):
            return None
        if payload.pop("backend", "any") not in ("any", backend):
            return None
        try:
            return cls(**payload)
        except TypeError:
            return None


@dataclasses.dataclass(frozen=True)
class Decision:
    """One per-epoch dispatch decision, with its evidence attached.

    ``fill`` is the rolling-window estimate of ``active / full_span``
    (None until the first readback lands); ``hole_fraction`` its
    complement; ``costs`` the model's per-mode price in seconds;
    ``reason`` is "no-data" (cold start -> masked), "cost" (argmin) or
    "hysteresis" (kept the previous mode inside the switching band).
    """

    mode: str
    fill: Optional[float]
    costs: Dict[str, float]
    reason: str
    span_bucket: int

    @property
    def hole_fraction(self) -> Optional[float]:
        return None if self.fill is None else max(0.0, 1.0 - self.fill)


class DispatchController:
    """Per-epoch dispatch selection from observed frontier fill.

    The observation loop is driver-fed: after each epoch readback the
    driver reports ``observe(n_active, full_span_bucket)`` — active lanes
    against the *full* frontier width, not the launched width, so a
    gather epoch that launches a dense rung still measures the true hole
    fraction it is hiding.  ``choose`` then prices the next epoch's
    modes at the rolling fill estimate and picks the argmin, with a
    hysteresis band so marginal cost differences cannot flap the mode
    (every flap risks a fresh jit specialization at a new (mode, width)
    key).  Cold start is masked: the cheapest critical path when nothing
    is known, and the mode whose readback seeds the window.
    """

    def __init__(self, cost: Optional[CostModel] = None,
                 modes: Sequence[str] = AUTO_MODES,
                 n_types: int = 1, window: int = 32,
                 hysteresis: float = 0.15,
                 registry=None, driver: str = "host", app: str = "?"):
        for m in modes:
            if m not in AUTO_MODES:
                raise ValueError(f"unknown auto dispatch mode {m!r}")
        self.cost = cost or CostModel()
        self.modes = tuple(modes)
        self.n_types = max(1, int(n_types))
        self.window = RollingWindow(window)
        self.hysteresis = float(hysteresis)
        self.decisions: Dict[str, int] = {m: 0 for m in self.modes}
        self.last_decision: Optional[Decision] = None
        self._last_mode: Optional[str] = None
        self._decided, self._hole_gauge, self._fill_gauge = None, None, None
        if registry is not None:
            self.bind_registry(registry, driver=driver, app=app)

    # ------------------------------------------------------------ metrics
    def bind_registry(self, registry, driver: str = "host",
                      app: str = "?") -> None:
        """Attach a MetricsRegistry: decisions land as labeled counters
        (``trees_controller_decisions_total{mode=...}``) and the observed
        hole fraction as a gauge, so adaptivity is auditable in the same
        export as the series it consumed."""
        fam = registry.counter(
            "trees_controller_decisions_total",
            "dispatch=auto per-epoch mode picks",
            ("driver", "app", "mode"),
        )
        self._decided = {
            m: fam.labels(driver=driver, app=app, mode=m) for m in self.modes
        }
        self._hole_gauge = registry.gauge(
            "trees_controller_hole_fraction",
            "rolling observed hole fraction feeding dispatch=auto",
            ("driver", "app"),
        ).labels(driver=driver, app=app)
        self._fill_gauge = registry.gauge(
            "trees_controller_fill",
            "rolling observed frontier fill feeding dispatch=auto",
            ("driver", "app"),
        ).labels(driver=driver, app=app)

    # -------------------------------------------------------- observation
    def observe(self, n_active: int, full_span: int) -> None:
        """Feed one readback: active lanes vs the full frontier width."""
        if full_span <= 0:
            return
        fill = min(1.0, max(0.0, n_active / full_span))
        self.window.add(fill)
        if self._fill_gauge is not None:
            self._fill_gauge.set(fill)
            self._hole_gauge.set(1.0 - fill)

    # ----------------------------------------------------------- decision
    def choose(self, span_bucket: int) -> Decision:
        fill = self.window.mean()
        if fill is None:
            d = Decision("masked", None, {}, "no-data", span_bucket)
        else:
            costs = self.cost.epoch_costs(span_bucket, fill, self.n_types)
            costs = {m: costs[m] for m in self.modes}
            best = min(costs, key=costs.get)
            mode, reason = best, "cost"
            prev = self._last_mode
            if (prev is not None and prev != best and prev in costs
                    and costs[prev] <= costs[best] * (1.0 + self.hysteresis)):
                mode, reason = prev, "hysteresis"
            d = Decision(mode, fill, costs, reason, span_bucket)
        self._last_mode = d.mode
        self.last_decision = d
        self.decisions[d.mode] = self.decisions.get(d.mode, 0) + 1
        if self._decided is not None and d.mode in self._decided:
            self._decided[d.mode].inc()
        return d

    def choose_resident(self, capacity: int) -> Decision:
        """Pick the mode a resident (traced) loop bakes in: masked vs
        gather only (§5.4 compacted stays host-side), decided once per
        template rather than per epoch — the wave-template cache makes the
        choice sticky per wave shape, so identical consecutive waves can
        never retrace on a flipped decision."""
        saved = self.modes
        try:
            self.modes = tuple(m for m in RESIDENT_AUTO_MODES
                               if m in saved) or RESIDENT_AUTO_MODES
            return self.choose(capacity)
        finally:
            self.modes = saved


class ChunkController:
    """Adaptive resident chunk size K (tentpole decision (b)).

    Policy, evaluated once per chunk boundary — the only place the
    resident path surfaces information without paying an extra readback:

    * **shrink** (halve, floor ``k_min``) when the queue is hot: jobs are
      waiting and the oldest has waited longer than ``hot_wait_s`` (the
      same signal exported as ``trees_job_queue_wait_seconds``) — or the
      nearest outstanding *deadline* is within ``tight_slack_s``
      (DESIGN.md §16: boundaries are the only preemption/admission yield
      points, so a tightening deadline needs them to come sooner).  A
      long K starves admission — completions and free regions only
      surface at boundaries.
    * **widen** (double, cap ``k_max``) while a boundary surfaces no
      completions and nothing is queued: that readback bought nothing,
      so the next chunk should amortize more epochs per sync.
    * otherwise hold: completions are flowing at the current cadence.

    K feeds ``run_chunk``'s dynamic epoch bound, so every value re-enters
    the one compiled template per (regions, capacity, depth) — adaptation
    is retrace-free by construction, and the zero-retrace test guards it.
    """

    def __init__(self, k_init: int = 1, k_min: int = 1, k_max: int = 4096,
                 hot_wait_s: float = 0.05, tight_slack_s: float = 0.1,
                 registry=None, app: str = "?"):
        if not (1 <= k_min <= k_init <= k_max):
            raise ValueError(
                f"need 1 <= k_min <= k_init <= k_max, got "
                f"{k_min}/{k_init}/{k_max}"
            )
        self.k = int(k_init)
        self.k_min = int(k_min)
        self.k_max = int(k_max)
        self.hot_wait_s = float(hot_wait_s)
        self.tight_slack_s = float(tight_slack_s)
        self.widened = 0
        self.shrunk = 0
        self._k_gauge = self._adapt = None
        if registry is not None:
            self.bind_registry(registry, app=app)

    def bind_registry(self, registry, app: str = "?") -> None:
        self._k_gauge = registry.gauge(
            "trees_controller_chunk_k", "adaptive resident chunk size K",
            ("app",),
        ).labels(app=app)
        self._k_gauge.set(self.k)
        fam = registry.counter(
            "trees_controller_chunk_adaptations_total",
            "chunk=auto boundary decisions", ("app", "action"),
        )
        self._adapt = {a: fam.labels(app=app, action=a)
                       for a in ("widen", "shrink", "hold")}

    def current(self) -> int:
        return self.k

    def observe(self, completions: int, queued: int = 0,
                oldest_wait_s: float = 0.0,
                deadline_slack: float = float("inf")) -> int:
        """Feed one chunk boundary; returns the K for the next chunk.

        ``deadline_slack`` is seconds until the nearest outstanding
        deadline across queued + running jobs (``inf`` when none): within
        ``tight_slack_s`` it counts as hot even with an empty queue, so
        the boundary cadence tightens before the deadline, not after."""
        hot = (
            (queued > 0 and oldest_wait_s >= self.hot_wait_s)
            or deadline_slack <= self.tight_slack_s
        )
        if hot and self.k > self.k_min:
            self.k = max(self.k_min, self.k // 2)
            self.shrunk += 1
            action = "shrink"
        elif completions == 0 and not hot and self.k < self.k_max:
            self.k = min(self.k_max, self.k * 2)
            self.widened += 1
            action = "widen"
        else:
            action = "hold"
        if self._k_gauge is not None:
            self._k_gauge.set(self.k)
            self._adapt[action].inc()
        return self.k


class PlacementController:
    """Pick the fleet placement policy per workload mix (ROADMAP item).

    ``placement="auto"`` on a sharded fleet routes every placement
    decision through here, the way ``dispatch="auto"`` routes launch
    shaping through :class:`DispatchController`.  Placement, like
    dispatch, only moves *overhead* (which shard a job lands on — never
    its results), so an online heuristic is safe:

    * **least_loaded** when the fleet runs *imbalanced*: the observed
      per-shard utilization spread or pending-queue spread exceeds its
      threshold — evening out load beats any affinity.
    * **sticky** when the workload is *type-diverse* and balanced: many
      distinct program structures in the recent submission window means
      type-affinity maximizes region compatibility on each shard (a
      queued job only seats into a structurally-equal region, so mixing
      types across shards strands free regions).
    * **round_robin** otherwise: a homogeneous balanced workload needs
      no signal — rotation is the cheapest fair spread.
    """

    def __init__(self, window: int = 64, spread_hot: float = 0.25,
                 queue_spread_hot: int = 2, diversity_hot: float = 0.5,
                 registry=None, app: str = "?"):
        self.window = int(window)
        self.spread_hot = float(spread_hot)
        self.queue_spread_hot = int(queue_spread_hot)
        self.diversity_hot = float(diversity_hot)
        self._recent_types: list = []
        self._util_spread = 0.0
        self._queue_spread = 0
        self.decisions: Dict[str, int] = {}
        self.last_policy: Optional[str] = None
        self._decided = None
        if registry is not None:
            self.bind_registry(registry, app=app)

    def bind_registry(self, registry, app: str = "?") -> None:
        fam = registry.counter(
            "trees_controller_placement_total",
            "placement=auto per-job policy picks", ("app", "policy"),
        )
        self._decided = {
            p: fam.labels(app=app, policy=p)
            for p in ("round_robin", "least_loaded", "sticky")
        }

    # -------------------------------------------------------- observation
    def observe_job(self, type_key) -> None:
        """Feed one submission's structural type (rolling window)."""
        self._recent_types.append(type_key)
        if len(self._recent_types) > self.window:
            self._recent_types.pop(0)

    def observe_imbalance(self, util_spread: float,
                          queue_spread: int) -> None:
        """Feed one collective boundary's imbalance signals: max-min
        per-shard lane utilization, max-min pending-queue depth."""
        self._util_spread = float(util_spread)
        self._queue_spread = int(queue_spread)

    @property
    def diversity(self) -> float:
        """Distinct structural types per recent submission (0..1)."""
        if not self._recent_types:
            return 0.0
        return len(set(self._recent_types)) / len(self._recent_types)

    # ----------------------------------------------------------- decision
    def choose(self) -> str:
        if (
            self._util_spread > self.spread_hot
            or self._queue_spread > self.queue_spread_hot
        ):
            policy = "least_loaded"
        elif (
            len(self._recent_types) >= 2
            and len(set(self._recent_types)) >= 2
            and self.diversity >= self.diversity_hot
        ):
            policy = "sticky"
        else:
            policy = "round_robin"
        self.last_policy = policy
        self.decisions[policy] = self.decisions.get(policy, 0) + 1
        if self._decided is not None:
            self._decided[policy].inc()
        return policy


# queue-heat probe fed to the chunk controller: (queued, oldest_wait_s)
# with an optional third element, seconds of slack to the nearest
# outstanding deadline (drivers accept both arities)
QueueProbe = Callable[[], Tuple[float, ...]]
