"""Self-tuning controllers (DESIGN.md §14): telemetry -> policy -> dispatch."""
from .controller import (
    AUTO_MODES,
    RESIDENT_AUTO_MODES,
    ChunkController,
    CostModel,
    Decision,
    DispatchController,
    RollingWindow,
)

__all__ = [
    "AUTO_MODES",
    "RESIDENT_AUTO_MODES",
    "ChunkController",
    "CostModel",
    "Decision",
    "DispatchController",
    "RollingWindow",
]
