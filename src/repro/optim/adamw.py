"""AdamW in pure JAX with ZeRO-1 optimizer-state sharding.

The first/second-moment tensors carry a PartitionSpec that additionally
shards one param-replicated dimension over the "data" mesh axis (ZeRO-1).
Under GSPMD this materializes as reduce-scattered moment updates and an
all-gather of the updated params — the standard ZeRO-1 collective schedule —
without any manual collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Dict[str, jnp.ndarray]
    v: Dict[str, jnp.ndarray]
    step: jnp.ndarray
    # f32 master copies when training with bf16 params (mixed precision);
    # empty dict otherwise
    master: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)


def zero1_pspec(
    spec: P,
    shape: Tuple[int, ...],
    data_axes: Tuple[str, ...],
    data_axis_size: int,
) -> P:
    """Shard the first replicated, divisible dim of a moment tensor over the
    data axes (ZeRO-1).  Falls back to the param spec when nothing divides."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    ax = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
    for i, (p_, n) in enumerate(zip(parts, shape)):
        if p_ is None and n > 0 and n % data_axis_size == 0:
            parts[i] = ax
            return P(*parts)
    return P(*list(spec))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Any = 3e-4                 # float or callable(step) -> float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    master_weights: bool = False   # bf16 params + f32 masters in OptState

    def init(self, params: Dict[str, jnp.ndarray]) -> OptState:
        zeros = {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()}
        master = {}
        if self.master_weights:
            master = {
                k: v.astype(jnp.float32) for k, v in params.items()
            }
        return OptState(
            m=zeros,
            v={k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()},
            step=jnp.zeros((), jnp.int32),
            master=master,
        )

    def update(
        self,
        params: Dict[str, jnp.ndarray],
        grads: Dict[str, jnp.ndarray],
        state: OptState,
    ) -> Tuple[Dict[str, jnp.ndarray], OptState, Dict[str, jnp.ndarray]]:
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in grads.values()
            )
        )
        scale = jnp.float32(1.0)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))

        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        new_p, new_m, new_v, new_master = {}, {}, {}, {}
        for k, p_ in params.items():
            g = grads[k].astype(jnp.float32) * scale
            m = self.b1 * state.m[k] + (1 - self.b1) * g
            v = self.b2 * state.v[k] + (1 - self.b2) * g * g
            upd = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            ref = state.master[k] if self.master_weights else (
                p_.astype(jnp.float32)
            )
            if self.weight_decay and p_.ndim > 1:  # no decay on norms/bias
                upd = upd + self.weight_decay * ref
            newf = ref - lr * upd
            if self.master_weights:
                new_master[k] = newf
            new_p[k] = newf.astype(p_.dtype)
            new_m[k] = m
            new_v[k] = v
        return new_p, OptState(
            m=new_m, v=new_v, step=step, master=new_master
        ), {
            "grad_norm": gnorm, "lr": jnp.float32(lr),
        }
