"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    min_ratio: float = 0.1,
):
    """Linear warmup then cosine decay to ``min_ratio * peak_lr``."""

    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * s / max(1, warmup_steps)
        t = jnp.clip(
            (s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0
        )
        cos = peak_lr * (
            min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        )
        return jnp.where(s < warmup_steps, warm, cos)

    return lr
