# Pure-JAX optimizer substrate: AdamW with ZeRO-1 state sharding, cosine
# schedule, global-norm clipping, gradient accumulation.
from .adamw import AdamW, OptState, zero1_pspec  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
