# Data substrate: deterministic synthetic token pipeline (step-indexed, so
# checkpoint restart replays exactly), sequence packing, sharded placement.
from .pipeline import PackedDataset, SyntheticLM, place_batch  # noqa: F401
