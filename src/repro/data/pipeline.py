"""Deterministic, step-indexed data pipeline.

Fault-tolerance contract: ``batch_at(step)`` is a pure function of
(seed, step), so a restart from checkpoint step k replays byte-identical
data without any reader state to persist — the data-side half of exact
resume (runtime/ft.py tests rely on this).

``SyntheticLM`` draws Zipf-ish token streams with induced bigram structure
(so a model can actually reduce loss on it); ``PackedDataset`` packs
variable-length documents into fixed (batch, seq) with -1 label masking at
document boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step) % (2**31 - 1)
        )
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # zipf-ish unigram draw
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        toks = (base % max(V - 2, 1)) + 1
        # induced structure: token t is often followed by (t*7+3) % V
        follow = (toks * 7 + 3) % max(V - 2, 1) + 1
        use_follow = rng.rand(B, S) < 0.5
        toks[:, 1:] = np.where(use_follow[:, 1:], follow[:, :-1], toks[:, 1:])
        tokens = toks.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((B, 1), -1, np.int32)], axis=1
        )
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class PackedDataset:
    """Packs variable-length documents into fixed (batch, seq) windows.

    Documents are delimited with an EOS token; labels are masked (-1) across
    document boundaries so loss never crosses documents.
    """

    vocab: int
    seq_len: int
    global_batch: int
    eos: int = 2
    mean_doc_len: int = 256
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 2_000_003 + step) % (2**31 - 1)
        )
        B, S = self.global_batch, self.seq_len
        tokens = np.zeros((B, S), np.int32)
        labels = np.full((B, S), -1, np.int32)
        for b in range(B):
            pos = 0
            while pos < S:
                doc_len = min(
                    S - pos, max(2, int(rng.exponential(self.mean_doc_len)))
                )
                doc = rng.randint(3, max(self.vocab, 4), size=doc_len)
                doc[-1] = self.eos
                tokens[b, pos : pos + doc_len] = doc
                labels[b, pos : pos + doc_len - 1] = doc[1:]
                pos += doc_len
        return {"tokens": tokens, "labels": labels}


def place_batch(
    batch: Dict[str, np.ndarray],
    mesh: Optional[jax.sharding.Mesh] = None,
    batch_axes=("pod", "data"),
) -> Dict[str, jnp.ndarray]:
    """Device-put a host batch with the batch dim sharded over (pod, data)."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    ax = tuple(a for a in batch_axes if a in mesh.axis_names)
    ax = ax[0] if len(ax) == 1 else (ax or None)
    out = {}
    for k, v in batch.items():
        spec = P(ax, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
