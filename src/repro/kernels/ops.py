"""Public jit'd wrappers around the Pallas kernels, with backend dispatch.

On TPU the Pallas kernels run natively; on CPU (this container, smoke tests,
and the dry-run lowering) the pure-jnp oracles from ``ref.py`` are used —
mathematically identical, so tests and the dry-run cost model stay valid.
``impl`` overrides: "pallas" (native), "interpret" (Pallas interpreter —
the kernel body executed on CPU, used by the per-kernel allclose sweeps),
"ref" (oracle), "auto" (platform default).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import mha_flash as _flash_pallas
from .fork_compact import fork_scan as _fork_scan_pallas
from .fork_compact import segmented_fork_scan as _seg_scan_pallas
from .fork_compact import type_rank as _type_rank_pallas
from .ssd_scan import ssd_scan as _ssd_pallas


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(impl: str) -> str:
    return _default_impl() if impl == "auto" else impl


def fork_offsets(counts: jnp.ndarray, impl: str = "auto"):
    """Exclusive prefix-sum fork allocation (engine + MoE dispatch)."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.fork_scan_ref(counts)
    return _fork_scan_pallas(counts, interpret=(impl == "interpret"))


def segmented_fork_offsets(
    counts: jnp.ndarray, seg: jnp.ndarray, n_segs: int, impl: str = "auto"
):
    """Per-region exclusive fork allocation (the ``JobArena`` segmented scan).

    ``seg`` tags each lane with its TV region; each region's forks get
    contiguous offsets among that region's own counts, so the service's
    multi-tenant commit stays bit-identical to the solo cumsum per region.
    Returns (offsets i32[C], per-region totals i32[n_segs]).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.segmented_fork_scan_ref(counts, seg, n_segs)
    return _seg_scan_pallas(
        counts, seg, n_segs, interpret=(impl == "interpret")
    )


def type_rank(
    types: jnp.ndarray, active: jnp.ndarray, n_types: int, impl: str = "auto"
):
    """Stable within-type rank of each active lane + per-type counts.

    The engine's type-compaction stage (§5.4 contiguity): ``dest =
    type_start[type] + rank`` scatters same-type tasks into dense ranges so
    each type executes as one coherent launch.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.type_rank_ref(types, active, n_types)
    return _type_rank_pallas(
        types, active, n_types, interpret=(impl == "interpret")
    )


def lane_pack(active: jnp.ndarray, impl: str = "auto"):
    """Stable frontier pack of the scheduled lanes (gather dispatch).

    The single-type specialization of the §5.4 compaction: ``perm[d]`` is
    the lane position of the d-th scheduled lane (-1 beyond the scheduled
    population) and ``count`` the scheduled population.  The engine's
    gather dispatch packs a masked fused epoch into a dense frontier with
    this permutation, executes the task step lane-exact, and scatters the
    effects back — so cross-region hole lanes are never launched.  The
    non-ref path rides the ``type_rank`` Pallas kernel with a single type
    bucket (rank-among-active is exactly a one-type stable rank).
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.lane_pack_ref(active)
    P = active.shape[0]
    rank, counts = _type_rank_pallas(
        jnp.zeros((P,), jnp.int32), active, 1,
        interpret=(impl == "interpret"),
    )
    return ref.rank_to_perm(rank, active), counts[0].astype(jnp.int32)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    scale: Optional[float] = None,
    q_offset: int = 0,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    site: str = "kv_self",
    impl: str = "auto",
) -> jnp.ndarray:
    """GQA attention (B, Hq, Sq, D) x (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    The jnp path switches to the blockwise online-softmax form beyond 1k
    context (O(Sq*block) score memory); ``site`` names the KV loop for the
    dry-run's unroll calibration."""
    impl = _resolve(impl)
    if impl == "ref":
        from ..models.common import get_unroll

        if k.shape[2] > 1024:
            return ref.mha_blockwise(
                q, k, v, causal=causal, scale=scale, q_offset=q_offset,
                window=window, block_k=512, unroll=get_unroll(site),
            )
        return ref.mha_ref(
            q, k, v, causal=causal, scale=scale, q_offset=q_offset,
            window=window,
        )
    return _flash_pallas(
        q, k, v, causal=causal, scale=scale, q_offset=q_offset, window=window,
        block_q=block_q, block_k=block_k, interpret=(impl == "interpret"),
    )


def gqa_decode(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    scale: Optional[float] = None,
    window: int = 0,
    block_k: int = 512,
    impl: str = "auto",
) -> jnp.ndarray:
    """Single-token decode attention over a ragged KV cache."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.decode_attention_ref(
            q, k_cache, v_cache, lengths, scale=scale, window=window
        )
    return _decode_pallas(
        q, k_cache, v_cache, lengths, scale=scale, window=window,
        block_k=block_k, interpret=(impl == "interpret"),
    )


def ssd(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    h0: Optional[jnp.ndarray] = None,
    chunk: int = 128,
    impl: str = "auto",
):
    """Mamba-2 SSD scan; returns (y, final_state)."""
    impl = _resolve(impl)
    if impl == "ref":
        from ..models.common import get_unroll

        return ref.ssd_chunked(
            x, dt, A, B, C, h0=h0, chunk=chunk, unroll=get_unroll("ssd")
        )
    return _ssd_pallas(
        x, dt, A, B, C, h0=h0, chunk=chunk, interpret=(impl == "interpret")
    )
