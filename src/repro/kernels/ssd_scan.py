"""Pallas TPU kernel: Mamba-2 SSD chunked state-space scan.

The SSD recurrence  h_t = exp(A dt_t) h_{t-1} + dt_t (x_t (x) B_t),
y_t = h_t C_t  is evaluated in chunks of T steps (the state-space-duality
form of arXiv:2405.21060 §6): within a chunk the contribution is a masked
"attention"  Y_intra = ((C B^T) o M) (dt o X)  — three MXU matmuls — and the
chunk-crossing state is carried in VMEM scratch across the *sequential* TPU
grid (chunks innermost), exactly one (P, N) state per head.

Grid (H, S/T).  All decay exponents are differences of a per-chunk cumsum of
A*dt <= 0, so every exp() argument is <= 0 — numerically safe in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, h_scr,
    *, chunk,
):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[...].astype(jnp.float32)     # (T, P)
    dt = dt_ref[...].astype(jnp.float32)   # (1, T)
    a = a_ref[0, 0].astype(jnp.float32)    # scalar decay rate (< 0)
    bmat = b_ref[...].astype(jnp.float32)  # (T, N)
    cmat = c_ref[...].astype(jnp.float32)  # (T, N)

    la = a * dt                            # (1, T) log-decays, <= 0
    cum = jnp.cumsum(la, axis=1)           # (1, T) inclusive
    cum_col = cum.reshape(chunk, 1)
    cum_last = cum[0, chunk - 1]
    # intra-chunk: masked decay kernel  M[t,s] = exp(cum_t - cum_s), s <= t
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    logm = cum_col - cum                   # (T, T)
    m = jnp.where(rows >= cols, jnp.exp(jnp.minimum(logm, 0.0)), 0.0)
    g = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                      # (T, T) = C B^T
    w = g * m
    xdt = x * dt.reshape(chunk, 1)         # (T, P)
    y_intra = jax.lax.dot(w, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: carried state h0 contributes  exp(cum_t) * (C_t . h)
    h = h_scr[...]                         # (P, N)
    cdecay = cmat * jnp.exp(cum_col)       # (T, N)
    y_carry = jax.lax.dot_general(
        cdecay, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                      # (T, P)
    y_ref[...] = (y_intra + y_carry).astype(y_ref.dtype)

    # new carry:  h' = exp(cum_T) h + X^T diag(dt exp(cum_T - cum)) B
    wvec = (dt * jnp.exp(cum_last - cum)).reshape(chunk, 1)  # (T, 1)
    upd = jax.lax.dot_general(
        x * wvec, bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                      # (P, N)
    h_scr[...] = jnp.exp(cum_last) * h + upd

    @pl.when(ic == nc - 1)
    def _fini():
        hout_ref[...] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,   # (S, H, P)
    dt: jnp.ndarray,  # (S, H)
    A: jnp.ndarray,   # (H,)
    B: jnp.ndarray,   # (S, N)
    C: jnp.ndarray,   # (S, N)
    h0: jnp.ndarray | None = None,  # (H, P, N)
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    """Returns (y (S,H,P), h_final (H,P,N)); matches ref.ssd_scan_ref."""
    S, H, P = x.shape
    N = B.shape[1]
    chunk = min(chunk, max(S, 8))
    pad = (-S) % chunk
    # dt = 0 padding is a no-op on the state (decay exp(0)=1, update 0)
    xt = jnp.pad(x.transpose(1, 0, 2), ((0, 0), (0, pad), (0, 0)))
    dtt = jnp.pad(dt.T, ((0, 0), (0, pad)))
    Bp = jnp.pad(B, ((0, pad), (0, 0)))
    Cp = jnp.pad(C, ((0, pad), (0, 0)))
    nc = (S + pad) // chunk
    if h0 is None:
        h0 = jnp.zeros((H, P, N), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, hout = pl.pallas_call(
        kernel,
        grid=(H, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, 1), lambda h, c: (h, 0)),
            pl.BlockSpec((chunk, N), lambda h, c: (c, 0)),
            pl.BlockSpec((chunk, N), lambda h, c: (c, 0)),
            pl.BlockSpec((None, P, N), lambda h, c: (h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, P, N), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((H, S + pad, P), x.dtype),
            jax.ShapeDtypeStruct((H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.reshape(H, 1), Bp, Cp, h0)
    return y.transpose(1, 0, 2)[:S], hout
