# Pallas TPU kernels for the compute hot spots (fork allocation scan,
# flash/decode attention, Mamba-2 SSD scan), each with a pure-jnp oracle in
# ref.py and a dispatching wrapper in ops.py.
from . import ops, ref  # noqa: F401
from .ops import attention, fork_offsets, gqa_decode, ssd, type_rank  # noqa: F401
