# Pallas TPU kernels for the compute hot spots (fork allocation scan,
# flash/decode attention, Mamba-2 SSD scan, persistent epoch megakernel),
# each with a pure-jnp oracle in ref.py and a dispatching wrapper in ops.py
# (the megakernel dispatches in its own module — it wraps a traced loop
# body, not a fixed array signature).
from . import epoch_megakernel, ops, ref  # noqa: F401
from .epoch_megakernel import epoch_chunk  # noqa: F401
from .ops import attention, fork_offsets, gqa_decode, ssd, type_rank  # noqa: F401
