"""Pallas TPU kernel: blockwise (flash) attention with GQA and causal mask.

Grid (B, Hq, n_q_blocks, n_kv_blocks); the kv dimension is innermost, so the
online-softmax running max / normalizer / accumulator live in VMEM scratch
across the sequential kv steps.  GQA is expressed through the K/V BlockSpec
index maps (kv head = q head // group) — the grouped heads *share* the K/V
block in VMEM instead of materializing repeated KV in HBM.

Block shapes default to (128, head_dim): MXU-aligned on the contraction and
output dims, VMEM working set = q(128xD) + k,v(2x128xD) + scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, block_q, block_k, q_offset, kv_len, window,
):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = (
        iq * block_q + q_offset
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    )
    kpos = (
        ik * block_k
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    )
    # skip kv blocks entirely in the causal future of this q block, and
    # (for sliding-window) blocks entirely behind every query's window
    run = True
    if causal:
        run = run & (ik * block_k <= (iq + 1) * block_q - 1 + q_offset)
    if window > 0:
        run = run & (
            (ik + 1) * block_k - 1 >= iq * block_q + q_offset - window + 1
        )

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (block_q, block_k)
        mask = kpos < kv_len
        if causal:
            mask = mask & (qpos >= kpos)
        if window > 0:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _fini():
        l = l_scr[:, :1]
        o_ref[...] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "scale", "q_offset", "window", "block_q", "block_k",
        "interpret"
    ),
)
def mha_flash(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,  # (B, Hkv, Skv, D)
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, "GQA requires Hq divisible by Hkv"
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 128))
    pq = (-Sq) % block_q
    pk = (-Skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = qp.shape[2] // block_q
    nk = kp.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        q_offset=q_offset,
        kv_len=Skv,
        window=window,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (None, None, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)
            ),
            pl.BlockSpec(
                (None, None, block_k, D),
                lambda b, h, iq, ik, g=group: (b, h // g, ik, 0),
            ),
            pl.BlockSpec(
                (None, None, block_k, D),
                lambda b, h, iq, ik, g=group: (b, h // g, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, None, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq + pq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]
