"""Pallas TPU kernel: single-token decode attention over a KV cache (GQA).

Decode is memory-bound: the kernel streams K/V blocks from HBM once while
the tiny q row sits in VMEM, with per-sequence valid lengths masking the
ragged cache tail.  Grid (B, Hkv, n_kv_blocks), kv innermost; all ``group``
grouped q heads of one kv head are processed together as the rows of an
MXU matmul — the grouped-heads-share-KV trick that makes GQA decode read
each cache byte exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_K = 512


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, block_k, window,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[pl.program_id(0)]
    group = q_ref.shape[0]
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (group, block_k), 1
    )

    lo = length - window if window > 0 else 0

    @pl.when((ik * block_k < length) & ((ik + 1) * block_k > lo))
    def _body():
        q = q_ref[...].astype(jnp.float32)       # (group, D)
        k = k_ref[...].astype(jnp.float32)       # (block_k, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                 # (group, block_k)
        valid = kpos < length
        if window > 0:
            valid = valid & (kpos >= length - window)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)       # (block_k, D)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _fini():
        l = l_scr[:, :1]
        o_ref[...] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "window", "block_k", "interpret")
)
def decode_attention(
    q: jnp.ndarray,        # (B, Hq, D)
    k_cache: jnp.ndarray,  # (B, Hkv, S, D)
    v_cache: jnp.ndarray,  # (B, Hkv, S, D)
    lengths: jnp.ndarray,  # (B,) i32
    scale: float | None = None,
    window: int = 0,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    block_k = min(block_k, max(S, 128))
    pk = (-S) % block_k
    kp = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nk = kp.shape[2] // block_k
    # regroup q rows under their kv head: (B, Hkv, group, D)
    qg = q.reshape(B, Hkv, group, D)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, window=window
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths, whole array
            pl.BlockSpec((None, None, group, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((None, None, block_k, D), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, None, group, D), lambda b, h, ik: (b, h, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kp, vp)
    return out.reshape(B, Hq, D)
