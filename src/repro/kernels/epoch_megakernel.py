"""Persistent Pallas megakernel for the resident epoch step (DESIGN.md §12).

The chunked resident drivers (DESIGN.md §9–10) already run pop → pack →
step → commit inside one compiled ``lax.while_loop``, but each phase is
still a separate XLA op sandwich inside the loop body: every epoch pays
op-level launch overhead between the scheduler pop, the task step, and the
fork commit.  This module fuses the whole K-epoch chunk into **one**
``pl.pallas_call``: the carry pytree — TVM state, heap, JobArena cursors,
the batched ``[n_regions, depth]`` scheduler stacks with their per-region
stack pointers, and the hi/lo accumulator pairs — is loaded into
kernel-resident memory once, the epoch ``while_loop`` runs entirely inside
the kernel, and the carry is stored back when the chunk bound ``limit`` is
reached or every stack drains.  The chunk bound rides in as a dynamic
scalar operand, so K=1, K=4, and the fully-resident wave re-enter one
compiled kernel exactly like the while_loop template they replace.

The kernel is *generic over the carry pytree*: the driver passes the same
traced ``body_fn`` / ``cond_fn`` it would hand to ``lax.while_loop``
(built by :meth:`~repro.core.engine.EpochLoop.resident_body`), so the
megakernel and the while_loop baseline are bit-identical by construction —
``kernels/ref.py::epoch_chunk_ref`` is that baseline, packaged as this
kernel's oracle.

Backend dispatch follows ``ops.py``: "pallas" on TPU, the jnp oracle on
CPU, "interpret" to execute the kernel body through the Pallas interpreter
(the CI parity path on this CPU container).  Grid strategy: one program
instance owning the full TV — the epoch body is already lane-vectorized
(VPU-shaped masked/gather steps), and lanes interact every epoch through
the fork prefix sum and the stack push, so a lane-partitioned grid would
need cross-program reductions per epoch; see DESIGN.md §12 for the
single-block rationale and the TPU scaling notes.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(impl: str) -> str:
    return _default_impl() if impl in ("auto", None) else impl


def epoch_chunk(
    cond_fn: Callable,
    body_fn: Callable,
    carry: Any,
    limit,
    impl: str = "auto",
) -> Any:
    """Run one resident chunk: ``while cond_fn(carry, limit): body_fn``.

    ``carry`` is any pytree (the drivers pass a
    :class:`~repro.core.engine.ResidentCarry`); ``limit`` is the dynamic
    chunk bound (i32 scalar).  Returns the carry after the chunk, with the
    same pytree structure.  ``impl``: "pallas" (native TPU), "interpret"
    (Pallas interpreter), "ref" (the ``lax.while_loop`` oracle), "auto"
    (platform default).
    """
    impl = _resolve(impl)
    limit = jnp.asarray(limit, jnp.int32)
    if impl == "ref":
        from . import ref

        return ref.epoch_chunk_ref(cond_fn, body_fn, carry, limit)
    if impl not in ("pallas", "interpret"):
        raise ValueError(
            f"epoch_chunk impl must be 'pallas', 'interpret', 'ref' or "
            f"'auto', got {impl!r}"
        )
    return _epoch_chunk_pallas(
        cond_fn, body_fn, carry, limit, interpret=(impl == "interpret")
    )


def _epoch_chunk_pallas(cond_fn, body_fn, carry, limit, *, interpret: bool):
    """One ``pallas_call`` for the whole chunk.

    The carry pytree is flattened to kernel refs (scalar leaves ride as
    length-1 vectors — TPU refs are arrays), every input aliases its
    output so the chunk updates in place, and the chunk bound is read from
    a scalar operand inside the kernel.  The kernel body is exactly the
    oracle's ``while_loop`` — evaluated in kernel-resident values instead
    of between XLA ops.
    """
    leaves, treedef = jax.tree_util.tree_flatten(carry)

    # A pallas kernel body may not close over array-valued constants, and
    # the traced epoch body mints several (the span/map width-ladder
    # tables, lane iotas).  Trace it to a jaxpr up front: the minted
    # constants surface as ``closed.consts``, which ride in as explicit
    # kernel operands and feed ``eval_jaxpr`` inside the kernel.
    def _flat_body(*ls):
        out = body_fn(jax.tree_util.tree_unflatten(treedef, ls))
        return jax.tree_util.tree_leaves(out)

    closed = jax.make_jaxpr(_flat_body)(*leaves)
    consts = [jnp.asarray(c) for c in closed.consts]

    # Zero-size leaves (e.g. a zero-width arena payload plane when the
    # program has no float args) carry no data and pallas refuses them as
    # operands — mint them inside the kernel and pass the originals
    # through unchanged on return.
    keep = [leaf.size > 0 for leaf in leaves]
    ckeep = [c.size > 0 for c in consts]

    scalar = [jnp.ndim(leaf) == 0 for leaf in leaves]
    shaped = [
        leaf[None] if s else leaf
        for leaf, s, k in zip(leaves, scalar, keep)
        if k
    ]
    cscalar = [jnp.ndim(c) == 0 for c in consts]
    cshaped = [
        c[None] if s else c
        for c, s, k in zip(consts, cscalar, ckeep)
        if k
    ]
    n, m = len(shaped), len(cshaped)

    def _unpack(refs, all_vals, kept, scal):
        """Read kept leaves from refs, mint zero-size ones in place."""
        out, it = [], iter(refs)
        for v, k, s in zip(all_vals, kept, scal):
            if k:
                r = next(it)
                out.append(r[...][0] if s else r[...])
            else:
                out.append(jnp.zeros(v.shape, v.dtype))
        return out

    def kernel(lim_ref, *refs):
        ins, cins, outs = refs[:n], refs[n:n + m], refs[n + m:]
        vals = _unpack(ins, leaves, keep, scalar)
        cvals = _unpack(cins, consts, ckeep, cscalar)
        lim = lim_ref[0]

        def loop_body(ls):
            return tuple(jax.core.eval_jaxpr(closed.jaxpr, cvals, *ls))

        def loop_cond(ls):
            cc = jax.tree_util.tree_unflatten(treedef, ls)
            return cond_fn(cc, lim)

        out_leaves = jax.lax.while_loop(loop_cond, loop_body, tuple(vals))
        kept_out = [
            (v, s)
            for v, s, k in zip(out_leaves, scalar, keep)
            if k
        ]
        for r, (v, s) in zip(outs, kept_out):
            r[...] = v[None] if s else v

    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype) for x in shaped]
    flat = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        # operand 0 is the chunk bound; carry operand i+1 aliases output i
        # (the hoisted constants after the carry alias nothing)
        input_output_aliases={i + 1: i for i in range(n)},
        interpret=interpret,
    )(limit[None], *shaped, *cshaped)
    it = iter(flat)
    outs = []
    for leaf, s, k in zip(leaves, scalar, keep):
        if k:
            v = next(it)
            outs.append(v[0] if s else v)
        else:
            outs.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, outs)
