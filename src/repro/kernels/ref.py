"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret-mode
allclose sweeps in tests/test_kernels.py) and the fallback implementation on
backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fork_scan_ref(counts: jnp.ndarray):
    """Exclusive prefix sum + total (oracle for fork_compact.fork_scan)."""
    counts = counts.astype(jnp.int32)
    incl = jnp.cumsum(counts)
    return incl - counts, incl[-1] if counts.shape[0] else jnp.int32(0)


def segmented_fork_scan_ref(counts: jnp.ndarray, seg: jnp.ndarray, n_segs: int):
    """Oracle for fork_compact.segmented_fork_scan: per-segment exclusive
    prefix sum of ``counts`` + per-segment totals.

    ``seg[i]`` is lane i's segment (TV region) id; ids outside
    ``[0, n_segs)`` contribute to no segment and read offset 0.  This is the
    ``JobArena`` fork allocator: each lane's offset among *its own region's*
    forks equals the solo cumsum restricted to that region.  Returns
    (offsets i32[C], totals i32[n_segs]).
    """
    counts = counts.astype(jnp.int32)
    seg = seg.astype(jnp.int32)
    onehot = seg[:, None] == jnp.arange(n_segs, dtype=jnp.int32)[None, :]
    cnt1h = jnp.where(onehot, counts[:, None], 0)
    excl = jnp.cumsum(cnt1h, axis=0) - cnt1h
    offs = jnp.where(onehot, excl, 0).sum(axis=1).astype(jnp.int32)
    return offs, cnt1h.sum(axis=0).astype(jnp.int32)


def rank_to_perm(rank: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Scatter a stable within-mask rank into a pack permutation.

    ``perm[d]`` is the lane position of the d-th active lane (increasing,
    so fork-allocation order is preserved), -1 beyond the active
    population.  Shared by the jnp oracle and the kernel-backed
    ``ops.lane_pack`` so the two paths can only differ in how the rank is
    computed."""
    P = rank.shape[0]
    return (
        jnp.full((P,), -1, jnp.int32)
        .at[jnp.where(active, rank, P)]
        .set(jnp.arange(P, dtype=jnp.int32), mode="drop")
    )


def lane_pack_ref(active: jnp.ndarray):
    """Oracle for the gather-dispatch frontier pack (single-type compaction).

    ``active`` is the epoch's per-lane scheduled mask; the pack is the
    stable permutation that gathers every scheduled lane into a contiguous
    frontier (:func:`rank_to_perm`).  Returns (perm i32[P], count i32[]).
    """
    act = active.astype(bool)
    rank = jnp.cumsum(act.astype(jnp.int32)) - act.astype(jnp.int32)
    return rank_to_perm(rank, act), act.sum().astype(jnp.int32)


def epoch_chunk_ref(cond_fn, body_fn, carry, limit):
    """Oracle for the persistent epoch megakernel (epoch_megakernel.py).

    One K-epoch chunk of the resident loop — pop, pack, step, commit —
    expressed as a host-level ``lax.while_loop`` over the carry pytree.
    The megakernel runs the *same* ``body_fn`` inside one ``pallas_call``
    with the carry held in kernel memory; this oracle defines the bits it
    must produce.  ``cond_fn(carry, limit)`` is the chunk-bound predicate.
    """
    lim = jnp.asarray(limit, jnp.int32)
    return jax.lax.while_loop(lambda c: cond_fn(c, lim), body_fn, carry)


def type_rank_ref(types: jnp.ndarray, active: jnp.ndarray, n_types: int):
    """Oracle for fork_compact.type_rank: stable within-type ranks."""
    types = types.astype(jnp.int32)
    act = active.astype(bool)
    onehot = jax.nn.one_hot(types, n_types, dtype=jnp.int32)
    onehot = onehot * act[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    rank = jnp.take_along_axis(
        pos, jnp.clip(types, 0, n_types - 1)[:, None], axis=1
    )[:, 0]
    rank = jnp.where(act, rank, -1)
    counts = onehot.sum(axis=0)
    return rank, counts


def mha_ref(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,  # (B, Hkv, Skv, D)
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    window: int = 0,
) -> jnp.ndarray:
    """Grouped-query attention oracle, f32 accumulation.

    ``q_offset`` positions queries at absolute index q_offset + i for the
    causal mask (decode-with-cache semantics).  ``window > 0`` restricts
    attention to the last ``window`` positions (sliding-window attention).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, Sq, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    static_no_window = isinstance(window, int) and window == 0
    if causal or not static_no_window:
        Skv = k.shape[2]
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Skv)
        mask = jnp.ones((Sq, Skv), bool)
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if not static_no_window:
            # window may be a traced per-layer scalar (hybrid archs)
            w = jnp.asarray(window)
            mask = mask & (
                (qpos[:, None] - kpos[None, :] < w) | (w <= 0)
            )
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


def mha_blockwise(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Skv, D)
    v: jnp.ndarray,  # (B, Hkv, Skv, D)
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    window: int = 0,
    block_k: int = 512,
    unroll: int = 1,
) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure jnp: a lax.scan over KV
    blocks.  This is the XLA twin of the Pallas kernel — O(Sq * block_k)
    score memory instead of O(Sq * Skv) — used for the long-context cells on
    backends without Pallas (and as the dry-run lowering).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    block_k = min(block_k, Skv)
    pad = (-Skv) % block_k
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = kp.shape[2] // block_k
    kb = kp.reshape(B, Hkv, nk, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, Hkv, nk, block_k, D).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Sq, D) * scale
    qpos = jnp.arange(Sq) + q_offset
    static_no_window = isinstance(window, int) and window == 0

    m0 = jnp.full((B, Hkv, g, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Sq, D), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        ib, kblk, vblk = xs
        kpos = ib * block_k + jnp.arange(block_k)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qf, kblk.astype(jnp.float32)
        )
        mask = kpos[None, :] < Skv
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if not static_no_window:
            w = jnp.asarray(window)
            mask = mask & ((qpos[:, None] - kpos[None, :] < w) | (w <= 0))
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l + p.sum(-1, keepdims=True)
        acc_new = alpha * acc + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), ()

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(nk), kb, vb),
        unroll=unroll,
    )
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


def ssd_chunked(
    x: jnp.ndarray,   # (S, H, P)
    dt: jnp.ndarray,  # (S, H)
    A: jnp.ndarray,   # (H,)
    B: jnp.ndarray,   # (S, N)
    C: jnp.ndarray,   # (S, N)
    h0: jnp.ndarray | None = None,
    chunk: int = 128,
    unroll: int = 1,
):
    """Chunked SSD in pure jnp — the XLA twin of the Pallas ssd_scan kernel
    (same matrix formulation, lax.scan over chunks instead of a sequential
    grid).  Matches ssd_scan_ref; O(S/chunk) loop trips instead of O(S)."""
    S, H, P = x.shape
    N = B.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    xf = jnp.pad(x.astype(jnp.float32), ((0, pad), (0, 0), (0, 0)))
    dtf = jnp.pad(dt.astype(jnp.float32), ((0, pad), (0, 0)))
    Bf = jnp.pad(B.astype(jnp.float32), ((0, pad), (0, 0)))
    Cf = jnp.pad(C.astype(jnp.float32), ((0, pad), (0, 0)))
    Af = A.astype(jnp.float32)
    nc = (S + pad) // chunk
    xb = xf.reshape(nc, chunk, H, P)
    dtb = dtf.reshape(nc, chunk, H)
    Bb = Bf.reshape(nc, chunk, N)
    Cb = Cf.reshape(nc, chunk, N)
    h_init = (
        jnp.zeros((H, P, N), jnp.float32) if h0 is None
        else h0.astype(jnp.float32)
    )
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h, xs):
        xc, dtc, Bc, Cc = xs          # (T,H,P), (T,H), (T,N), (T,N)
        la = Af[None, :] * dtc        # (T, H)
        cum = jnp.cumsum(la, axis=0)  # (T, H)
        logm = cum[:, None, :] - cum[None, :, :]        # (T, T, H)
        m = jnp.where(tri[..., None], jnp.exp(jnp.minimum(logm, 0.0)), 0.0)
        gmat = Cc @ Bc.T                                # (T, T)
        w = gmat[..., None] * m                         # (T, T, H)
        xdt = xc * dtc[..., None]                       # (T, H, P)
        y_intra = jnp.einsum("tsh,shp->thp", w, xdt)
        cdecay = Cc[:, None, :] * jnp.exp(cum)[..., None]  # (T, H, N)
        y_carry = jnp.einsum("thn,hpn->thp", cdecay, h)
        wvec = dtc * jnp.exp(cum[-1][None, :] - cum)       # (T, H)
        upd = jnp.einsum("thp,th,tn->hpn", xc, wvec, Bc)
        h_new = jnp.exp(cum[-1])[:, None, None] * h + upd
        return h_new, y_intra + y_carry

    h, ys = jax.lax.scan(body, h_init, (xb, dtb, Bb, Cb), unroll=unroll)
    y = ys.reshape(nc * chunk, H, P)[:S]
    return y.astype(x.dtype), h


def decode_attention_ref(
    q: jnp.ndarray,        # (B, Hq, D)      one new query per sequence
    k_cache: jnp.ndarray,  # (B, Hkv, S, D)
    v_cache: jnp.ndarray,  # (B, Hkv, S, D)
    lengths: jnp.ndarray,  # (B,) valid cache lengths
    scale: float | None = None,
    window: int = 0,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qf, k_cache.astype(jnp.float32))
    logits = logits * scale
    valid = jnp.arange(S)[None] < lengths[:, None]  # (B, S)
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window)
        valid = valid & (
            (jnp.arange(S)[None] >= lengths[:, None] - w) | (w <= 0)
        )
    logits = jnp.where(valid[:, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


def ssd_scan_ref(
    x: jnp.ndarray,   # (S, H, P)   inputs per head
    dt: jnp.ndarray,  # (S, H)      softplus-activated step sizes
    A: jnp.ndarray,   # (H,)        negative decay rates (A < 0)
    B: jnp.ndarray,   # (S, N)      input projection (shared across heads)
    C: jnp.ndarray,   # (S, N)      output projection
    h0: jnp.ndarray | None = None,  # (H, P, N) initial state
):
    """Sequential Mamba-2 SSD recurrence (oracle for the chunked kernel).

    h_t = exp(A dt_t) h_{t-1} + dt_t * (x_t outer B_t);  y_t = h_t C_t
    Returns (y (S,H,P), h_final (H,P,N)).
    """
    S, H, P = x.shape
    N = B.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        decay = jnp.exp(Af * dtf[t])[:, None, None]  # (H,1,1)
        upd = (dtf[t][:, None, None] * xf[t][:, :, None]) * Bf[t][None, None, :]
        h = decay * h + upd
        y = jnp.einsum("hpn,n->hp", h, Cf[t])
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return ys.astype(x.dtype), h
