"""Pallas TPU kernel: cooperative fork-slot allocation (exclusive prefix sum).

This is the TPU-native replacement for the paper's ``atomicInc(nextFreeCore)``
(§5.2.3).  On the GPU, TREES reduces per-wavefront then issues one atomic per
wavefront; on TPU there are no global atomics, so the whole Task Vector's
fork counts are scanned cooperatively:

  * each grid step loads one (8, 128)-aligned block of counts into VMEM,
  * computes the block-local exclusive scan on the VPU,
  * adds the running carry held in SMEM scratch — TPU grid steps execute
    *sequentially* on a core, so the carry needs no synchronization at all
    (the "wavefront -> block, atomic -> sequential-grid carry" adaptation
    from DESIGN.md §2),
  * the final step emits the grand total (the new ``nextFreeCore`` delta).

Used by the engine via ``ops.fork_offsets`` and by the MoE work-together
dispatch (expert bincount offsets share the same primitive).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 1024  # lanes per grid step; multiple of the (8,128) VPU tile


def _fork_scan_kernel(counts_ref, offs_ref, total_ref, carry_ref):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = jnp.int32(0)

    block = counts_ref[...]  # (1, BLOCK) i32
    incl = jnp.cumsum(block, axis=-1)
    carry = carry_ref[0]
    offs_ref[...] = incl - block + carry
    carry_ref[0] = carry + incl[0, -1]

    @pl.when(i == n - 1)
    def _fini():
        total_ref[0, 0] = carry_ref[0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fork_scan(
    counts: jnp.ndarray, block: int = BLOCK, interpret: bool = False
):
    """Exclusive prefix sum + total of an i32 vector (any length).

    Returns (offsets i32[C], total i32[]).
    """
    (c,) = counts.shape
    pad = (-c) % block
    x = jnp.pad(counts.astype(jnp.int32), (0, pad)).reshape(-1, block)
    nb = x.shape[0]
    offs, total = pl.pallas_call(
        _fork_scan_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(x)
    return offs.reshape(-1)[:c], total[0, 0]


def _seg_scan_kernel(counts_ref, seg_ref, offs_ref, totals_ref, carry_ref,
                     *, n_segs):
    """Segmented exclusive scan: each lane's offset among *its own segment's*
    counts.  One (n_segs,)-wide running total in SMEM replaces n_segs atomic
    cursors (the ``JobArena`` per-region ``nextFreeCore``); TPU's sequential
    grid makes the carry race-free, exactly as in ``_fork_scan_kernel``."""
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        for s in range(n_segs):
            carry_ref[s] = jnp.int32(0)

    cnt = counts_ref[...]  # (1, B) i32
    seg = seg_ref[...]     # (1, B) i32
    offs = jnp.zeros_like(cnt)
    for s in range(n_segs):  # n_segs = fleet size: small and static
        m = seg == s
        x = jnp.where(m, cnt, 0)
        excl = jnp.cumsum(x, axis=-1) - x
        offs = jnp.where(m, excl + carry_ref[s], offs)
        carry_ref[s] = carry_ref[s] + jnp.sum(x)
    offs_ref[...] = offs

    @pl.when(i == n - 1)
    def _fini():
        for s in range(n_segs):
            totals_ref[0, s] = carry_ref[s]


@functools.partial(
    jax.jit, static_argnames=("n_segs", "block", "interpret")
)
def segmented_fork_scan(
    counts: jnp.ndarray,
    seg: jnp.ndarray,
    n_segs: int,
    block: int = BLOCK,
    interpret: bool = False,
):
    """Per-segment exclusive prefix sum + per-segment totals.

    The multi-tenant fork allocator (``JobArena`` in ``core.tvm``): lane
    ``i``'s fork slots start at ``region_cursor[seg[i]] + offsets[i]``, and
    each region's cursor advances by ``totals[seg]``.  Lanes of one segment
    need not be contiguous.  ``seg`` ids outside ``[0, n_segs)`` contribute
    to no segment and read offset 0.

    Returns (offsets i32[C], totals i32[n_segs]).
    """
    (c,) = counts.shape
    pad = (-c) % block
    x = jnp.pad(counts.astype(jnp.int32), (0, pad)).reshape(-1, block)
    # pad with segment id n_segs: matches no segment, contributes nothing
    s = jnp.pad(
        seg.astype(jnp.int32), (0, pad), constant_values=n_segs
    ).reshape(-1, block)
    nb = x.shape[0]
    ns = max(n_segs, 1)
    kernel = functools.partial(_seg_scan_kernel, n_segs=n_segs)
    offs, totals = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, ns), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int32),
            jax.ShapeDtypeStruct((1, ns), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((ns,), jnp.int32)],
        interpret=interpret,
    )(x, s)
    return offs.reshape(-1)[:c], totals[0, :n_segs]


def _type_rank_kernel(types_ref, active_ref, rank_ref, counts_ref, carry_ref,
                      *, n_types):
    """Per-type stable ranks: rank[i] = #active lanes of the same type before
    lane i.  One (n_types,)-wide running count in SMEM replaces n_types
    atomic counters; TPU's sequential grid makes the carry race-free."""
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        for t in range(n_types):
            carry_ref[t] = jnp.int32(0)

    types = types_ref[...]       # (1, B) i32
    act = active_ref[...] != 0   # (1, B)
    rank = jnp.zeros_like(types)
    for t in range(n_types):     # n_types is small and static
        m = (types == t) & act
        mi = m.astype(jnp.int32)
        excl = jnp.cumsum(mi, axis=-1) - mi
        rank = jnp.where(m, excl + carry_ref[t], rank)
        carry_ref[t] = carry_ref[t] + jnp.sum(mi)
    rank_ref[...] = jnp.where(act, rank, -1)

    @pl.when(i == n - 1)
    def _fini():
        for t in range(n_types):
            counts_ref[0, t] = carry_ref[t]


@functools.partial(
    jax.jit, static_argnames=("n_types", "block", "interpret")
)
def type_rank(
    types: jnp.ndarray,
    active: jnp.ndarray,
    n_types: int,
    block: int = BLOCK,
    interpret: bool = False,
):
    """Stable rank of each active lane within its task type + per-type counts.

    This is the paper's §5.4 contiguity principle as a kernel: with
    ``dest = type_start[type] + rank`` (type_start = exclusive cumsum of the
    returned counts), scattering lanes to ``dest`` groups same-type tasks
    contiguously so each type executes as one dense range.  Also the core of
    the MoE work-together dispatch (type = expert id).

    Returns (rank i32[C] — -1 for inactive lanes, counts i32[n_types]).
    """
    (c,) = types.shape
    pad = (-c) % block
    t = jnp.pad(types.astype(jnp.int32), (0, pad)).reshape(-1, block)
    a = jnp.pad(active.astype(jnp.int32), (0, pad)).reshape(-1, block)
    nb = t.shape[0]
    ct = max(n_types, 1)
    kernel = functools.partial(_type_rank_kernel, n_types=n_types)
    rank, counts = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int32),
            jax.ShapeDtypeStruct((1, ct), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((ct,), jnp.int32)],
        interpret=interpret,
    )(t, a)
    return rank.reshape(-1)[:c], counts[0, :n_types]
