"""Labeled metrics registry: counters, gauges, histograms for the runtime.

Prometheus-shaped (families -> labeled children -> samples) but dependency
free: the registry is a plain in-process object, exported as JSONL or
Prometheus text exposition by ``obs/export.py``.  Two feeding paths:

* :class:`MetricsCollector` adapts the existing
  :class:`~repro.core.scheduler.StatsCollector` hook surface — wrap any
  inner collector and every engine hook (epochs, lanes, dispatches,
  transfers, forks, maps, holes) lands both in the inner ``RunStats`` and
  in labeled registry series, including the per-epoch/per-chunk lane
  utilization and hole-fraction histograms no scalar total can express;
* ``JobService`` feeds job *lifecycle* series directly: per-tenant latency
  histograms split into queue-wait vs run time, completion/failure
  counters, and the wave-template cache hit/miss + retrace counters.

Metric names follow one scheme (DESIGN.md §13): ``trees_<noun>_total`` for
counters, ``trees_<noun>`` gauges, ``trees_<noun>_<unit>`` histograms;
label keys are ``driver`` (host/device), ``dispatch`` (masked/compacted/
gather), ``app`` (program name), ``tenant`` (job name).  ``RunStats.
as_dict()`` keys are the shared vocabulary — ``obs.export.export_run_stats``
publishes a finished run's stats under ``trees_run_<key>`` without
re-spelling any name.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import threading
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from ..core.scheduler import RunStats, StatsCollector

# default histogram buckets: latencies in seconds (submillisecond epochs up
# to minute-long waves)
LATENCY_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)
# ratios in [0, 1] (lane utilization, hole fraction)
RATIO_BUCKETS = (0.01, 0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8,
                 0.9, 0.95, 0.99, 1.0)


class MetricsError(ValueError):
    pass


class RollingWindow:
    """Bounded recent-value series with O(1) mean.

    The registry's counters/histograms are cumulative, built for export;
    a controller needs the *recent* value of a ratio (frontier fill, hole
    fraction) without differencing registry state, so it reads through
    one of these instead (``control/controller.py`` is the consumer).
    """

    __slots__ = ("_items", "_sum")

    def __init__(self, size: int):
        if size < 1:
            raise MetricsError(f"window size must be >= 1, got {size}")
        self._items: deque = deque(maxlen=size)
        self._sum = 0.0

    def add(self, v: float) -> None:
        if len(self._items) == self._items.maxlen:
            self._sum -= self._items[0]
        self._items.append(float(v))
        self._sum += float(v)

    def mean(self) -> Optional[float]:
        if not self._items:
            return None
        return self._sum / len(self._items)

    def last(self) -> Optional[float]:
        return self._items[-1] if self._items else None

    def __len__(self) -> int:
        return len(self._items)


def _check_labels(labelnames: Tuple[str, ...], labels: Dict[str, str]):
    if tuple(sorted(labels)) != tuple(sorted(labelnames)):
        raise MetricsError(
            f"labels {sorted(labels)} do not match declared label names "
            f"{sorted(labelnames)}"
        )


class Counter:
    """Monotone counter child (one label combination)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise MetricsError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Set-to-current-value child."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def max(self, v: float) -> None:
        self.value = max(self.value, float(v))


class Histogram:
    """Cumulative-bucket histogram child (Prometheus semantics)."""

    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float]):
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # +inf tail
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Upper-bound estimate of the q-quantile from the buckets (the
        load-generator benchmarks report p50/p99 from this)."""
        if not 0.0 <= q <= 1.0:
            raise MetricsError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return math.nan
        target = q * self.count
        seen = 0
        for le, c in zip(self.buckets, self.counts):
            seen += c
            if seen >= target:
                return le
        return math.inf


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


@dataclasses.dataclass
class Family:
    """One metric family: a name + help + kind, children per label set."""

    name: str
    kind: str
    help: str
    labelnames: Tuple[str, ...]
    buckets: Optional[Tuple[float, ...]] = None
    children: Dict[Tuple[str, ...], object] = dataclasses.field(
        default_factory=dict
    )

    def labels(self, **labels: str):
        _check_labels(self.labelnames, labels)
        key = tuple(str(labels[k]) for k in self.labelnames)
        child = self.children.get(key)
        if child is None:
            if self.kind == "histogram":
                child = Histogram(self.buckets or LATENCY_BUCKETS)
            else:
                child = _KINDS[self.kind]()
            self.children[key] = child
        return child

    def items(self) -> Iterable[Tuple[Dict[str, str], object]]:
        for key, child in sorted(self.children.items()):
            yield dict(zip(self.labelnames, key)), child


class MetricsRegistry:
    """Process-local registry of metric families.

    Registration is idempotent per (name, kind, labelnames): engines and
    services re-declare their families freely and share the children.
    Thread-safe registration (benchmark load generators observe from worker
    threads); child mutation is plain (CPython atomic enough for counters,
    and the runtime drivers are single-threaded).
    """

    def __init__(self):
        self._families: Dict[str, Family] = {}
        self._lock = threading.Lock()

    # -------------------------------------------------------- declaration
    def _declare(self, name: str, kind: str, help: str,
                 labels: Sequence[str], buckets=None) -> Family:
        labelnames = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise MetricsError(
                        f"metric {name!r} already registered as {fam.kind} "
                        f"with labels {fam.labelnames}, not {kind} with "
                        f"{labelnames}"
                    )
                return fam
            fam = Family(
                name=name, kind=kind, help=help, labelnames=labelnames,
                buckets=tuple(buckets) if buckets else None,
            )
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._declare(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._declare(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), buckets=None) -> Family:
        return self._declare(name, "histogram", help, labels, buckets)

    # ------------------------------------------------------------ reading
    def families(self) -> List[Family]:
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]

    def get(self, name: str) -> Optional[Family]:
        return self._families.get(name)

    def value(self, name: str, **labels: str) -> float:
        """Scalar value of one counter/gauge child (tests/controllers)."""
        fam = self._families[name]
        child = fam.labels(**labels)
        if isinstance(child, Histogram):
            raise MetricsError(
                f"{name!r} is a histogram; read .sum/.count/.quantile"
            )
        return child.value


class MetricsCollector(StatsCollector):
    """StatsCollector adapter feeding a :class:`MetricsRegistry`.

    Wraps an inner collector (``RunStatsCollector`` or ``NullStats``) so
    the engine's existing accounting is untouched; every hook additionally
    lands in labeled registry series.  One instance per run/wave, labels
    fixed at construction: ``driver`` (host/device), ``dispatch``, ``app``.

    The per-event histograms are the part a scalar total cannot express:
    ``trees_lane_utilization`` gets one observation per epoch (host) or per
    chunk (resident — the chunk *is* the finest grain the resident path can
    observe without paying extra readbacks, see DESIGN.md §13), and
    ``trees_hole_fraction`` the matching skipped-lane share.

    ``shard`` (opt-in) adds a shard label to every series — the sharded
    fleet engine gives each shard its own collector so per-shard work
    splits and utilization spread are scrapeable directly (DESIGN.md §15).
    The registry pins labelnames per metric name, so a given registry must
    be fed consistently: all collectors sharded, or none.
    """

    def __init__(self, inner: StatsCollector, registry: MetricsRegistry,
                 driver: str, dispatch: str, app: str,
                 shard: Optional[str] = None):
        self.inner = inner
        self.registry = registry
        self.labels = dict(driver=driver, dispatch=dispatch, app=app)
        lab = ("driver", "dispatch", "app")
        if shard is not None:
            self.labels["shard"] = shard
            lab = lab + ("shard",)
        r = registry
        self._epochs = r.counter(
            "trees_epochs_total", "epochs run (critical-path T_inf)", lab
        ).labels(**self.labels)
        self._tasks = r.counter(
            "trees_tasks_total", "tasks executed (work T_1)", lab
        ).labels(**self.labels)
        self._lanes = r.counter(
            "trees_lanes_total", "lanes launched incl. padding", lab
        ).labels(**self.labels)
        self._dispatches = r.counter(
            "trees_dispatches_total", "host->device launches (V_inf)", lab
        ).labels(**self.labels)
        self._transfers = r.counter(
            "trees_transfers_total", "device->host readbacks (V_inf)", lab
        ).labels(**self.labels)
        self._forks = r.counter(
            "trees_forks_total", "tasks forked", lab
        ).labels(**self.labels)
        self._holes = r.counter(
            "trees_hole_lanes_total",
            "full-span lanes skipped by dense dispatch", lab
        ).labels(**self.labels)
        self._map_launches = r.counter(
            "trees_map_launches_total", "map payload launches", lab
        ).labels(**self.labels)
        self._map_elements = r.counter(
            "trees_map_elements_total", "live map element-lanes", lab
        ).labels(**self.labels)
        self._map_lanes = r.counter(
            "trees_map_lanes_total", "launched map element-lanes", lab
        ).labels(**self.labels)
        self._peak = r.gauge(
            "trees_peak_tv_slots", "peak TV slot cursor", lab
        ).labels(**self.labels)
        self._util = r.histogram(
            "trees_lane_utilization",
            "active/launched lanes per epoch (host) or chunk (resident)",
            lab, buckets=RATIO_BUCKETS,
        ).labels(**self.labels)
        self._hole_frac = r.histogram(
            "trees_hole_fraction",
            "skipped/full-span lanes per epoch (host) or chunk (resident)",
            lab, buckets=RATIO_BUCKETS,
        ).labels(**self.labels)
        self._pending_holes = 0

    # ------------------------------------------------------------- hooks
    def epoch(self, cen: int, n_ranges: int = 1, n: int = 1) -> None:
        self.inner.epoch(cen, n_ranges, n)
        self._epochs.inc(n)

    def lanes(self, n_active: int, launched: int, by_type=None) -> None:
        self.inner.lanes(n_active, launched, by_type)
        self._tasks.inc(n_active)
        self._lanes.inc(launched)
        holes = self._pending_holes
        self._pending_holes = 0
        full = launched + holes
        if full > 0:
            self._util.observe(n_active / full)
            self._hole_frac.observe(holes / full)

    def dispatch(self, n: int = 1) -> None:
        self.inner.dispatch(n)
        self._dispatches.inc(n)

    def transfer(self, n: int = 1) -> None:
        self.inner.transfer(n)
        self._transfers.inc(n)

    def forks(self, n: int) -> None:
        self.inner.forks(n)
        self._forks.inc(n)

    def map_launch(self, elements: int = 0, lanes: int = 0,
                   n: int = 1) -> None:
        self.inner.map_launch(elements, lanes, n)
        self._map_launches.inc(n)
        self._map_elements.inc(elements)
        self._map_lanes.inc(lanes)

    def holes_skipped(self, n: int) -> None:
        # holes are reported just before the matching lanes() call (the
        # drivers keep that order), so the pair folds into one fraction
        # observation per epoch/chunk
        self.inner.holes_skipped(n)
        self._holes.inc(n)
        self._pending_holes += n

    def tv_peak(self, slots: int) -> None:
        self.inner.tv_peak(slots)
        self._peak.max(slots)

    def result(self) -> RunStats:
        return self.inner.result()
