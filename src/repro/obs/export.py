"""Metric export: JSONL snapshots and Prometheus text exposition.

One :class:`~repro.obs.metrics.MetricsRegistry` in, two wire formats out:

* :func:`write_jsonl` — one JSON object per sample line, the benchmark/CI
  artifact format (diffable, greppable, loads with one ``json.loads`` per
  line).  Histograms emit one line carrying buckets + sum + count.
* :func:`to_prometheus` / :func:`write_prometheus` — the text exposition
  format a Prometheus scrape endpoint serves (``# HELP``/``# TYPE``
  headers, ``_bucket{le=...}``/``_sum``/``_count`` histogram series).

:func:`export_run_stats` publishes a finished run's
:class:`~repro.core.scheduler.RunStats` into the registry under
``trees_run_<key>`` gauges using ``RunStats.as_dict()`` — the *same* metric
vocabulary ``benchmarks/run.py`` writes into its JSON rows, so dashboards
and the regression gate agree on names by construction.
"""
from __future__ import annotations

import json
from typing import Dict, Iterator, Optional

from ..core.scheduler import RunStats
from .metrics import Family, Histogram, MetricsRegistry

RUN_STATS_PREFIX = "trees_run_"


# --------------------------------------------------------------------------
# JSONL
# --------------------------------------------------------------------------
def _sample(fam: Family, labels: Dict[str, str], child) -> dict:
    base = {"name": fam.name, "type": fam.kind, "labels": labels}
    if isinstance(child, Histogram):
        base["sum"] = child.sum
        base["count"] = child.count
        base["buckets"] = [
            {"le": le, "count": c}
            for le, c in zip(
                list(child.buckets) + ["+Inf"],
                _cumulative(child.counts),
            )
        ]
    else:
        base["value"] = child.value
    return base


def _cumulative(counts):
    total = 0
    out = []
    for c in counts:
        total += c
        out.append(total)
    return out


def iter_samples(registry: MetricsRegistry) -> Iterator[dict]:
    for fam in registry.families():
        for labels, child in fam.items():
            yield _sample(fam, labels, child)


def write_jsonl(registry: MetricsRegistry, path: str) -> int:
    """Write one sample per line; returns the number of lines written."""
    n = 0
    with open(path, "w") as f:
        for sample in iter_samples(registry):
            f.write(json.dumps(sample, sort_keys=True))
            f.write("\n")
            n += 1
    return n


def read_jsonl(path: str):
    """Load a JSONL snapshot back into a list of sample dicts."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# --------------------------------------------------------------------------
# Prometheus text exposition
# --------------------------------------------------------------------------
def _fmt_labels(labels: Dict[str, str], extra: Optional[Dict] = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    body = ",".join(
        f'{k}="{_escape(str(v))}"' for k, v in sorted(merged.items())
    )
    return "{" + body + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v)


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render the registry in the Prometheus text exposition format."""
    lines = []
    for fam in registry.families():
        if fam.help:
            lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for labels, child in fam.items():
            if isinstance(child, Histogram):
                cum = _cumulative(child.counts)
                for le, c in zip(list(child.buckets) + [float("inf")], cum):
                    lines.append(
                        f"{fam.name}_bucket"
                        f"{_fmt_labels(labels, {'le': _fmt_value(le)})} {c}"
                    )
                lines.append(
                    f"{fam.name}_sum{_fmt_labels(labels)} "
                    f"{_fmt_value(child.sum)}"
                )
                lines.append(
                    f"{fam.name}_count{_fmt_labels(labels)} {child.count}"
                )
            else:
                lines.append(
                    f"{fam.name}{_fmt_labels(labels)} "
                    f"{_fmt_value(child.value)}"
                )
    return "\n".join(lines) + "\n"


def write_prometheus(registry: MetricsRegistry, path: str) -> None:
    with open(path, "w") as f:
        f.write(to_prometheus(registry))


# --------------------------------------------------------------------------
# RunStats bridge (shared metric vocabulary)
# --------------------------------------------------------------------------
def export_run_stats(registry: MetricsRegistry, stats: RunStats,
                     **labels: str) -> None:
    """Publish a finished run's stats as ``trees_run_<key>`` gauges.

    The key set *is* ``RunStats.as_dict()`` — a single source of truth for
    metric names shared with ``benchmarks/run.py``'s JSON rows; renaming a
    stats field renames it everywhere at once (per-type dict fields are
    flattened to one gauge per type)."""
    labelnames = tuple(sorted(labels))
    for key, value in stats.as_dict().items():
        if isinstance(value, dict):
            fam = registry.gauge(
                RUN_STATS_PREFIX + key, f"RunStats.{key}",
                labelnames + ("type",),
            )
            for tname, tval in value.items():
                fam.labels(**labels, type=tname).set(float(tval))
        else:
            registry.gauge(
                RUN_STATS_PREFIX + key, f"RunStats.{key}", labelnames
            ).labels(**labels).set(float(value))
