"""Shared ``repro`` logger hierarchy with structured key=value output.

Every module logs through :func:`get_logger` (``repro.<subsystem>``
children of one root), so level configuration and formatting happen in
exactly one place instead of per-module ``logging.getLogger`` calls with
ad-hoc formats.  The formatter renders ``key=value`` pairs (the structured
fields ride ``logging``'s ``extra=`` mechanism via :func:`kv`), which grep
and log pipelines parse without a schema:

    log = get_logger("service")
    log.info("wave admitted %s", kv(jobs=3, capacity=4096, chunk=8))
    # 2026-08-09 12:00:00 INFO repro.service wave admitted jobs=3 ...

The level is env-configurable (``REPRO_LOG_LEVEL=DEBUG``) so a serving
deployment can flip verbosity without code changes; :func:`configure` is
idempotent and never touches the root logger (library etiquette — the
embedding application owns global logging).
"""
from __future__ import annotations

import logging
import os
import sys
from typing import Any, Optional

ROOT = "repro"
ENV_LEVEL = "REPRO_LOG_LEVEL"

_configured = False


def kv(**fields: Any) -> str:
    """Render structured fields as ``key=value`` pairs, space-joined.

    Values containing whitespace are repr-quoted so the line stays
    machine-splittable on spaces.
    """
    out = []
    for k, v in fields.items():
        s = f"{v:.6g}" if isinstance(v, float) else str(v)
        if any(c.isspace() for c in s):
            s = repr(s)
        out.append(f"{k}={s}")
    return " ".join(out)


class KeyValueFormatter(logging.Formatter):
    """``ts level logger message`` with exception text appended plainly."""

    default_msec_format = "%s.%03d"

    def __init__(self):
        super().__init__(
            fmt="%(asctime)s %(levelname)s %(name)s %(message)s",
            datefmt="%Y-%m-%dT%H:%M:%S",
        )


def configure(level: Optional[str] = None, stream=None,
              force: bool = False) -> logging.Logger:
    """Attach the key=value handler to the ``repro`` root logger once.

    ``level`` overrides ``$REPRO_LOG_LEVEL`` (default WARNING, matching the
    stdlib default so importing the runtime stays silent).  ``force``
    re-applies handler + level (tests, or runtime level flips).
    """
    global _configured
    root = logging.getLogger(ROOT)
    if _configured and not force:
        return root
    lvl = level or os.environ.get(ENV_LEVEL) or "WARNING"
    root.setLevel(getattr(logging, str(lvl).upper(), logging.WARNING))
    if force:
        for h in list(root.handlers):
            if getattr(h, "_repro_obs", False):
                root.removeHandler(h)
    if not any(getattr(h, "_repro_obs", False) for h in root.handlers):
        handler = logging.StreamHandler(stream or sys.stderr)
        handler.setFormatter(KeyValueFormatter())
        handler._repro_obs = True  # type: ignore[attr-defined]
        root.addHandler(handler)
    root.propagate = False  # one handler, no double lines via the stdlib root
    _configured = True
    return root


def get_logger(name: str = "") -> logging.Logger:
    """Child logger under the shared ``repro`` hierarchy.

    ``get_logger("runtime")`` -> ``repro.runtime``; a bare call returns the
    hierarchy root.  Ensures the hierarchy is configured (cheap after the
    first call), so call sites need no logging boilerplate.
    """
    configure()
    return logging.getLogger(f"{ROOT}.{name}" if name else ROOT)
