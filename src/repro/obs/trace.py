"""Span tracing: Chrome-trace-event timelines for the epoch runtime.

The paper's whole argument is an *accounting* one — V_inf critical-path
overhead (dispatches + readbacks) should be paid once by the whole system —
and the runtime already counts those terms in ``RunStats``/``ChunkSummary``.
This module turns the counters into an observable timeline: a
:class:`SpanTracer` collects Chrome trace events (the ``traceEvents`` JSON
format that chrome://tracing and Perfetto load directly), and every driver
emits spans against it:

* **host drivers** (``HostEngine``, ``EpochMultiplexer``) emit one
  ``epoch`` span per epoch with ``pack`` / ``dispatch`` / ``readback`` /
  ``maps`` child phases — the V_inf terms as visible time, annotated with
  the CEN, dispatch mode, launch width, and lane utilization;
* **resident drivers** (``DeviceEngine``, ``DeviceMultiplexer``, and the
  megakernel path) cannot observe individual epochs without paying the
  readbacks the design exists to avoid, so they emit one ``chunk`` span per
  chunk boundary, reconstructed from the :class:`~repro.core.engine.
  ChunkSummary` deltas (epochs/tasks/holes run inside the chunk), with the
  chunk's single ``readback`` as a child span — the trace makes the ⌈E/K⌉
  readback cadence literally countable;
* device launches are additionally wrapped in
  ``jax.profiler.TraceAnnotation`` (:meth:`SpanTracer.annotation`) so an
  XLA profiler session collected alongside lines up with the runtime spans.

Tracing is strictly opt-in: the module-level :data:`NULL_TRACER` is the
default everywhere, its hooks are constant-time no-ops, and driver code
guards argument construction behind ``tracer.enabled`` — the disabled path
adds nothing to the critical path (the zero-retrace and stats-equality
guards run with it in place).
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, Iterator, List, Optional


class NullTracer:
    """Disabled tracer: every hook is a constant-time no-op.

    ``span``/``annotation`` return a shared no-op context manager whose
    ``__enter__`` yields a throwaway dict, so call sites can unconditionally
    ``with tracer.span(...) as args: args.update(...)`` — though hot paths
    should still guard on ``tracer.enabled`` to skip building the args.
    """

    enabled = False

    class _NullSpan:
        def __enter__(self) -> Dict[str, Any]:
            return {}

        def __exit__(self, *exc) -> None:
            return None

    _NULL_SPAN = _NullSpan()

    def span(self, name: str, cat: str = "runtime", tid: int = 0,
             **args: Any):
        return self._NULL_SPAN

    def instant(self, name: str, cat: str = "runtime", tid: int = 0,
                **args: Any) -> None:
        return None

    def counter(self, name: str, tid: int = 0, **values: float) -> None:
        return None

    def annotation(self, name: str):
        return contextlib.nullcontext()

    def events_named(self, name: str) -> List[dict]:
        return []


NULL_TRACER = NullTracer()


class SpanTracer(NullTracer):
    """Collects Chrome trace events; write with :meth:`write`.

    Timestamps are microseconds since tracer construction
    (``perf_counter_ns`` based, so spans nest consistently within one
    process).  ``pid`` groups all events into one process track;
    each driver picks a ``tid`` lane via :meth:`thread` so e.g. the host
    epoch loop and the map launcher render as separate rows.
    """

    enabled = True

    def __init__(self, process_name: str = "trees-runtime", pid: int = 1):
        self.pid = pid
        self.events: List[dict] = []
        self._t0 = time.perf_counter_ns()
        self._threads: Dict[int, str] = {}
        self.events.append({
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "ts": 0, "args": {"name": process_name},
        })

    # ------------------------------------------------------------- clock
    def now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    # ------------------------------------------------------------ tracks
    def thread(self, tid: int, name: str) -> int:
        """Name a tid lane (idempotent); returns the tid for chaining."""
        if self._threads.get(tid) != name:
            self._threads[tid] = name
            self.events.append({
                "ph": "M", "name": "thread_name", "pid": self.pid,
                "tid": tid, "ts": 0, "args": {"name": name},
            })
        return tid

    # ------------------------------------------------------------- spans
    class _Span:
        """Complete-event ("ph": "X") recorder.

        Yields its mutable ``args`` dict on ``__enter__`` so the caller can
        attach values only known at the end of the phase (lane utilization
        after the readback, chunk deltas after the summary fetch).
        """

        __slots__ = ("_tr", "_name", "_cat", "_tid", "args", "_t0")

        def __init__(self, tr: "SpanTracer", name: str, cat: str, tid: int,
                     args: Dict[str, Any]):
            self._tr = tr
            self._name = name
            self._cat = cat
            self._tid = tid
            self.args = args

        def __enter__(self) -> Dict[str, Any]:
            self._t0 = self._tr.now_us()
            return self.args

        def __exit__(self, *exc) -> None:
            t1 = self._tr.now_us()
            self._tr.events.append({
                "ph": "X", "name": self._name, "cat": self._cat,
                "pid": self._tr.pid, "tid": self._tid,
                "ts": self._t0, "dur": t1 - self._t0,
                "args": self.args,
            })
            return None

    def span(self, name: str, cat: str = "runtime", tid: int = 0,
             **args: Any) -> "SpanTracer._Span":
        """Context manager recording one complete event over its body."""
        return SpanTracer._Span(self, name, cat, tid, args)

    def instant(self, name: str, cat: str = "runtime", tid: int = 0,
                **args: Any) -> None:
        self.events.append({
            "ph": "i", "name": name, "cat": cat, "pid": self.pid,
            "tid": tid, "ts": self.now_us(), "s": "t", "args": args,
        })

    def counter(self, name: str, tid: int = 0, **values: float) -> None:
        """Counter-track sample (renders as a stacked area in Perfetto)."""
        self.events.append({
            "ph": "C", "name": name, "pid": self.pid, "tid": tid,
            "ts": self.now_us(), "args": dict(values),
        })

    def annotation(self, name: str):
        """``jax.profiler.TraceAnnotation`` wrapping a device launch, so an
        XLA profile collected alongside shows the same phase names as the
        runtime timeline.  Falls back to a no-op where unavailable."""
        try:
            import jax.profiler

            return jax.profiler.TraceAnnotation(name)
        except Exception:  # pragma: no cover - profiler always present
            return contextlib.nullcontext()

    # ----------------------------------------------------------- queries
    def events_named(self, name: str, cat: Optional[str] = None
                     ) -> List[dict]:
        """All non-metadata events with this name (tests count readbacks)."""
        return [
            e for e in self.events
            if e.get("name") == name and e["ph"] != "M"
            and (cat is None or e.get("cat") == cat)
        ]

    # ------------------------------------------------------------ output
    def to_dict(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs.trace"},
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
            f.write("\n")


# --------------------------------------------------------------------------
# Validation (the tier-1 guard that emitted traces stay loadable)
# --------------------------------------------------------------------------
_REQUIRED_BY_PHASE = {
    "X": ("name", "ts", "dur", "pid", "tid"),
    "i": ("name", "ts", "pid", "tid"),
    "C": ("name", "ts", "pid", "args"),
    "M": ("name", "pid"),
    "B": ("name", "ts", "pid", "tid"),
    "E": ("ts", "pid", "tid"),
}


def validate_chrome_trace(doc: Any) -> List[dict]:
    """Check a parsed trace document is Chrome-trace-event JSON that
    chrome://tracing / Perfetto will load; returns the event list.

    Accepts both container layouts the format allows (a bare event array,
    or an object with ``traceEvents``).  Raises ``ValueError`` on the first
    structural problem — this is the tier-1 test's oracle, so the message
    names the offending event.
    """
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("trace object has no traceEvents list")
    elif isinstance(doc, list):
        events = doc
    else:
        raise ValueError(f"trace document must be dict or list, got "
                         f"{type(doc).__name__}")
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not an object: {e!r}")
        ph = e.get("ph")
        if not isinstance(ph, str) or not ph:
            raise ValueError(f"event {i} has no phase ('ph'): {e!r}")
        for field in _REQUIRED_BY_PHASE.get(ph, ("name",)):
            if field not in e:
                raise ValueError(
                    f"event {i} (ph={ph!r}, name={e.get('name')!r}) "
                    f"missing required field {field!r}"
                )
        if ph == "X" and not isinstance(e["dur"], (int, float)):
            raise ValueError(f"event {i} has non-numeric dur: {e!r}")
    return events


def load_trace(path: str) -> List[dict]:
    """Load + validate a trace file; returns its event list."""
    with open(path) as f:
        return validate_chrome_trace(json.load(f))


def iter_spans(events: List[dict], name: Optional[str] = None,
               cat: Optional[str] = None) -> Iterator[dict]:
    """Complete-event spans, optionally filtered by name/category."""
    for e in events:
        if e.get("ph") != "X":
            continue
        if name is not None and e.get("name") != name:
            continue
        if cat is not None and e.get("cat") != cat:
            continue
        yield e
