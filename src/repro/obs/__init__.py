# Runtime telemetry (DESIGN.md §13): span timelines, labeled metrics, and
# structured logging for the epoch runtime.  The paper's V_inf accounting
# already lives in RunStats/ChunkSummary; this package makes it observable —
# Chrome-trace epoch/chunk timelines (trace.py), a Prometheus-shaped
# metrics registry with per-tenant latency series (metrics.py), JSONL +
# text-exposition export (export.py), and the shared `repro` logger
# hierarchy (log.py).  Everything is opt-in: NULL_TRACER and plain
# collectors keep the disabled path free.
from .log import configure as configure_logging, get_logger, kv
from .metrics import (
    LATENCY_BUCKETS,
    RATIO_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsCollector,
    MetricsError,
    MetricsRegistry,
)
from .trace import (
    NULL_TRACER,
    NullTracer,
    SpanTracer,
    iter_spans,
    load_trace,
    validate_chrome_trace,
)
from .export import (
    export_run_stats,
    iter_samples,
    read_jsonl,
    to_prometheus,
    write_jsonl,
    write_prometheus,
)

__all__ = [
    "LATENCY_BUCKETS",
    "RATIO_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsCollector",
    "MetricsError",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "SpanTracer",
    "configure_logging",
    "export_run_stats",
    "get_logger",
    "iter_samples",
    "iter_spans",
    "kv",
    "load_trace",
    "read_jsonl",
    "to_prometheus",
    "validate_chrome_trace",
    "write_jsonl",
    "write_prometheus",
]
