# Epoch-multiplexing job service: co-schedule many independent task-parallel
# programs inside one shared TVM, paying the per-epoch launch + scalar
# readback (the paper's V_inf critical-path terms) once for the whole fleet
# instead of once per program — the §3 "work-together" principle extended
# across tenants.  Two wave drivers: the host-loop EpochMultiplexer
# (DESIGN.md §8; streaming completions, region reuse, compacted dispatch)
# and the device-resident DeviceMultiplexer (DESIGN.md §9; the whole wave
# in one lax.while_loop, O(1) dispatches + readbacks per wave).
from .api import JobService, merge_stats
from .jobs import (
    AdmissionError,
    Job,
    JobFailure,
    JobHandle,
    JobResult,
    JobStats,
    JobStatus,
)
from .multiplexer import (
    DeviceMultiplexer,
    EpochMultiplexer,
    TenantSlot,
    fuse_programs,
)

__all__ = [
    "AdmissionError",
    "DeviceMultiplexer",
    "EpochMultiplexer",
    "Job",
    "JobFailure",
    "JobHandle",
    "JobResult",
    "JobService",
    "JobStats",
    "JobStatus",
    "TenantSlot",
    "fuse_programs",
    "merge_stats",
]
