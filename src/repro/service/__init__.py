# Epoch-multiplexing job service: co-schedule many independent task-parallel
# programs inside one shared TVM, paying the per-epoch launch + scalar
# readback (the paper's V_inf critical-path terms) once for the whole fleet
# instead of once per program — the §3 "work-together" principle extended
# across tenants.  Two wave drivers: the host-loop EpochMultiplexer
# (DESIGN.md §8; streaming completions, region reuse, compacted dispatch)
# and the chunked-resident DeviceMultiplexer (DESIGN.md §9–10; K epochs per
# lax.while_loop re-entry, ⌈epochs/K⌉ dispatches + readbacks per wave, with
# streaming completions and region reuse at the chunk boundaries; K=∞ is
# the fully resident O(1) wave).  Structurally identical consecutive device
# waves reuse one compiled chunk template (WaveTemplateCache).
from .admission import AdmissionController, QuotaClass
from .api import JobFuture, JobService, merge_stats
from .jobs import (
    AdmissionError,
    Job,
    JobFailure,
    JobHandle,
    JobResult,
    JobStats,
    JobStatus,
    RegionCheckpoint,
    WaveTemplate,
    WaveTemplateCache,
    canonical_wave_order,
    wave_template_key,
)
from .multiplexer import (
    DeviceMultiplexer,
    EpochMultiplexer,
    TenantSlot,
    fuse_programs,
)

__all__ = [
    "AdmissionController",
    "AdmissionError",
    "DeviceMultiplexer",
    "EpochMultiplexer",
    "Job",
    "JobFailure",
    "JobFuture",
    "JobHandle",
    "JobResult",
    "JobService",
    "JobStats",
    "JobStatus",
    "QuotaClass",
    "RegionCheckpoint",
    "TenantSlot",
    "WaveTemplate",
    "WaveTemplateCache",
    "canonical_wave_order",
    "fuse_programs",
    "merge_stats",
    "wave_template_key",
]
