"""Admission layer of the serving front door (DESIGN.md §16).

The service stack is now three explicit layers:

1. **Admission** (this module): decides *which* queued jobs form the next
   wave and *when* a running wave should give a region back.  Pure policy —
   it never touches a TVM; it orders and packs :class:`JobHandle`\\ s under
   quota classes (priority, token-bucket rate limits, capacity shares) and
   plans preemptions for the wave scheduler to execute.
2. **Wave scheduler** (``multiplexer.py`` / ``distributed/fleet.py``):
   executes admission's plan at chunk boundaries — seats jobs through the
   ``_seed_region`` reseed path, lifts preempted regions into
   :class:`~repro.service.jobs.RegionCheckpoint` images.
3. **Execution surface** (``api.py``): sync + async submit/poll/stream.

TREES makes this cheap by construction: the runtime already pays its
critical-path overhead "by the entire system at once" at explicit epoch
boundaries, so every chunk boundary is a natural yield point — admission
decisions piggyback on synchronization the runtime performs anyway,
where a work-first runtime would need fine-grained queues and locks.

Packing policy: stable sort by (priority desc, deadline asc, submission
order) — i.e. EDF within each priority band — then first-fit under the
capacity / max_jobs / value-dtype / class-share budgets, with per-class
token buckets gating how fast a class may consume wave slots.  With no
priorities, deadlines, or class limits configured this degenerates to
exactly the greedy FIFO first-fit the service shipped with, so the default
service behaves identically to before the refactor.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .jobs import AdmissionError, JobHandle, check_fleet_dtype

Clock = Callable[[], float]


@dataclasses.dataclass(frozen=True)
class QuotaClass:
    """One tenant class: the admission contract a job submits under.

    ``priority`` orders classes (higher runs first and may preempt lower);
    ``rate``/``burst`` form a token bucket (jobs admitted per second,
    bucket depth) so a chatty tenant class cannot starve the queue;
    ``share`` caps the fraction of one wave's slot capacity the class may
    hold at once; ``preemptible=False`` exempts the class's running jobs
    from eviction (they still yield regions when they finish).
    """

    name: str
    priority: int = 0
    rate: float = math.inf
    burst: float = math.inf
    share: float = 1.0
    preemptible: bool = True


DEFAULT_CLASS = QuotaClass(name="default")


class AdmissionController:
    """Wave assembly + preemption planning over quota classes.

    Owns no execution state: the service hands it the queue and the
    running set; it hands back ordered picks and victim lists.  The clock
    is injectable (virtual time in the load generator, ``time.monotonic``
    in production) and must be the same clock the handles were stamped
    with — deadline arithmetic mixes the two otherwise.
    """

    def __init__(
        self,
        classes: Optional[Sequence[QuotaClass]] = None,
        clock: Clock = time.monotonic,
        evict_over_deadline: bool = False,
    ):
        self.clock = clock
        self.evict_over_deadline = bool(evict_over_deadline)
        self.classes: Dict[str, QuotaClass] = {"default": DEFAULT_CLASS}
        for qc in classes or ():
            self.classes[qc.name] = qc
        # token buckets: class name -> [tokens, last refill timestamp]
        self._buckets: Dict[str, List[float]] = {}
        # per-class outcome counters (the deadline-miss ratio numerators)
        self.deadline_misses: Dict[str, int] = {}
        self.deadline_met: Dict[str, int] = {}
        self.preempted: Dict[str, int] = {}

    # ------------------------------------------------------------ classes
    def klass_of(self, h: JobHandle) -> QuotaClass:
        qc = self.classes.get(h.klass)
        if qc is None:
            raise AdmissionError(
                f"job {h.job.name!r}: unknown quota class {h.klass!r} "
                f"(known: {sorted(self.classes)})"
            )
        return qc

    def effective_priority(self, h: JobHandle) -> int:
        """Job priority overrides its class's when explicitly set."""
        return h.priority if h.priority else self.klass_of(h).priority

    # ------------------------------------------------------ token buckets
    def _refill(self, qc: QuotaClass, now: float) -> List[float]:
        b = self._buckets.get(qc.name)
        if b is None:
            b = [min(qc.burst, max(1.0, qc.burst)), now]
            if math.isinf(qc.rate):
                b[0] = math.inf
            self._buckets[qc.name] = b
            return b
        if not math.isinf(qc.rate):
            b[0] = min(qc.burst, b[0] + (now - b[1]) * qc.rate)
        b[1] = now
        return b

    def allow(self, h: JobHandle, now: Optional[float] = None) -> bool:
        """Consume one admission token for this job's class (always true
        for unlimited classes).  Called once per actual seating — both by
        wave assembly and by the streaming mid-flight admit path, so rate
        limits hold across both doors."""
        qc = self.klass_of(h)
        if math.isinf(qc.rate):
            return True
        b = self._refill(qc, self.clock() if now is None else now)
        if b[0] >= 1.0:
            b[0] -= 1.0
            return True
        return False

    def has_token(self, h: JobHandle, now: Optional[float] = None) -> bool:
        """Non-consuming :meth:`allow`: whether the class *could* admit
        now.  The streaming admit path checks this first so a job with no
        free region doesn't burn a token on the failed attempt."""
        qc = self.klass_of(h)
        if math.isinf(qc.rate):
            return True
        b = self._refill(qc, self.clock() if now is None else now)
        return b[0] >= 1.0

    # ------------------------------------------------------ wave assembly
    def order(self, queue: Sequence[JobHandle]) -> List[JobHandle]:
        """Admission order: priority desc, then EDF, then submission order
        (the sort is stable and job_ids are monotone, so FIFO survives as
        the tie-break and the whole thing degenerates to FIFO when nobody
        sets priorities or deadlines)."""
        return sorted(
            queue,
            key=lambda h: (
                -self.effective_priority(h),
                h.deadline if h.deadline is not None else math.inf,
                h.job_id,
            ),
        )

    def take_wave(
        self,
        queue: List[JobHandle],
        capacity: int,
        max_jobs: int,
        now: Optional[float] = None,
    ) -> Tuple[List[JobHandle], List[JobHandle]]:
        """Assemble the next wave: (picked, left-behind).

        First-fit in admission order under four budgets: wave capacity,
        ``max_jobs`` fan-in, one TV value dtype per wave, and each class's
        ``share`` of capacity; the class token bucket is consumed per
        pick.  Left-behind jobs keep their queue positions for the next
        assembly — nothing is dropped here (rate-limited jobs simply wait
        for tokens)."""
        now = self.clock() if now is None else now
        wave: List[JobHandle] = []
        left: List[JobHandle] = []
        budget = capacity
        class_used: Dict[str, int] = {}
        for h in self.order(queue):
            qc = self.klass_of(h)
            cap_share = int(qc.share * capacity)
            if (
                len(wave) < max_jobs
                and h.job.quota <= budget
                and class_used.get(qc.name, 0) + h.job.quota <= cap_share
            ):
                try:
                    check_fleet_dtype(
                        [w.job.program for w in wave] + [h.job.program]
                    )
                except AdmissionError:
                    left.append(h)
                    continue
                if not self.allow(h, now):
                    left.append(h)
                    continue
                wave.append(h)
                budget -= h.job.quota
                class_used[qc.name] = (
                    class_used.get(qc.name, 0) + h.job.quota
                )
            else:
                left.append(h)
        # left-behind keeps submission order (stable under re-sorts)
        left.sort(key=lambda h: h.job_id)
        return wave, left

    # -------------------------------------------------------- preemption
    def plan_preemptions(
        self,
        running: Sequence[JobHandle],
        queued: Sequence[JobHandle],
        now: Optional[float] = None,
    ) -> List[JobHandle]:
        """Pick running victims to make room for starved queued jobs.

        A queued job may displace running work only when its priority is
        *strictly* higher than the victim's (strictness prevents equal
        -priority ping-pong: a resumed job can never be re-evicted by the
        peer it displaced).  Victims are preemptible, chosen lowest
        priority first (FIFO-late among equals), and only until the freed
        quota covers the demander.  With ``evict_over_deadline`` the
        controller additionally evicts preemptible running jobs already
        past their deadline when anything at all is queued — the region is
        worth more to a job that can still meet its contract.
        """
        now = self.clock() if now is None else now
        victims: List[JobHandle] = []
        pool = [
            h for h in running
            if self.klass_of(h).preemptible and not h.done
        ]
        # lowest priority last-submitted first: cheapest progress lost
        pool.sort(
            key=lambda h: (self.effective_priority(h), -h.job_id)
        )
        if self.evict_over_deadline and queued:
            for h in list(pool):
                if h.deadline is not None and now > h.deadline:
                    victims.append(h)
                    pool.remove(h)
        for q in self.order(queued):
            qp = self.effective_priority(q)
            need = q.job.quota
            freed = sum(v.job.quota for v in victims)
            if freed >= need:
                continue
            for v in list(pool):
                if self.effective_priority(v) >= qp:
                    break  # pool is priority-ascending: no victim fits
                victims.append(v)
                pool.remove(v)
                freed += v.job.quota
                if freed >= need:
                    break
        return victims

    # ------------------------------------------------------- accounting
    def note_finished(
        self, h: JobHandle, now: Optional[float] = None
    ) -> Optional[bool]:
        """Record the deadline outcome of a finished job (None if the job
        had no deadline; True = met).  Feeds the per-class deadline-miss
        ratio the metrics layer exports."""
        if h.deadline is None:
            return None
        now = self.clock() if now is None else now
        end = h.finished_at if h.finished_at is not None else now
        met = end <= h.deadline
        key = h.klass
        if met:
            self.deadline_met[key] = self.deadline_met.get(key, 0) + 1
        else:
            self.deadline_misses[key] = (
                self.deadline_misses.get(key, 0) + 1
            )
        return met

    def note_preempted(self, h: JobHandle) -> None:
        self.preempted[h.klass] = self.preempted.get(h.klass, 0) + 1

    def miss_ratio(self, klass: Optional[str] = None) -> float:
        """Deadline-miss ratio, per class or overall (0.0 when no
        deadlined job has finished yet)."""
        if klass is None:
            miss = sum(self.deadline_misses.values())
            met = sum(self.deadline_met.values())
        else:
            miss = self.deadline_misses.get(klass, 0)
            met = self.deadline_met.get(klass, 0)
        total = miss + met
        return miss / total if total else 0.0

    def deadline_slack(
        self,
        queued: Sequence[JobHandle],
        running: Sequence[JobHandle] = (),
        now: Optional[float] = None,
    ) -> float:
        """Seconds until the nearest outstanding deadline (inf if none).

        The chunk controller folds this in: a tightening nearest deadline
        shrinks K so completions (and preemption yield points) surface
        sooner than the hot-queue heuristic alone would arrange."""
        now = self.clock() if now is None else now
        slack = math.inf
        for h in list(queued) + list(running):
            if h.deadline is not None and not h.done:
                slack = min(slack, h.deadline - now)
        return slack
