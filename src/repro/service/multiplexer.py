"""Epoch multiplexers: fused multi-tenant driving over one shared TVM.

The paper's "work-together" principle (§3) says critical-path overhead
should be paid by the entire system at once.  A solo ``HostEngine.run``
already pays phase 1 (stack pop + launch) and phase 3 (scalar readback)
once per epoch *for one program*; N concurrent tenants would pay N× that
V_inf cost.  This module extends work-together **across tenants**, at two
levels of residency:

* :func:`fuse_programs` builds one fused :class:`Program` from N tenant
  programs — task tables and map tables concatenate (task ids shifted by a
  per-tenant offset), heap variables are namespaced ``j<k>/name``, and every
  tenant task function runs behind a context shim that translates task ids,
  map ids, and heap names back into the tenant's own vocabulary.  Phase 2
  therefore needs *no new machinery*: the fused program is an ordinary
  ``Program`` and the masked, §5.4-compacted, and §11-gather dispatches
  all apply.

* :class:`EpochMultiplexer` is the *host-loop* driver (an
  :class:`~repro.core.engine.EpochLoop` configuration): each global epoch it
  pops every ready job's frontier (``MuxPopPolicy`` selects the gang), fuses
  the popped ranges into one launch with a per-lane epoch-number vector, and
  reads back one :class:`~repro.core.tvm.MuxEpochSummary` for the whole
  fleet — V_inf paid once per *global epoch*.  Because the host sees every
  epoch, it supports streaming completion, mid-flight region reuse
  (including structurally-equal program templates, see
  ``Program.structural_hash``), gang policies, and the compacted and
  gather dispatches (the latter packs the fused span's scheduled lanes
  into one dense frontier, so cross-region hole lanes are never launched
  — DESIGN.md §11).

* :class:`DeviceMultiplexer` is the *chunked resident* driver (DESIGN.md
  §9–10): the admitted wave runs inside a ``lax.while_loop`` with
  per-region scheduler stacks (``batched_device_stacks``) and the
  :class:`~repro.core.tvm.JobArena` region cursors carried on device, for
  at most ``chunk`` (K) epochs per loop invocation.  At each chunk
  boundary the host fetches one compact
  :class:`~repro.core.engine.ChunkSummary` — so a wave of E epochs costs
  ⌈E/K⌉ dispatches + readbacks, and between chunks the host streams
  completions of drained regions and reseeds freed regions with queued
  jobs (``Program.structural_hash`` reuse, no retrace).  ``chunk=None``
  is the fully-resident endpoint (K=∞, the PR-3 behaviour: O(1) V_inf,
  host blind until the wave drains); ``chunk=1`` is host-mux cadence.
  The masked and gather dispatches are traceable on this driver (gather
  packs the scheduled lanes into a fixed-shape in-loop frontier —
  DESIGN.md §12); ``megakernel=True`` swaps the chunk's ``while_loop``
  for the persistent Pallas epoch megakernel.

Per-job results are bit-identical to the solo runs under both drivers, at
every K.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import tvm
from ..core.engine import (
    ChunkSummary,
    EpochLoop,
    _COMPACTED_RESIDENT_MSG,
    _HILO_BASE,
    _fresh_resident_carry,
    _hilo_value,
    resolve_resident_dispatch,
)
from ..control.controller import ChunkController
from ..core.program import HeapVar, MapType, Program, TaskType, pack_args
from ..obs.trace import NULL_TRACER
from ..core.scheduler import (
    EpochScheduler,
    NullStats,
    RunStats,
    RunStatsCollector,
    StatsCollector,
    batched_device_stacks,
    load_region_stacks,
    reseed_region_stacks,
    resolve_mux_policy,
    resolve_policy,
)
from .jobs import (
    Job,
    JobFailure,
    JobHandle,
    JobResult,
    JobStats,
    JobStatus,
    RegionCheckpoint,
    check_fleet_dtype,
    validate_job,
)


# --------------------------------------------------------------------------
# Tenant context shims: run a tenant task body against the fused program
# --------------------------------------------------------------------------
class _TenantEpochCtx:
    """EpochCtx view in the tenant's own vocabulary.

    Delegates every read/effect to the fused :class:`EpochCtx`, translating
    task names/ids by the tenant's task-table offset, map names/ids by its
    map-table offset, and heap names by its ``j<k>/`` namespace prefix.
    """

    __slots__ = ("_ctx", "_sub", "_task_off", "_map_off", "_prefix")

    def __init__(self, ctx, sub: Program, task_off: int, map_off: int,
                 prefix: str):
        self._ctx = ctx
        self._sub = sub
        self._task_off = task_off
        self._map_off = map_off
        self._prefix = prefix

    # reads -----------------------------------------------------------------
    def argi(self, k: int):
        return self._ctx.argi(k)

    def argf(self, k: int):
        return self._ctx.argf(k)

    @property
    def slot(self):
        return self._ctx.slot

    @property
    def child_count(self):
        return self._ctx.child_count

    def child_values(self, n: int):
        # slice the fused value rows down to the tenant's own width so a
        # width-w program sees exactly the (n, w) a solo run returns
        return self._ctx.child_values(n)[:, : self._sub.value_width]

    def read(self, name: str, index):
        return self._ctx.read(self._prefix + name, index)

    # effects ---------------------------------------------------------------
    def _code(self, task):
        if isinstance(task, str):
            return self._task_off + self._sub.task_id(task)
        return self._task_off + task

    def fork(self, task, argi=(), argf=(), where=True):
        self._ctx.fork(self._code(task), argi=argi, argf=argf, where=where)

    def join(self, task, argi=(), argf=(), where=True):
        self._ctx.join(self._code(task), argi=argi, argf=argf, where=where)

    def emit(self, value, where=True):
        # enforce the tenant's own value width (the fused width may be
        # larger; a solo run would reject the overflow, so must we)
        v = jnp.asarray(value).reshape(-1)
        if v.shape[0] > self._sub.value_width:
            raise ValueError("emit value wider than program.value_width")
        self._ctx.emit(value, where=where)

    def write(self, name: str, index, value, op: str = "set", where=True):
        self._ctx.write(self._prefix + name, index, value, op=op, where=where)

    def map(self, map_fn, argi=(), argf=(), where=True):
        mid = (
            self._sub.map_id(map_fn)
            if isinstance(map_fn, str)
            else int(map_fn)
        )
        self._ctx.map(self._map_off + mid, argi=argi, argf=argf, where=where)


class _TenantMapCtx:
    """MapCtx view with the tenant's heap namespace."""

    __slots__ = ("_ctx", "_prefix")

    def __init__(self, ctx, prefix: str):
        self._ctx = ctx
        self._prefix = prefix

    def argi(self, k: int):
        return self._ctx.argi(k)

    def argf(self, k: int):
        return self._ctx.argf(k)

    @property
    def eid(self):
        return self._ctx.eid

    def read(self, name: str, index):
        return self._ctx.read(self._prefix + name, index)

    def write(self, name: str, index, value, op: str = "set", where=True):
        self._ctx.write(self._prefix + name, index, value, op=op, where=where)


def _wrap_task(fn, sub: Program, task_off: int, map_off: int, prefix: str):
    def wrapped(ctx, _fn=fn):
        _fn(_TenantEpochCtx(ctx, sub, task_off, map_off, prefix))

    return wrapped


def _wrap_map(fn, prefix: str):
    def wrapped(mctx, _fn=fn):
        _fn(_TenantMapCtx(mctx, prefix))

    return wrapped


# --------------------------------------------------------------------------
# Program fusion
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TenantSlot:
    """One tenant's compile-time contribution to the fused program, plus its
    slot region in the shared TV.  The region is sized by the job's quota at
    fuse time; a later tenant re-admitted into this region may use less."""

    index: int
    program: Program
    task_offset: int
    map_offset: int
    prefix: str
    base: int
    quota: int

    @property
    def end(self) -> int:
        return self.base + self.quota


def fuse_programs(
    programs: Sequence[Program], quotas: Sequence[int]
) -> Tuple[Program, List[TenantSlot]]:
    """Concatenate N tenant programs into one fused :class:`Program`.

    Argument-register widths and the value width are the fleet maxima (a
    tenant's own args/emits occupy a prefix; the padding columns stay zero,
    so the tenant-visible slice is bit-identical to solo).  The value dtype
    must be uniform across the fleet (:func:`check_fleet_dtype`).
    """
    value_dtype = check_fleet_dtype(programs)
    tasks: List[TaskType] = []
    maps: List[MapType] = []
    heap: List[HeapVar] = []
    slots: List[TenantSlot] = []
    base = 0
    for j, (p, q) in enumerate(zip(programs, quotas)):
        prefix = f"j{j}/"
        slot = TenantSlot(
            index=j, program=p, task_offset=len(tasks),
            map_offset=len(maps), prefix=prefix, base=base, quota=int(q),
        )
        for t in p.tasks:
            tasks.append(
                TaskType(
                    prefix + t.name,
                    _wrap_task(t.fn, p, slot.task_offset, slot.map_offset,
                               prefix),
                )
            )
        for m in p.maps:
            maps.append(
                MapType(
                    prefix + m.name,
                    _wrap_map(m.fn, prefix),
                    domain=m.domain,
                    max_domain=m.max_domain,
                )
            )
        for hv in p.heap:
            heap.append(HeapVar(prefix + hv.name, hv.shape, hv.dtype))
        slots.append(slot)
        base += int(q)

    fused = Program(
        name="mux[" + "+".join(p.name for p in programs) + "]",
        tasks=tuple(tasks),
        n_arg_i=max(p.n_arg_i for p in programs),
        n_arg_f=max(p.n_arg_f for p in programs),
        value_width=max(p.value_width for p in programs),
        value_dtype=value_dtype,
        maps=tuple(maps),
        heap=tuple(heap),
    )
    return fused, slots


# --------------------------------------------------------------------------
# Shared fleet plumbing
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Region:
    """Runtime state of one slot region: the tenant currently in it (if
    any), its scheduler stacks (host driver only), and its solo-comparable
    stats."""

    slot: TenantSlot
    handle: Optional[JobHandle] = None
    sched: Optional[EpochScheduler] = None
    stats: Optional[JobStats] = None
    active_quota: int = 0

    @property
    def running(self) -> bool:
        return (
            self.handle is not None
            and self.handle.status is JobStatus.RUNNING
        )


class _FleetBase:
    """Shared multi-tenant plumbing: program fusion, the shared TVM state +
    :class:`~repro.core.tvm.JobArena`, per-region bookkeeping, and result
    extraction.  The host and resident drivers differ only in *how* they
    drive epochs; everything either one reads or writes lives here."""

    def __init__(
        self,
        handles: Sequence[JobHandle],
        capacity: Optional[int] = None,
        coalesce: bool = True,
        collect_stats: bool = True,
        stats_factory=None,
        template=None,
    ):
        if not handles:
            raise ValueError(f"{type(self).__name__} needs at least one job")
        # a ``None`` entry is a *vacant* slot region: the sharded fleet
        # (distributed/fleet.py) builds every shard with the same slot
        # layout and seats tenants through the admit/reseed path, so a
        # shard may start with some (or all) regions empty.  Vacancy
        # requires a template — the fused program cannot be built from
        # absent jobs.
        jobs = [h.job for h in handles if h is not None]
        if len(jobs) != len(handles) and template is None:
            raise ValueError(
                "vacant wave slots (handle=None) require a wave template: "
                "the fused program cannot be derived from absent jobs"
            )
        quota_total = (
            sum(s.quota for s in template.slots) if template is not None
            else sum(j.quota for j in jobs)
        )
        self.capacity = int(capacity) if capacity else quota_total
        if quota_total > self.capacity:
            raise ValueError(
                f"sum of job quotas ({quota_total}) exceeds TV capacity "
                f"({self.capacity})"
            )
        for j in jobs:
            validate_job(j, self.capacity)
        self.coalesce = coalesce
        self._stats_factory = stats_factory
        self._collect_stats = collect_stats

        if template is not None:
            # wave-template reuse (service/jobs.py WaveTemplateCache): this
            # wave's members are structurally equal to the template's
            # fuse-time members, so the fused program — and every compiled
            # step/loop traced against it — applies verbatim; only runtime
            # state (TV, heap, stacks) is rebuilt below
            if len(handles) != len(template.slots) or any(
                h is not None and h.job.quota != s.quota
                for h, s in zip(handles, template.slots)
            ):
                raise ValueError(
                    "wave template quota layout does not match the wave"
                )
            self.program = template.program
            self._slots = list(template.slots)
        else:
            self.program, self._slots = fuse_programs(
                [j.program for j in jobs], [j.quota for j in jobs]
            )
        self._col = self._collector()
        # (region index, handle) pairs whose TV image must be restored from
        # a RegionCheckpoint once the driver's runtime state exists — the
        # host driver restores at construction, the resident driver at its
        # first chunk (the carry is built lazily)
        self._restore_pending: List[Tuple[int, JobHandle]] = []
        self._init_fleet(handles)

    def _collector(self) -> StatsCollector:
        if self._stats_factory is not None:
            return self._stats_factory()
        return RunStatsCollector() if self._collect_stats else NullStats()

    def _init_fleet(self, handles: Sequence[JobHandle]) -> None:
        """Build the shared TVM state, arena, heap, and per-job schedulers."""
        fused, C = self.program, self.capacity
        J = len(self._slots)
        npdtype = jnp.dtype(fused.value_dtype)
        task = np.zeros(C, np.int32)
        argi = np.zeros((C, fused.n_arg_i), np.int32)
        argf = np.zeros((C, fused.n_arg_f), np.float32)
        epoch = np.zeros(C, np.int32)
        value = np.zeros((C, fused.value_width), npdtype)
        slot_job = np.full(C, J, np.int32)

        self._regions: List[_Region] = []
        self._heap: Dict[str, jnp.ndarray] = {}
        for slot, h in zip(self._slots, handles):
            slot_job[slot.base : slot.end] = slot.index
            if h is None or h.checkpoint is not None:
                # vacant region: TV slots stay zeroed (epoch 0 matches no
                # frontier), the tenant heap gets its declared-default
                # arrays so the fused program's traced steps see every
                # key; a tenant seats later via the admit/reseed path.
                # A *checkpointed* handle (preempted elsewhere, resuming
                # in this wave) is seated the same lazy way — its region
                # image restores through ``_restore_region`` once the
                # driver's runtime state exists, never by reseeding.
                for k, v in slot.program.init_heap().items():
                    self._heap[slot.prefix + k] = v
                self._regions.append(_Region(slot=slot))
                if h is not None:
                    self._restore_pending.append((slot.index, h))
                continue
            job = h.job
            tid = slot.task_offset + slot.program.task_id(job.initial.task)
            ai, af = pack_args(fused, job.initial.argi, job.initial.argf)
            task[slot.base] = tid
            argi[slot.base] = ai
            argf[slot.base] = af
            epoch[slot.base] = 1
            for k, v in slot.program.init_heap(**dict(job.heap_init)).items():
                self._heap[slot.prefix + k] = v
            sched = EpochScheduler(coalesce=self.coalesce)
            sched.reset(cen=1, start=slot.base, count=1)
            h.mark_running()
            self._regions.append(
                _Region(
                    slot=slot, handle=h, sched=sched, stats=JobStats(),
                    active_quota=job.quota,
                )
            )

        self._state = tvm.TVMState(
            task=jnp.asarray(task),
            argi=jnp.asarray(argi),
            argf=jnp.asarray(argf),
            epoch=jnp.asarray(epoch),
            value=jnp.asarray(value),
            child_base=jnp.zeros((C,), jnp.int32),
            child_count=jnp.zeros((C,), jnp.int32),
            next_free=jnp.asarray(max(s.base for s in self._slots) + 1,
                                  jnp.int32),
        )
        self._arena = tvm.JobArena(
            slot_job=jnp.asarray(slot_job),
            base=jnp.asarray([s.base for s in self._slots], jnp.int32),
            end=jnp.asarray([s.end for s in self._slots], jnp.int32),
            next=jnp.asarray([s.base + 1 for s in self._slots], jnp.int32),
        )

    @property
    def live(self) -> bool:
        return any(r.running for r in self._regions)

    def stats(self) -> RunStats:
        """Fleet-level stats: V_inf terms counted per fused dispatch."""
        return self._col.result()

    # ------------------------------------------------- streaming admission
    def admit(self, handle: JobHandle) -> bool:
        """Seed a queued job into a freed region, mid-flight.

        A region can be reused by any job whose program is *structurally
        equal* to the region's fused-in template (``Program.structural_hash``
        — same task/map/heap tables and task bytecode; the phase-2 trace is
        identical, so nothing retraces).  The new job may carry its own
        initial task, heap init, and a quota up to the region size.  Returns
        False when the driver is not currently admitting (see
        ``_admits_midflight``) or no compatible free region exists.

        The scan is shared by both drivers; only *how* a region is reseeded
        (``_seed_region``) differs — host scheduler stacks vs the resident
        carry's device stacks.
        """
        if not self._admits_midflight():
            return False
        job = handle.job
        for r in self._regions:
            if r.handle is not None:
                continue
            s = r.slot
            if job.quota > s.quota:
                continue
            if s.program is not job.program and (
                s.program.structural_hash() != job.program.structural_hash()
            ):
                continue
            if handle.checkpoint is not None:
                self._restore_region(r, handle)
            else:
                self._seed_region(r, handle)
            return True
        return False

    def _admits_midflight(self) -> bool:
        return True

    def _seed_region(self, r: _Region, handle: JobHandle) -> None:
        raise NotImplementedError

    # --------------------------------------------------------- preemption
    def preempt(self, handle: JobHandle) -> bool:
        """Evict a RUNNING job at the current boundary (DESIGN.md §16).

        The job's region — TV columns, tenant heap, arena cursor, stack
        entries, accumulators — lifts into an engine-agnostic
        :class:`~repro.service.jobs.RegionCheckpoint` on the handle, the
        region is vacated (free for admission), and the handle moves to
        PREEMPTED.  Re-admitting the handle (same wave later, or any other
        wave whose layout fits) restores the image through
        ``_restore_region`` and the job continues bit-identically to an
        uninterrupted run.  Returns False when the driver is not at a
        yield point (``_admits_midflight`` — e.g. a fully resident wave)
        or the handle is not running here.
        """
        if not self._admits_midflight():
            return False
        for j, r in enumerate(self._regions):
            if r.handle is handle and r.running:
                cp = self._capture_region(j)
                self._release(j)
                self._vacate(j)
                handle.mark_preempted(cp)
                return True
        return False

    def running_handles(self) -> List[JobHandle]:
        """The handles currently seated in this wave's regions."""
        return [r.handle for r in self._regions if r.running]

    def _capture_region(self, j: int) -> RegionCheckpoint:
        raise NotImplementedError

    def _restore_region(self, r: _Region, handle: JobHandle) -> None:
        raise NotImplementedError

    def _vacate(self, j: int) -> None:
        """Driver-specific cleanup after a region's tenant was captured
        (the host driver needs none: with the scheduler gone, the stale
        TV content is unreachable — no pop ever targets the region)."""

    def _capture_tv(self, r: _Region):
        """The TVM half of a capture, shared by both drivers: the job's
        TV columns (position-dependent ones region-relative), its tenant
        heap (namespace stripped), and the arena cursor offset.

        Task codes are stored relative to the slot's fuse-time task-table
        offset and ``child_base`` relative to the region base — the
        restore target may be a different slot of a different fused
        program.  Lanes never written (epoch 0 / no children) are stored
        as zeros rather than translated: they are inert either way (the
        TMS epoch check skips them) and zeros keep the image independent
        of the source wave's offsets.
        """
        s = r.slot
        sub = s.program
        q = r.active_quota
        tgt = slice(s.base, s.base + q)
        epoch = np.asarray(self._state.epoch[tgt], np.int32)
        task = np.asarray(self._state.task[tgt], np.int32)
        child_count = np.asarray(self._state.child_count[tgt], np.int32)
        child_base = np.asarray(self._state.child_base[tgt], np.int32)
        tv = {
            "epoch": epoch,
            "task_rel": np.where(
                epoch > 0, task - s.task_offset, 0
            ).astype(np.int32),
            "argi": np.asarray(
                self._state.argi[tgt, : sub.n_arg_i], np.int32
            ),
            "argf": np.asarray(
                self._state.argf[tgt, : sub.n_arg_f], np.float32
            ),
            "value": np.asarray(
                self._state.value[tgt, : sub.value_width]
            ),
            "child_count": child_count,
            "child_base_rel": np.where(
                child_count > 0, child_base - s.base, 0
            ).astype(np.int32),
        }
        heap = {hv.name: self._heap[s.prefix + hv.name] for hv in sub.heap}
        next_off = int(np.asarray(self._arena.next)[s.index]) - s.base
        return tv, heap, next_off

    def _restore_state(self, state: tvm.TVMState, slot: TenantSlot,
                       cp: RegionCheckpoint) -> tvm.TVMState:
        """The TVM half of a restore: clear the slot region (as
        ``_seed_state`` does) and write the checkpoint image shifted to
        this slot's base and task-table offset, padded to this fused
        program's argument/value widths (the tenant's own columns are a
        prefix; padding stays zero, exactly the fuse-time layout)."""
        fused = self.program
        sl = slice(slot.base, slot.end)
        q = cp.quota
        tgt = slice(slot.base, slot.base + q)
        epoch = cp.tv["epoch"]
        task = np.where(
            epoch > 0, cp.tv["task_rel"] + slot.task_offset, 0
        ).astype(np.int32)
        cb = np.where(
            cp.tv["child_count"] > 0,
            cp.tv["child_base_rel"] + slot.base, 0,
        ).astype(np.int32)
        argi = np.zeros((q, fused.n_arg_i), np.int32)
        argi[:, : cp.tv["argi"].shape[1]] = cp.tv["argi"]
        argf = np.zeros((q, fused.n_arg_f), np.float32)
        argf[:, : cp.tv["argf"].shape[1]] = cp.tv["argf"]
        value = np.zeros((q, fused.value_width), jnp.dtype(fused.value_dtype))
        value[:, : cp.tv["value"].shape[1]] = cp.tv["value"]
        return tvm.TVMState(
            task=state.task.at[sl].set(0).at[tgt].set(jnp.asarray(task)),
            argi=state.argi.at[sl].set(0).at[tgt].set(jnp.asarray(argi)),
            argf=state.argf.at[sl].set(0.0).at[tgt].set(jnp.asarray(argf)),
            epoch=state.epoch.at[sl].set(0).at[tgt].set(jnp.asarray(epoch)),
            value=state.value.at[sl].set(0).at[tgt].set(jnp.asarray(value)),
            child_base=state.child_base.at[sl].set(0).at[tgt].set(
                jnp.asarray(cb)),
            child_count=state.child_count.at[sl].set(0).at[tgt].set(
                jnp.asarray(cp.tv["child_count"])),
            next_free=state.next_free,
        )

    def _seed_state(self, state: tvm.TVMState, slot: TenantSlot,
                    job: Job) -> tvm.TVMState:
        """Clear a freed slot region and seed the new tenant's root task —
        the TVM half of region reuse, shared by both drivers."""
        sub = slot.program
        sl = slice(slot.base, slot.end)
        tid = slot.task_offset + sub.task_id(job.initial.task)
        ai, af = pack_args(self.program, job.initial.argi, job.initial.argf)
        return tvm.TVMState(
            task=state.task.at[sl].set(0).at[slot.base].set(tid),
            argi=state.argi.at[sl].set(0).at[slot.base].set(jnp.asarray(ai)),
            argf=state.argf.at[sl].set(0.0).at[slot.base].set(
                jnp.asarray(af)),
            epoch=state.epoch.at[sl].set(0).at[slot.base].set(1),
            value=state.value.at[sl].set(0),
            child_base=state.child_base.at[sl].set(0),
            child_count=state.child_count.at[sl].set(0),
            next_free=state.next_free,
        )

    # ------------------------------------------------- completion / release
    def _finalize(self, j: int) -> JobHandle:
        """Extract the region's solo-equivalent result; free the region."""
        r = self._regions[j]
        s = r.slot
        sub = s.program
        value = self._state.value[
            s.base : s.base + r.active_quota, : sub.value_width
        ]
        heap = {
            hv.name: self._heap[s.prefix + hv.name] for hv in sub.heap
        }
        r.handle.result = JobResult(heap=heap, value=value, stats=r.stats)
        r.handle.status = JobStatus.DONE
        r.handle.mark_finished()
        return self._release(j)

    def _fail(self, j: int, reason: Optional[str] = None) -> JobHandle:
        r = self._regions[j]
        r.handle.error = JobFailure(
            reason
            or f"job {r.handle.job.name!r} overflowed its region: "
               f"quota={r.active_quota}"
        )
        r.handle.status = JobStatus.FAILED
        r.handle.mark_finished()
        return self._release(j)

    def _release(self, j: int) -> JobHandle:
        r = self._regions[j]
        h = r.handle
        r.handle = None
        r.sched = None
        r.stats = None
        r.active_quota = 0
        return h


# --------------------------------------------------------------------------
# Host-loop driver
# --------------------------------------------------------------------------
class EpochMultiplexer(_FleetBase):
    """Co-schedule a fleet of jobs inside one shared TVM (host loop).

    Each global epoch: select a gang of ready jobs (``pop_policy``), pop one
    dispatch from each job's own scheduler, fuse the ranges into a single
    launch over their covering span with a per-lane epoch-number vector
    (lanes outside every popped range carry 0 and stay inactive), commit
    with the :class:`~repro.core.tvm.JobArena` segmented allocator, and read
    back one fused :class:`~repro.core.tvm.MuxEpochSummary`.  Dispatch +
    readback are counted once per global epoch — the fleet's V_inf — while
    each job's scheduler sees exactly the solo sequence of pops and pushes.
    """

    def __init__(
        self,
        handles: Sequence[JobHandle],
        capacity: Optional[int] = None,
        dispatch: Any = "masked",
        coalesce: bool = True,
        pop_policy: Any = "fuse_all",
        gang: int = 0,
        collect_stats: bool = True,
        stats_factory=None,
        rank_fn=None,
        pack_fn=None,
        seg_offsets_fn=None,
        tracer=None,
        controller=None,
    ):
        super().__init__(
            handles, capacity=capacity, coalesce=coalesce,
            collect_stats=collect_stats, stats_factory=stats_factory,
        )
        self.pop_policy = resolve_mux_policy(pop_policy, gang)
        self._loop = EpochLoop(
            self.program, dispatch,
            rank_fn=rank_fn, pack_fn=pack_fn, seg_offsets_fn=seg_offsets_fn,
            # fused fleets have many task types but type-homogeneous epochs
            # stay common, so idle types skip via lax.cond
            skip_idle_types=True,
            tracer=tracer, controller=controller,
        )
        self.tracer = self._loop.tracer
        self.policy = self._loop.policy
        self.controller = self._loop.controller
        self._rotor = 0
        self._global_epochs = 0
        # resume preempted members: the host driver's runtime state is
        # fully built by now, so checkpointed wave members restore here
        for j, h in self._restore_pending:
            self._restore_region(self._regions[j], h)
        self._restore_pending = []

    @staticmethod
    def _readback(summary, state):
        # one fused readback for the whole fleet (the cross-tenant V_inf win)
        return (
            summary.job_forks, summary.job_join, summary.job_active,
            summary.job_overflow, summary.job_next, summary.map_scheduled,
        )

    # ------------------------------------------------------------ stepping
    def step(self) -> List[JobHandle]:
        """Run one fused global epoch; return handles that completed."""
        ready = [
            j for j, r in enumerate(self._regions) if r.running and r.sched
        ]
        if not ready:
            return []
        depths = [len(self._regions[j].sched) for j in ready]
        chosen = self.pop_policy.select(ready, depths, self._rotor)
        self._rotor += 1
        self._global_epochs += 1
        col = self._col

        pops = {j: self._regions[j].sched.pop() for j in chosen}
        lo = min(d.start for d in pops.values())
        hi = max(d.start + d.count for d in pops.values())
        cen_np = np.zeros(hi - lo, np.int32)
        for d in pops.values():
            cen_np[d.start - lo : d.start - lo + d.count] = d.cen

        tr = self.tracer
        if tr.enabled:
            tr.thread(1, "host-epochs")
        with tr.span(
            "epoch", "host", tid=1,
            epoch=self._global_epochs, jobs=len(chosen), span=hi - lo,
            mode=self.policy.name,
        ) as sargs:
            (self._state, self._heap, summary, fetched, map_launches,
             launched, by_type, shared_dispatches) = self._loop.run_epoch(
                self._state, self._heap, self._arena, lo, hi - lo, cen_np,
                col, self._readback,
            )
            job_forks, job_join, job_active, job_overflow, job_next, \
                map_sched = fetched
            # dispatch="auto" feedback: the fused readback's active count
            # vs the full frontier width seeds the next epoch's decision
            if self._loop.controller is not None:
                self._loop.controller.observe(
                    int(job_active.sum()), self._loop.last_span_bucket
                )
            if tr.enabled:
                n_act = int(job_active.sum())
                dec = self._loop.last_decision
                sargs.update(
                    launched=launched, active=n_act,
                    util=n_act / max(1, launched),
                    **({"mode": dec.mode, "auto_reason": dec.reason}
                       if dec is not None else {}),
                )
        # the region cursors advance on device; only the readback copy above
        # crosses to the host
        self._arena = dataclasses.replace(self._arena, next=summary.job_next)

        done: List[JobHandle] = []
        for j in chosen:
            r = self._regions[j]
            d = pops[j]
            if bool(job_overflow[j]):
                done.append(self._fail(j))
                continue
            if bool(job_join[j]):
                r.sched.push_join(d.cen, d.start, d.count)
            forks = int(job_forks[j])
            r.sched.push_forked(d.cen + 1, int(job_next[j]) - forks, forks)
            st = r.stats
            st.epochs += 1
            st.tasks_executed += int(job_active[j])
            st.total_forks += forks
            st.peak_tv_slots = max(
                st.peak_tv_slots, int(job_next[j]) - r.slot.base
            )
            st.shared_dispatches += shared_dispatches
            st.shared_transfers += shared_dispatches

        if bool(map_sched):
            self._heap = self._loop.maps.run(map_launches, self._heap, col)

        col.epoch(self._global_epochs,
                  sum(d.n_ranges for d in pops.values()))
        col.lanes(int(job_active.sum()), launched, by_type)
        col.forks(int(job_forks.sum()))
        col.tv_peak(int(job_next.max()))

        for j in chosen:
            r = self._regions[j]
            if r.running and not r.sched:
                done.append(self._finalize(j))
        return done

    def run(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        """Drive every admitted job to completion; return finished handles."""
        out: List[JobHandle] = []
        while self.live:
            if self._global_epochs >= max_epochs:
                raise RuntimeError(f"exceeded max_epochs={max_epochs}")
            out.extend(self.step())
        return out

    # ------------------------------------------------- streaming admission
    def _seed_region(self, r: _Region, handle: JobHandle) -> None:
        """Clear a freed region and seed the new tenant's root task."""
        job = handle.job
        s = r.slot
        self._state = self._seed_state(self._state, s, job)
        self._arena = tvm.arena_reset_region(
            self._arena, s.index, s.base, job.quota
        )
        for k, v in s.program.init_heap(**dict(job.heap_init)).items():
            self._heap[s.prefix + k] = v
        sched = EpochScheduler(coalesce=self.coalesce)
        sched.reset(cen=1, start=s.base, count=1)
        r.handle = handle
        r.sched = sched
        r.stats = JobStats()
        r.active_quota = job.quota
        handle.mark_running()

    # --------------------------------------------------------- preemption
    def _capture_region(self, j: int) -> RegionCheckpoint:
        r = self._regions[j]
        tv, heap, next_off = self._capture_tv(r)
        cens, ranges = r.sched.export_stack()
        ranges = ranges.copy()
        if ranges.size:
            ranges[:, 0] -= r.slot.base
        st = dataclasses.replace(r.stats)
        return RegionCheckpoint(
            structural_hash=r.slot.program.structural_hash(),
            quota=r.active_quota,
            tv=tv, heap=heap, arena_next_off=next_off,
            sp=len(cens), jstack=cens, rstack=ranges,
            job_epochs=st.epochs, job_tasks=st.tasks_executed,
            job_forks=st.total_forks, job_peak=st.peak_tv_slots,
            stats=st,
        )

    def _restore_region(self, r: _Region, handle: JobHandle) -> None:
        """Seat a preempted job's checkpoint into a freed region: the TV
        image shifts to this region's base/offsets, the arena cursor
        resumes where it left off, and the scheduler stacks reload — the
        dual of ``_seed_region`` with the checkpoint as the seed."""
        cp = handle.checkpoint
        s = r.slot
        self._state = self._restore_state(self._state, s, cp)
        arena = tvm.arena_reset_region(self._arena, s.index, s.base, cp.quota)
        self._arena = dataclasses.replace(
            arena, next=arena.next.at[s.index].set(s.base + cp.arena_next_off)
        )
        for k, v in cp.heap.items():
            self._heap[s.prefix + k] = v
        sched = EpochScheduler(coalesce=self.coalesce)
        ranges = np.asarray(cp.rstack, np.int32).reshape(-1, 2).copy()
        if ranges.size:
            ranges[:, 0] += s.base
        sched.load_stack(cp.jstack, ranges)
        r.handle = handle
        r.sched = sched
        r.stats = (
            cp.stats if cp.stats is not None
            else JobStats(
                epochs=cp.job_epochs, tasks_executed=cp.job_tasks,
                total_forks=cp.job_forks, peak_tv_slots=cp.job_peak,
            )
        )
        r.active_quota = cp.quota
        handle.checkpoint = None
        handle.mark_running()


# --------------------------------------------------------------------------
# Chunked resident driver
# --------------------------------------------------------------------------
class _ChunkLedger:
    """Fleet totals already credited to the stats collector.

    Each chunk boundary accounts only its *delta* against these, so
    re-reading the carry's monotone accumulators can never double-count and
    an empty trailing chunk credits nothing.  Per-region entries zero when
    a region is reseeded with a new tenant (the carry's accumulators zero
    at the same moment).
    """

    def __init__(self, n_regions: int):
        self.epochs = 0
        self.job_epochs = np.zeros(n_regions, np.int64)
        self.job_tasks = np.zeros(n_regions, np.int64)
        self.job_forks = np.zeros(n_regions, np.int64)
        self.map_launches = 0
        self.map_elements = 0
        self.map_lanes = 0
        self.hole_lanes = 0


class DeviceMultiplexer(_FleetBase):
    """Chunked device-resident wave execution (DESIGN.md §9–10).

    The admitted fleet runs inside a ``lax.while_loop`` — per-region
    scheduler stacks on device (``batched_device_stacks``), the
    :class:`~repro.core.tvm.JobArena` region cursors and per-region
    trailing reclamation riding the loop carry, every region's pop fused
    into one per-lane epoch-number vector per iteration — for at most
    ``chunk`` (K) epochs per invocation.  At each chunk boundary the host
    fetches one compact :class:`~repro.core.engine.ChunkSummary`; a wave of
    E epochs therefore pays ⌈E/K⌉ dispatches + readbacks, and between
    chunks the host:

      * **streams completions** — regions whose stack drained surface
        immediately, not when the whole wave ends;
      * **reseeds freed regions** — ``admit`` seats a structurally-equal
        queued job into the live carry (TV slots, heap, arena cursors,
        stack row, accumulators), and the re-entered loop simply sees one
        more live region — no retrace, the compiled chunk template is
        reused verbatim.

    ``chunk=None`` is the fully-resident endpoint (K=∞): one chunk for the
    whole wave, O(1) V_inf, the host blind until it drains — and ``admit``
    refuses, because there are no boundaries to admit at.  ``chunk=1`` is
    host-mux readback cadence.  Masked and gather dispatches only
    (resident launch shapes are fixed at trace time — gather packs into a
    fixed-shape segmented frontier, DESIGN.md §12; compacted sizes
    launches from runtime populations and stays host-only); every live
    region pops each global epoch (``fuse_all``).  ``megakernel=True``
    runs each chunk as one persistent Pallas kernel
    (``kernels/epoch_megakernel.py``) instead of the XLA ``while_loop`` —
    bit-identical, same ⌈E/K⌉ readback cadence.  A job overflowing its
    region (TV quota or stack depth) fails alone, mid-chunk: its stack
    pointer zeroes and its neighbours keep running.  Per-job results are
    bit-identical to solo ``HostEngine.run`` at every K.
    """

    def __init__(
        self,
        handles: Sequence[JobHandle],
        capacity: Optional[int] = None,
        dispatch: Any = "masked",
        stack_depth: int = 1 << 10,
        chunk: Any = None,
        collect_stats: bool = True,
        stats_factory=None,
        seg_offsets_fn=None,
        template=None,
        megakernel: bool = False,
        megakernel_impl: str = "auto",
        tracer=None,
        controller=None,
        chunk_controller=None,
        queue_probe=None,
    ):
        super().__init__(
            handles, capacity=capacity,
            collect_stats=collect_stats, stats_factory=stats_factory,
            template=template,
        )
        # dispatch="auto" resolves once, against the controller's rolling
        # window, before anything is traced: a resident loop bakes its mode
        # in (DESIGN.md §14).  The service layer makes the outcome sticky
        # per wave shape through the template cache.
        self._dispatch_controller = controller
        dispatch = resolve_resident_dispatch(
            dispatch, controller, self.capacity
        )
        policy = resolve_policy(dispatch)
        if policy.name not in ("masked", "gather"):
            raise ValueError(_COMPACTED_RESIDENT_MSG)
        # chunk="auto": a ChunkController owns K, re-decided at every chunk
        # boundary from completions + queue heat.  K only ever feeds the
        # dynamic `limit` argument of the one compiled chunk template, so
        # adaptation is retrace-free by construction.
        self._kctl = None
        self._queue_probe = queue_probe
        if chunk == "auto":
            self._kctl = chunk_controller or ChunkController()
        elif isinstance(chunk, str):
            raise ValueError(
                f"chunk must be an int >= 1, None, or 'auto'; got {chunk!r}"
            )
        elif chunk is not None and chunk < 1:
            raise ValueError(
                "chunk must be >= 1 epoch, or None for a fully resident "
                f"wave; got {chunk}"
            )
        self.stack_depth = stack_depth
        self.chunk = chunk
        if template is not None:
            if seg_offsets_fn is not None:
                raise ValueError(
                    "seg_offsets_fn cannot be overridden on a template "
                    "wave: the template's loop was already traced with its "
                    "own fork-scan kernel (build the template with the "
                    "desired seg_offsets_fn instead)"
                )
            if template.loop.policy.name != policy.name:
                raise ValueError(
                    "wave template was traced with dispatch "
                    f"{template.loop.policy.name!r} but this wave asks for "
                    f"{policy.name!r}: a cached chunk template bakes its "
                    "dispatch into the traced loop (key on dispatch when "
                    "caching templates)"
                )
            if template.loop.megakernel != bool(megakernel):
                raise ValueError(
                    "wave template was traced with megakernel="
                    f"{template.loop.megakernel} but this wave asks for "
                    f"megakernel={bool(megakernel)}: the chunk driver is "
                    "baked into the template (key on megakernel when "
                    "caching templates)"
                )
            self._loop: EpochLoop = template.loop
        else:
            self._loop = EpochLoop(
                self.program, dispatch,
                seg_offsets_fn=seg_offsets_fn, skip_idle_types=True,
                megakernel=megakernel, megakernel_impl=megakernel_impl,
            )
        self.policy = self._loop.policy
        # the mux owns its tracer rather than the (possibly template-shared)
        # loop: resident spans are emitted at chunk boundaries on the host
        # side, so a cached template can serve waves traced and untraced
        self.tracer = tracer or NULL_TRACER
        self._carry = None
        self._chunk_seq = 0
        self._ledger = _ChunkLedger(len(self._slots))
        self.last_deltas: Dict[str, int] = {}

    @property
    def loop(self) -> EpochLoop:
        """The driver core (owner of the compiled chunk template)."""
        return self._loop

    @property
    def slots(self):
        """Fuse-time slot layout (for wave-template capture)."""
        return self._slots

    # ------------------------------------------------------------ driving
    def _ensure_carry(self) -> None:
        """Build the resident carry on first use: a seated region's device
        stack gets its seed entry (sp=1), a *vacant* region (handle=None,
        sharded-fleet shards) starts empty (sp=0) — its tenant seats later
        through the admit/reseed path, so a shard's initial seating and
        its mid-flight reseeds are one code path."""
        if self._carry is not None:
            return
        J = len(self._slots)
        jstack, rstack, sp = batched_device_stacks(
            J, self.stack_depth,
            cens=np.ones(J, np.int32),
            starts=np.asarray([s.base for s in self._slots], np.int32),
            counts=np.ones(J, np.int32),
        )
        seated = np.asarray(
            [r.handle is not None for r in self._regions], np.int32
        )
        sp = sp * jnp.asarray(seated)
        self._carry = _fresh_resident_carry(
            self._state, self._heap, self._arena, jstack, rstack, sp,
            n_regions=J,
        )

    def _chunk_limit(self, max_epochs: int) -> int:
        """This chunk's dynamic epoch bound: the guard for a fully
        resident wave, else the ledger's epoch watermark plus K (the
        controller's K under ``chunk="auto"``)."""
        if self.chunk is None:
            return max_epochs
        k = self._kctl.current() if self._kctl is not None else self.chunk
        return min(max_epochs, self._ledger.epochs + k)

    def _attach_carry(self, carry) -> None:
        """Adopt a post-chunk carry: the bulk state stays on device; these
        references keep ``_finalize`` / ``_seed_region`` working on the
        current wave state."""
        self._carry = carry
        self._state, self._heap, self._arena = (
            carry.state, carry.heap, carry.arena
        )

    def _finish_chunk(self, s: ChunkSummary, riders: List[int],
                      max_epochs: int) -> List[JobHandle]:
        """Account one chunk's readback and settle its riders — shared by
        :meth:`step` and the sharded fleet's collective step (which runs
        the chunk itself, P shards fused, then finishes each shard here).
        Leaves the delta terms in ``last_deltas`` for span args."""
        deltas = self._account(s, riders)
        self.last_deltas = deltas
        # dispatch-controller feedback: the chunk is the finest observable
        # grain on this driver — one fill observation per boundary, against
        # the full-TV width (tasks / (lanes + holes))
        if self._dispatch_controller is not None and deltas["epochs"] > 0:
            self._dispatch_controller.observe(
                deltas["tasks"], deltas["lanes"] + deltas["holes"]
            )
        return self._settle(s, riders, max_epochs)

    def step(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        """Run one chunk — at most ``chunk`` epochs in one resident loop
        invocation (the whole wave when ``chunk`` is None) — then surface
        every region that drained or failed.

        Further calls continue the wave from the carried device state; once
        nothing is live, calls are clean no-ops that touch neither the
        device nor the stats ledger.
        """
        if self._restore_pending:
            # wave members resuming from preemption: build the carry, then
            # write each checkpoint image into its region
            self._ensure_carry()
            for j, h in self._restore_pending:
                self._restore_region(self._regions[j], h)
            self._restore_pending = []
        riders = [j for j, r in enumerate(self._regions) if r.running]
        if not riders:
            return []
        J = len(self._slots)
        self._ensure_carry()
        limit = self._chunk_limit(max_epochs)
        tr = self.tracer
        if tr.enabled:
            tr.thread(2, "resident")
        self._chunk_seq += 1
        # one "chunk" span per resident loop invocation, with the chunk's
        # single dispatch and readback as children — a wave of E epochs
        # renders as exactly ⌈E/K⌉ readback spans, the V_inf cadence made
        # countable.  Per-epoch detail inside the chunk is unobservable by
        # design (no readbacks to hang spans on); the deltas the readback
        # reveals are attached to the span's args instead.
        with tr.span(
            "chunk", "resident", tid=2,
            seq=self._chunk_seq, jobs=len(riders),
            k=(self._kctl.current() if self._kctl is not None
               else self.chunk),
            mode=self.policy.name, megakernel=self._loop.megakernel,
        ) as sargs:
            with tr.span("dispatch", "resident", tid=2), tr.annotation(
                "trees:resident_chunk"
            ):
                carry = self._loop.run_chunk(self._carry, limit, n_regions=J)
            self._attach_carry(carry)
            # the chunk's one readback (XLA launches are async: the dispatch
            # span above is enqueue time, this wait is the real chunk)
            with tr.span("readback", "resident", tid=2):
                s = self._loop.chunk_summary(carry)
            done = self._finish_chunk(s, riders, max_epochs)
            if tr.enabled:
                sargs.update(self.last_deltas)
        # chunk-controller feedback: widen K while boundaries surface no
        # completions, shrink while the job queue runs hot or the nearest
        # deadline tightens (the probe's optional third element)
        if self._kctl is not None:
            queued, oldest, slack = (0, 0.0, None)
            if self._queue_probe is not None:
                probe = self._queue_probe()
                queued, oldest = probe[0], probe[1]
                if len(probe) > 2:
                    slack = probe[2]
            if slack is None:
                self._kctl.observe(len(done), queued, oldest)
            else:
                self._kctl.observe(
                    len(done), queued, oldest, deadline_slack=slack
                )
        return done

    def run(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        """Drive the wave to completion, chunk by chunk; API parity with
        :class:`EpochMultiplexer`."""
        out: List[JobHandle] = []
        while self.live:
            out.extend(self.step(max_epochs=max_epochs))
        return out

    # --------------------------------------------------------- accounting
    def _account(self, s: ChunkSummary, riders: List[int]) -> Dict[str, int]:
        """Credit this chunk's delta to the fleet collector and to every
        region that rode the chunk's fused launch; returns the delta terms
        (the chunk span's trace args)."""
        col = self._col
        col.dispatch()
        col.transfer()
        for j in riders:
            self._regions[j].stats.shared_dispatches += 1
            self._regions[j].stats.shared_transfers += 1
        led = self._ledger
        d_epochs = s.n_epochs - led.epochs
        d_holes = s.hole_lanes - led.hole_lanes
        d_tasks = int((s.job_tasks - led.job_tasks).sum())
        d_lanes = d_epochs * self.capacity - d_holes
        if d_epochs > 0:
            # every global epoch fused all regions live then; bulk O(1)
            # accounting from the readback, same ledger semantics as the
            # host driver's per-epoch calls.  The task launches were
            # span-bucketed on device, so launched lanes are the full-TV
            # total minus the hole lanes the ladder skipped.  Holes are
            # reported *before* the matching lanes() call (the pairing the
            # metrics adapter's hole-fraction fold relies on — the host
            # gather path keeps the same order).
            col.epoch(
                s.n_epochs,
                n_ranges=int((s.job_epochs - led.job_epochs).sum()),
                n=d_epochs,
            )
            col.holes_skipped(d_holes)
            col.lanes(d_tasks, d_lanes, None)
            col.forks(int((s.job_forks - led.job_forks).sum()))
        bases = np.asarray([sl.base for sl in self._slots])
        col.tv_peak(int((s.job_peak + bases).max()))
        d_maps = s.map_launches - led.map_launches
        if d_maps > 0:
            col.map_launch(
                s.map_elements - led.map_elements,
                s.map_lanes - led.map_lanes, n=d_maps,
            )
        led.epochs = s.n_epochs
        led.job_epochs = s.job_epochs.astype(np.int64)
        led.job_tasks = s.job_tasks.astype(np.int64)
        led.job_forks = s.job_forks.astype(np.int64)
        led.map_launches = s.map_launches
        led.map_elements = s.map_elements
        led.map_lanes = s.map_lanes
        led.hole_lanes = s.hole_lanes
        return {
            "epochs": d_epochs, "tasks": d_tasks, "lanes": d_lanes,
            "holes": d_holes, "maps": d_maps,
        }

    def _settle(self, s: ChunkSummary, riders: List[int],
                max_epochs: int) -> List[JobHandle]:
        """Surface every rider whose region drained, failed, or hit the
        epoch guard; regions still mid-flight stay RUNNING for the next
        chunk."""
        done: List[JobHandle] = []
        for j in riders:
            r = self._regions[j]
            # a region still holding stack entries at the guard has an
            # unfinished schedule: fail it (like an overflow) so the wave
            # always resolves every handle, never wedged RUNNING
            timed_out = bool(s.sp[j] > 0) and s.n_epochs >= max_epochs
            if s.sp[j] > 0 and not timed_out:
                continue
            st = r.stats
            st.epochs = int(s.job_epochs[j])
            st.tasks_executed = int(s.job_tasks[j])
            st.total_forks = int(s.job_forks[j])
            st.peak_tv_slots = int(s.job_peak[j])
            if bool(s.failed[j]) or timed_out:
                if timed_out:
                    reason = f"exceeded max_epochs={max_epochs}"
                elif bool(s.failed_stack[j]):
                    reason = (
                        f"job {r.handle.job.name!r} exhausted the resident "
                        f"scheduler stack: stack_depth={self.stack_depth}"
                    )
                else:
                    reason = None  # TV region overflow: the default message
                done.append(self._fail(j, reason=reason))
            else:
                done.append(self._finalize(j))
        return done

    # ------------------------------------------------- streaming admission
    def _admits_midflight(self) -> bool:
        # a fully resident wave (chunk=None) is closed: the host never sees
        # a freed region until the whole wave drains.  With a finite chunk
        # the host holds the carry between chunks, so freed regions reseed.
        return self.chunk is not None and self._carry is not None and self.live

    def _seed_region(self, r: _Region, handle: JobHandle) -> None:
        """Reseed a freed region *into the live carry* between chunks: TV
        slots, tenant heap, arena cursors, the region's device stack row,
        and its accumulators — the next chunk's ``while_loop`` simply sees
        one more live region."""
        job = handle.job
        s = r.slot
        j = s.index
        carry = self._carry
        state = self._seed_state(carry.state, s, job)
        heap = dict(carry.heap)
        for k, v in s.program.init_heap(**dict(job.heap_init)).items():
            heap[s.prefix + k] = v
        arena = tvm.arena_reset_region(carry.arena, j, s.base, job.quota)
        jstack, rstack, sp = reseed_region_stacks(
            carry.jstack, carry.rstack, carry.sp, j,
            cen=1, start=s.base, count=1,
        )
        self._carry = dataclasses.replace(
            carry, state=state, heap=heap, arena=arena,
            jstack=jstack, rstack=rstack, sp=sp,
            failed=carry.failed.at[j].set(False),
            failed_stack=carry.failed_stack.at[j].set(False),
            job_epochs=carry.job_epochs.at[j].set(0),
            job_tasks=carry.job_tasks.at[j].set(0),
            job_forks=carry.job_forks.at[j].set(0),
            job_peak=carry.job_peak.at[j].set(0),
        )
        self._state, self._heap, self._arena = state, heap, arena
        led = self._ledger
        led.job_epochs[j] = led.job_tasks[j] = led.job_forks[j] = 0
        r.handle = handle
        r.sched = None
        r.stats = JobStats()
        r.active_quota = job.quota
        handle.mark_running()

    # --------------------------------------------------------- preemption
    def _capture_region(self, j: int) -> RegionCheckpoint:
        """Lift region ``j`` off the live carry at a chunk boundary: TV
        image + heap + arena cursor (shared helper), this region's device
        stack row (starts made region-relative), and the carry's
        solo-comparable accumulators (hi/lo pairs decoded to ints)."""
        r = self._regions[j]
        tv, heap, next_off = self._capture_tv(r)
        carry = self._carry
        sp = int(np.asarray(carry.sp)[j])
        jst = np.asarray(carry.jstack)[j, :sp].astype(np.int32)
        rst = np.asarray(carry.rstack)[j, :sp].astype(np.int32).copy()
        if rst.size:
            rst[:, 0] -= r.slot.base
        epochs = int(np.asarray(carry.job_epochs)[j])
        tasks = int(_hilo_value(np.asarray(carry.job_tasks)[j]))
        forks = int(_hilo_value(np.asarray(carry.job_forks)[j]))
        peak = int(np.asarray(carry.job_peak)[j])
        st = dataclasses.replace(
            r.stats, epochs=epochs, tasks_executed=tasks,
            total_forks=forks, peak_tv_slots=peak,
        )
        return RegionCheckpoint(
            structural_hash=r.slot.program.structural_hash(),
            quota=r.active_quota,
            tv=tv, heap=heap, arena_next_off=next_off,
            sp=sp, jstack=jst, rstack=rst,
            job_epochs=epochs, job_tasks=tasks,
            job_forks=forks, job_peak=peak,
            stats=st,
        )

    def _vacate(self, j: int) -> None:
        # parking a vacated region is one scalar: sp=0 makes it inert (no
        # pops, so the stale TV content is unreachable — lanes only run
        # when a popped range's CEN matches their epoch).  Accumulator
        # rows are left as-is: they still match the ledger rows, so chunk
        # deltas stay zero until a reseed/restore rewrites both sides.
        carry = self._carry
        self._carry = dataclasses.replace(
            carry, sp=carry.sp.at[j].set(0)
        )

    def _restore_region(self, r: _Region, handle: JobHandle) -> None:
        """Write a checkpoint image into a freed region of the live carry:
        the between-chunks dual of ``_seed_region``, restoring TV, heap,
        arena cursor, the whole stack row, and the accumulator rows (hi/lo
        re-encoded) — with the ledger rows set to match, so the next
        chunk's delta accounting credits only new work (the pre-preemption
        work was already credited when it happened)."""
        cp = handle.checkpoint
        s = r.slot
        j = s.index
        carry = self._carry
        state = self._restore_state(carry.state, s, cp)
        heap = dict(carry.heap)
        for k, v in cp.heap.items():
            heap[s.prefix + k] = v
        arena = tvm.arena_reset_region(carry.arena, j, s.base, cp.quota)
        arena = dataclasses.replace(
            arena, next=arena.next.at[j].set(s.base + cp.arena_next_off)
        )
        ranges = np.asarray(cp.rstack, np.int32).reshape(-1, 2).copy()
        if ranges.size:
            ranges[:, 0] += s.base
        jstack, rstack, sp = load_region_stacks(
            carry.jstack, carry.rstack, carry.sp, j, cp.jstack, ranges
        )
        t_hi, t_lo = divmod(int(cp.job_tasks), _HILO_BASE)
        f_hi, f_lo = divmod(int(cp.job_forks), _HILO_BASE)
        self._carry = dataclasses.replace(
            carry, state=state, heap=heap, arena=arena,
            jstack=jstack, rstack=rstack, sp=sp,
            failed=carry.failed.at[j].set(False),
            failed_stack=carry.failed_stack.at[j].set(False),
            job_epochs=carry.job_epochs.at[j].set(cp.job_epochs),
            job_tasks=carry.job_tasks.at[j].set(
                jnp.asarray([t_hi, t_lo], jnp.int32)),
            job_forks=carry.job_forks.at[j].set(
                jnp.asarray([f_hi, f_lo], jnp.int32)),
            job_peak=carry.job_peak.at[j].set(cp.job_peak),
        )
        self._state, self._heap, self._arena = state, heap, arena
        led = self._ledger
        led.job_epochs[j] = cp.job_epochs
        led.job_tasks[j] = cp.job_tasks
        led.job_forks[j] = cp.job_forks
        r.handle = handle
        r.sched = None
        r.stats = (
            cp.stats if cp.stats is not None
            else JobStats(
                epochs=cp.job_epochs, tasks_executed=cp.job_tasks,
                total_forks=cp.job_forks, peak_tv_slots=cp.job_peak,
            )
        )
        r.active_quota = cp.quota
        handle.checkpoint = None
        handle.mark_running()
