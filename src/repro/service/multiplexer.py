"""Epoch multiplexer: the fused phase-1/3 loop over many tenant programs.

The paper's "work-together" principle (§3) says critical-path overhead
should be paid by the entire system at once.  A solo ``HostEngine.run``
already pays phase 1 (stack pop + launch) and phase 3 (scalar readback)
once per epoch *for one program*; N concurrent tenants would pay N× that
V_inf cost.  This module extends work-together **across tenants**:

* :func:`fuse_programs` builds one fused :class:`Program` from N tenant
  programs — task tables and map tables concatenate (task ids shifted by a
  per-tenant offset), heap variables are namespaced ``j<k>/name``, and every
  tenant task function runs behind a context shim that translates task ids,
  map ids, and heap names back into the tenant's own vocabulary.  Phase 2
  therefore needs *no new machinery*: the fused program is an ordinary
  ``Program`` and both the masked and §5.4-compacted dispatches apply.

* :class:`EpochMultiplexer` gives each admitted job a contiguous slot
  region in one shared :class:`~repro.core.tvm.TVMState` (the region is the
  job's private Task Vector: its layout is the solo run's, shifted by the
  region base — see ``JobArena``), keeps one
  :class:`~repro.core.scheduler.EpochScheduler` per job, and each *global*
  epoch pops every ready job's frontier (``MuxPopPolicy`` selects the gang),
  fuses the popped ranges into one launch with a per-lane epoch-number
  vector, and reads back one :class:`~repro.core.tvm.MuxEpochSummary` for
  the whole fleet.  The per-epoch dispatch + scalar readback is paid once
  for the fleet instead of once per job, while per-job results stay
  bit-identical to the solo runs.

Completion is streamed: the moment a job's scheduler drains, its result is
extracted from its region and the region is freed for re-admission (a new
job reusing the *same* program template can be seeded into a freed region
mid-flight, without retracing anything).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tvm
from ..core.engine import MapLauncher, _default_rank_fn
from ..core.program import HeapVar, MapType, Program, TaskType, pack_args
from ..core.scheduler import (
    EpochScheduler,
    NullStats,
    RunStats,
    RunStatsCollector,
    StatsCollector,
    resolve_mux_policy,
    resolve_policy,
    size_type_buckets,
)
from .jobs import (
    Job,
    JobFailure,
    JobHandle,
    JobResult,
    JobStats,
    JobStatus,
    check_fleet_dtype,
    validate_job,
)


# --------------------------------------------------------------------------
# Tenant context shims: run a tenant task body against the fused program
# --------------------------------------------------------------------------
class _TenantEpochCtx:
    """EpochCtx view in the tenant's own vocabulary.

    Delegates every read/effect to the fused :class:`EpochCtx`, translating
    task names/ids by the tenant's task-table offset, map names/ids by its
    map-table offset, and heap names by its ``j<k>/`` namespace prefix.
    """

    __slots__ = ("_ctx", "_sub", "_task_off", "_map_off", "_prefix")

    def __init__(self, ctx, sub: Program, task_off: int, map_off: int,
                 prefix: str):
        self._ctx = ctx
        self._sub = sub
        self._task_off = task_off
        self._map_off = map_off
        self._prefix = prefix

    # reads -----------------------------------------------------------------
    def argi(self, k: int):
        return self._ctx.argi(k)

    def argf(self, k: int):
        return self._ctx.argf(k)

    @property
    def slot(self):
        return self._ctx.slot

    @property
    def child_count(self):
        return self._ctx.child_count

    def child_values(self, n: int):
        # slice the fused value rows down to the tenant's own width so a
        # width-w program sees exactly the (n, w) a solo run returns
        return self._ctx.child_values(n)[:, : self._sub.value_width]

    def read(self, name: str, index):
        return self._ctx.read(self._prefix + name, index)

    # effects ---------------------------------------------------------------
    def _code(self, task):
        if isinstance(task, str):
            return self._task_off + self._sub.task_id(task)
        return self._task_off + task

    def fork(self, task, argi=(), argf=(), where=True):
        self._ctx.fork(self._code(task), argi=argi, argf=argf, where=where)

    def join(self, task, argi=(), argf=(), where=True):
        self._ctx.join(self._code(task), argi=argi, argf=argf, where=where)

    def emit(self, value, where=True):
        # enforce the tenant's own value width (the fused width may be
        # larger; a solo run would reject the overflow, so must we)
        v = jnp.asarray(value).reshape(-1)
        if v.shape[0] > self._sub.value_width:
            raise ValueError("emit value wider than program.value_width")
        self._ctx.emit(value, where=where)

    def write(self, name: str, index, value, op: str = "set", where=True):
        self._ctx.write(self._prefix + name, index, value, op=op, where=where)

    def map(self, map_fn, argi=(), argf=(), where=True):
        mid = (
            self._sub.map_id(map_fn)
            if isinstance(map_fn, str)
            else int(map_fn)
        )
        self._ctx.map(self._map_off + mid, argi=argi, argf=argf, where=where)


class _TenantMapCtx:
    """MapCtx view with the tenant's heap namespace."""

    __slots__ = ("_ctx", "_prefix")

    def __init__(self, ctx, prefix: str):
        self._ctx = ctx
        self._prefix = prefix

    def argi(self, k: int):
        return self._ctx.argi(k)

    def argf(self, k: int):
        return self._ctx.argf(k)

    @property
    def eid(self):
        return self._ctx.eid

    def read(self, name: str, index):
        return self._ctx.read(self._prefix + name, index)

    def write(self, name: str, index, value, op: str = "set", where=True):
        self._ctx.write(self._prefix + name, index, value, op=op, where=where)


def _wrap_task(fn, sub: Program, task_off: int, map_off: int, prefix: str):
    def wrapped(ctx, _fn=fn):
        _fn(_TenantEpochCtx(ctx, sub, task_off, map_off, prefix))

    return wrapped


def _wrap_map(fn, prefix: str):
    def wrapped(mctx, _fn=fn):
        _fn(_TenantMapCtx(mctx, prefix))

    return wrapped


# --------------------------------------------------------------------------
# Program fusion
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TenantSlot:
    """One tenant's compile-time contribution to the fused program, plus its
    slot region in the shared TV.  The region is sized by the job's quota at
    fuse time; a later tenant re-admitted into this region may use less."""

    index: int
    program: Program
    task_offset: int
    map_offset: int
    prefix: str
    base: int
    quota: int

    @property
    def end(self) -> int:
        return self.base + self.quota


def fuse_programs(
    programs: Sequence[Program], quotas: Sequence[int]
) -> Tuple[Program, List[TenantSlot]]:
    """Concatenate N tenant programs into one fused :class:`Program`.

    Argument-register widths and the value width are the fleet maxima (a
    tenant's own args/emits occupy a prefix; the padding columns stay zero,
    so the tenant-visible slice is bit-identical to solo).  The value dtype
    must be uniform across the fleet (:func:`check_fleet_dtype`).
    """
    value_dtype = check_fleet_dtype(programs)
    tasks: List[TaskType] = []
    maps: List[MapType] = []
    heap: List[HeapVar] = []
    slots: List[TenantSlot] = []
    base = 0
    for j, (p, q) in enumerate(zip(programs, quotas)):
        prefix = f"j{j}/"
        slot = TenantSlot(
            index=j, program=p, task_offset=len(tasks),
            map_offset=len(maps), prefix=prefix, base=base, quota=int(q),
        )
        for t in p.tasks:
            tasks.append(
                TaskType(
                    prefix + t.name,
                    _wrap_task(t.fn, p, slot.task_offset, slot.map_offset,
                               prefix),
                )
            )
        for m in p.maps:
            maps.append(
                MapType(
                    prefix + m.name,
                    _wrap_map(m.fn, prefix),
                    domain=m.domain,
                    max_domain=m.max_domain,
                )
            )
        for hv in p.heap:
            heap.append(HeapVar(prefix + hv.name, hv.shape, hv.dtype))
        slots.append(slot)
        base += int(q)

    fused = Program(
        name="mux[" + "+".join(p.name for p in programs) + "]",
        tasks=tuple(tasks),
        n_arg_i=max(p.n_arg_i for p in programs),
        n_arg_f=max(p.n_arg_f for p in programs),
        value_width=max(p.value_width for p in programs),
        value_dtype=value_dtype,
        maps=tuple(maps),
        heap=tuple(heap),
    )
    return fused, slots


# --------------------------------------------------------------------------
# The multiplexer
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Region:
    """Runtime state of one slot region: the tenant currently in it (if
    any), its scheduler stacks, and its solo-comparable stats."""

    slot: TenantSlot
    handle: Optional[JobHandle] = None
    sched: Optional[EpochScheduler] = None
    stats: Optional[JobStats] = None
    active_quota: int = 0

    @property
    def running(self) -> bool:
        return (
            self.handle is not None
            and self.handle.status is JobStatus.RUNNING
        )


class EpochMultiplexer:
    """Co-schedule a fleet of jobs inside one shared TVM.

    Each global epoch: select a gang of ready jobs (``pop_policy``), pop one
    dispatch from each job's own scheduler, fuse the ranges into a single
    launch over their covering span with a per-lane epoch-number vector
    (lanes outside every popped range carry 0 and stay inactive), commit
    with the :class:`~repro.core.tvm.JobArena` segmented allocator, and read
    back one fused :class:`~repro.core.tvm.MuxEpochSummary`.  Dispatch +
    readback are counted once per global epoch — the fleet's V_inf — while
    each job's scheduler sees exactly the solo sequence of pops and pushes.
    """

    _MAX_STEP_CACHE = 256  # distinct (P, buckets) jit specializations kept

    def __init__(
        self,
        handles: Sequence[JobHandle],
        capacity: Optional[int] = None,
        dispatch: Any = "masked",
        coalesce: bool = True,
        pop_policy: Any = "fuse_all",
        gang: int = 0,
        collect_stats: bool = True,
        stats_factory=None,
        rank_fn=None,
    ):
        if not handles:
            raise ValueError("EpochMultiplexer needs at least one job")
        jobs = [h.job for h in handles]
        quota_total = sum(j.quota for j in jobs)
        self.capacity = int(capacity) if capacity else quota_total
        if quota_total > self.capacity:
            raise ValueError(
                f"sum of job quotas ({quota_total}) exceeds TV capacity "
                f"({self.capacity})"
            )
        for j in jobs:
            validate_job(j, self.capacity)
        self.policy = resolve_policy(dispatch)
        self.pop_policy = resolve_mux_policy(pop_policy, gang)
        self.coalesce = coalesce
        self._rank_fn = rank_fn or _default_rank_fn
        self._stats_factory = stats_factory
        self._collect_stats = collect_stats

        self.program, self._slots = fuse_programs(
            [j.program for j in jobs], [j.quota for j in jobs]
        )
        self._task_names = [t.name for t in self.program.tasks]
        self._maps = MapLauncher(self.program)
        self._col = self._collector()
        self._step_cache: Dict[Any, Any] = {}
        self._compact_cache: Dict[int, Any] = {}
        self._rotor = 0
        self._global_epochs = 0

        self._init_fleet(handles)

    # ------------------------------------------------------------ plumbing
    def _collector(self) -> StatsCollector:
        if self._stats_factory is not None:
            return self._stats_factory()
        return RunStatsCollector() if self._collect_stats else NullStats()

    def _init_fleet(self, handles: Sequence[JobHandle]) -> None:
        """Build the shared TVM state, arena, heap, and per-job schedulers."""
        fused, C = self.program, self.capacity
        J = len(self._slots)
        npdtype = jnp.dtype(fused.value_dtype)
        task = np.zeros(C, np.int32)
        argi = np.zeros((C, fused.n_arg_i), np.int32)
        argf = np.zeros((C, fused.n_arg_f), np.float32)
        epoch = np.zeros(C, np.int32)
        value = np.zeros((C, fused.value_width), npdtype)
        slot_job = np.full(C, J, np.int32)

        self._regions: List[_Region] = []
        self._heap: Dict[str, jnp.ndarray] = {}
        for slot, h in zip(self._slots, handles):
            job = h.job
            slot_job[slot.base : slot.end] = slot.index
            tid = slot.task_offset + slot.program.task_id(job.initial.task)
            ai, af = pack_args(fused, job.initial.argi, job.initial.argf)
            task[slot.base] = tid
            argi[slot.base] = ai
            argf[slot.base] = af
            epoch[slot.base] = 1
            for k, v in slot.program.init_heap(**dict(job.heap_init)).items():
                self._heap[slot.prefix + k] = v
            sched = EpochScheduler(coalesce=self.coalesce)
            sched.reset(cen=1, start=slot.base, count=1)
            h.status = JobStatus.RUNNING
            self._regions.append(
                _Region(
                    slot=slot, handle=h, sched=sched, stats=JobStats(),
                    active_quota=job.quota,
                )
            )

        self._state = tvm.TVMState(
            task=jnp.asarray(task),
            argi=jnp.asarray(argi),
            argf=jnp.asarray(argf),
            epoch=jnp.asarray(epoch),
            value=jnp.asarray(value),
            child_base=jnp.zeros((C,), jnp.int32),
            child_count=jnp.zeros((C,), jnp.int32),
            next_free=jnp.asarray(max(s.base for s in self._slots) + 1,
                                  jnp.int32),
        )
        self._arena = tvm.JobArena(
            slot_job=jnp.asarray(slot_job),
            base=jnp.asarray([s.base for s in self._slots], jnp.int32),
            end=jnp.asarray([s.end for s in self._slots], jnp.int32),
            next=jnp.asarray([s.base + 1 for s in self._slots], jnp.int32),
        )

    # ----------------------------------------------------------- jit steps
    def _get_step(self, P: int):
        """Masked fused step: full covering span, per-lane epoch numbers."""
        key = ("m", P)
        if key not in self._step_cache:
            program = self.program

            def step(state, heap, arena, lo, cen_lane):
                idx = lo + jnp.arange(P, dtype=jnp.int32)
                cidx = jnp.clip(idx, 0, state.capacity - 1)
                active = (cen_lane > 0) & (state.epoch[cidx] == cen_lane)
                # fused fleets have many task types but type-homogeneous
                # epochs stay common, so idle types skip via lax.cond
                per_type, _ = tvm.trace_tasks(
                    program, state, heap, idx, active, skip_idle_types=True
                )
                return tvm.commit_epoch(
                    program, state, heap, idx, active, per_type, cen_lane,
                    arena=arena,
                )

            self._step_cache[key] = jax.jit(step)
        return self._step_cache[key]

    def _get_compact(self, P: int):
        """Compaction pass over the fused span (one dispatch + count
        readback, exactly the solo §5.4 trade)."""
        if P not in self._compact_cache:
            program, rank_fn = self.program, self._rank_fn

            def cfn(state, lo, cen_lane):
                idx = lo + jnp.arange(P, dtype=jnp.int32)
                cidx = jnp.clip(idx, 0, state.capacity - 1)
                active = (cen_lane > 0) & (state.epoch[cidx] == cen_lane)
                return tvm.compact_types(
                    program, state, idx, active, rank_fn=rank_fn
                )

            self._compact_cache[P] = jax.jit(cfn)
        return self._compact_cache[P]

    def _get_compacted_step(self, P: int, buckets: Tuple[int, ...]):
        key = ("c", P, buckets)
        if key not in self._step_cache:
            while len(self._step_cache) >= self._MAX_STEP_CACHE:
                self._step_cache.pop(next(iter(self._step_cache)))
            program = self.program

            def step(state, heap, arena, lo, count, cen_lane, perm, toffs,
                     tcounts):
                per_type, idx, active = tvm.trace_tasks_compacted(
                    program, state, heap, lo, count, cen_lane,
                    perm, toffs, tcounts, buckets,
                )
                return tvm.commit_epoch(
                    program, state, heap, idx, active, per_type, cen_lane,
                    arena=arena,
                )

            self._step_cache[key] = jax.jit(step)
        return self._step_cache[key]

    # ------------------------------------------------------------ stepping
    @property
    def live(self) -> bool:
        return any(r.running for r in self._regions)

    def step(self) -> List[JobHandle]:
        """Run one fused global epoch; return handles that completed."""
        ready = [
            j for j, r in enumerate(self._regions) if r.running and r.sched
        ]
        if not ready:
            return []
        depths = [len(self._regions[j].sched) for j in ready]
        chosen = self.pop_policy.select(ready, depths, self._rotor)
        self._rotor += 1
        self._global_epochs += 1
        col = self._col

        pops = {j: self._regions[j].sched.pop() for j in chosen}
        lo = min(d.start for d in pops.values())
        hi = max(d.start + d.count for d in pops.values())
        P = self.policy.epoch_bucket(hi - lo)
        cen_np = np.zeros(P, np.int32)
        for d in pops.values():
            cen_np[d.start - lo : d.start - lo + d.count] = d.cen
        cen_lane = jnp.asarray(cen_np)
        lo_j = jnp.asarray(lo, jnp.int32)

        compacted = self.policy.name == "compacted"
        by_type = None
        shared_dispatches = 1
        if compacted:
            perm, counts_dev = self._get_compact(P)(
                self._state, lo_j, cen_lane
            )
            counts = np.asarray(jax.device_get(counts_dev), np.int64)
            col.dispatch()
            col.transfer()
            shared_dispatches += 1
            buckets, toffs, launched, by_type = size_type_buckets(
                self.policy, counts, self._task_names
            )
            step = self._get_compacted_step(P, buckets)
            self._state, self._heap, summary, map_launches = step(
                self._state, self._heap, self._arena, lo_j,
                jnp.asarray(hi - lo, jnp.int32), cen_lane, perm,
                jnp.asarray(toffs, jnp.int32), jnp.asarray(counts, jnp.int32),
            )
        else:
            step = self._get_step(P)
            self._state, self._heap, summary, map_launches = step(
                self._state, self._heap, self._arena, lo_j, cen_lane
            )
            launched = P

        # one fused readback for the whole fleet (the cross-tenant V_inf win)
        job_forks, job_join, job_active, job_overflow, job_next, map_sched = (
            jax.device_get(
                (
                    summary.job_forks, summary.job_join, summary.job_active,
                    summary.job_overflow, summary.job_next,
                    summary.map_scheduled,
                )
            )
        )
        col.dispatch()
        col.transfer()
        self._arena = dataclasses.replace(self._arena, next=summary.job_next)

        done: List[JobHandle] = []
        for j in chosen:
            r = self._regions[j]
            d = pops[j]
            if bool(job_overflow[j]):
                r.handle.error = JobFailure(
                    f"job {r.handle.job.name!r} overflowed its region: "
                    f"quota={r.active_quota}"
                )
                r.handle.status = JobStatus.FAILED
                done.append(self._release(j))
                continue
            if bool(job_join[j]):
                r.sched.push_join(d.cen, d.start, d.count)
            forks = int(job_forks[j])
            r.sched.push_forked(d.cen + 1, int(job_next[j]) - forks, forks)
            st = r.stats
            st.epochs += 1
            st.tasks_executed += int(job_active[j])
            st.total_forks += forks
            st.peak_tv_slots = max(
                st.peak_tv_slots, int(job_next[j]) - r.slot.base
            )
            st.shared_dispatches += shared_dispatches
            st.shared_transfers += shared_dispatches

        if bool(map_sched):
            self._heap = self._maps.run(map_launches, self._heap, col)

        col.epoch(self._global_epochs,
                  sum(d.n_ranges for d in pops.values()))
        col.lanes(int(job_active.sum()), launched, by_type)
        col.forks(int(job_forks.sum()))
        col.tv_peak(int(job_next.max()))

        for j in chosen:
            r = self._regions[j]
            if r.running and not r.sched:
                done.append(self._finalize(j))
        return done

    def run(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        """Drive every admitted job to completion; return finished handles."""
        out: List[JobHandle] = []
        while self.live:
            if self._global_epochs >= max_epochs:
                raise RuntimeError(f"exceeded max_epochs={max_epochs}")
            out.extend(self.step())
        return out

    def stats(self) -> RunStats:
        """Fleet-level stats: V_inf terms counted once per global epoch."""
        return self._col.result()

    # ------------------------------------------------- completion / reuse
    def _finalize(self, j: int) -> JobHandle:
        """Extract the region's solo-equivalent result; free the region."""
        r = self._regions[j]
        s = r.slot
        sub = s.program
        value = self._state.value[
            s.base : s.base + r.active_quota, : sub.value_width
        ]
        heap = {
            hv.name: self._heap[s.prefix + hv.name] for hv in sub.heap
        }
        r.handle.result = JobResult(heap=heap, value=value, stats=r.stats)
        r.handle.status = JobStatus.DONE
        return self._release(j)

    def _release(self, j: int) -> JobHandle:
        r = self._regions[j]
        h = r.handle
        r.handle = None
        r.sched = None
        r.stats = None
        r.active_quota = 0
        return h

    def admit(self, handle: JobHandle) -> bool:
        """Seed a queued job into a freed region, mid-flight.

        Only a region fused for the *same program template* can be reused
        (the fused task table is compiled in); the new job may carry its own
        initial task, heap init, and a quota up to the region size.  Returns
        False when no compatible free region exists.
        """
        job = handle.job
        for r in self._regions:
            if r.handle is not None:
                continue
            s = r.slot
            if s.program is not job.program and s.program != job.program:
                continue
            if job.quota > s.quota:
                continue
            self._seed_region(r, handle)
            return True
        return False

    def _seed_region(self, r: _Region, handle: JobHandle) -> None:
        """Clear a freed region and seed the new tenant's root task."""
        job = handle.job
        s = r.slot
        sub = s.program
        sl = slice(s.base, s.end)
        tid = s.task_offset + sub.task_id(job.initial.task)
        ai, af = pack_args(self.program, job.initial.argi, job.initial.argf)
        st = self._state
        self._state = tvm.TVMState(
            task=st.task.at[sl].set(0).at[s.base].set(tid),
            argi=st.argi.at[sl].set(0).at[s.base].set(jnp.asarray(ai)),
            argf=st.argf.at[sl].set(0.0).at[s.base].set(jnp.asarray(af)),
            epoch=st.epoch.at[sl].set(0).at[s.base].set(1),
            value=st.value.at[sl].set(0),
            child_base=st.child_base.at[sl].set(0),
            child_count=st.child_count.at[sl].set(0),
            next_free=st.next_free,
        )
        self._arena = dataclasses.replace(
            self._arena,
            end=self._arena.end.at[s.index].set(s.base + job.quota),
            next=self._arena.next.at[s.index].set(s.base + 1),
        )
        for k, v in sub.init_heap(**dict(job.heap_init)).items():
            self._heap[s.prefix + k] = v
        sched = EpochScheduler(coalesce=self.coalesce)
        sched.reset(cen=1, start=s.base, count=1)
        r.handle = handle
        r.sched = sched
        r.stats = JobStats()
        r.active_quota = job.quota
        handle.status = JobStatus.RUNNING
