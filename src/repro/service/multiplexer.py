"""Epoch multiplexers: fused multi-tenant driving over one shared TVM.

The paper's "work-together" principle (§3) says critical-path overhead
should be paid by the entire system at once.  A solo ``HostEngine.run``
already pays phase 1 (stack pop + launch) and phase 3 (scalar readback)
once per epoch *for one program*; N concurrent tenants would pay N× that
V_inf cost.  This module extends work-together **across tenants**, at two
levels of residency:

* :func:`fuse_programs` builds one fused :class:`Program` from N tenant
  programs — task tables and map tables concatenate (task ids shifted by a
  per-tenant offset), heap variables are namespaced ``j<k>/name``, and every
  tenant task function runs behind a context shim that translates task ids,
  map ids, and heap names back into the tenant's own vocabulary.  Phase 2
  therefore needs *no new machinery*: the fused program is an ordinary
  ``Program`` and both the masked and §5.4-compacted dispatches apply.

* :class:`EpochMultiplexer` is the *host-loop* driver (an
  :class:`~repro.core.engine.EpochLoop` configuration): each global epoch it
  pops every ready job's frontier (``MuxPopPolicy`` selects the gang), fuses
  the popped ranges into one launch with a per-lane epoch-number vector, and
  reads back one :class:`~repro.core.tvm.MuxEpochSummary` for the whole
  fleet — V_inf paid once per *global epoch*.  Because the host sees every
  epoch, it supports streaming completion, mid-flight region reuse
  (including structurally-equal program templates, see
  ``Program.structural_hash``), gang policies, and the compacted dispatch.

* :class:`DeviceMultiplexer` is the *resident* driver (DESIGN.md §9): the
  entire admitted wave runs to completion inside one ``lax.while_loop``,
  with per-region scheduler stacks (``batched_device_stacks``) and the
  :class:`~repro.core.tvm.JobArena` region cursors carried on device.
  Per-wave V_inf is O(1) — one dispatch + one readback for the whole wave —
  and the host only sees the final per-region heaps and stats.  The trade:
  no per-epoch host visibility, so streaming completion and mid-flight
  region reuse stay host-mux-only, and only the masked dispatch is
  traceable.

Per-job results are bit-identical to the solo runs under both drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import tvm
from ..core.engine import (
    EpochLoop,
    _COMPACTED_RESIDENT_MSG,
    _fresh_resident_carry,
    _hilo_value,
)
from ..core.program import HeapVar, MapType, Program, TaskType, pack_args
from ..core.scheduler import (
    EpochScheduler,
    NullStats,
    RunStats,
    RunStatsCollector,
    StatsCollector,
    batched_device_stacks,
    resolve_mux_policy,
    resolve_policy,
)
from .jobs import (
    Job,
    JobFailure,
    JobHandle,
    JobResult,
    JobStats,
    JobStatus,
    check_fleet_dtype,
    validate_job,
)


# --------------------------------------------------------------------------
# Tenant context shims: run a tenant task body against the fused program
# --------------------------------------------------------------------------
class _TenantEpochCtx:
    """EpochCtx view in the tenant's own vocabulary.

    Delegates every read/effect to the fused :class:`EpochCtx`, translating
    task names/ids by the tenant's task-table offset, map names/ids by its
    map-table offset, and heap names by its ``j<k>/`` namespace prefix.
    """

    __slots__ = ("_ctx", "_sub", "_task_off", "_map_off", "_prefix")

    def __init__(self, ctx, sub: Program, task_off: int, map_off: int,
                 prefix: str):
        self._ctx = ctx
        self._sub = sub
        self._task_off = task_off
        self._map_off = map_off
        self._prefix = prefix

    # reads -----------------------------------------------------------------
    def argi(self, k: int):
        return self._ctx.argi(k)

    def argf(self, k: int):
        return self._ctx.argf(k)

    @property
    def slot(self):
        return self._ctx.slot

    @property
    def child_count(self):
        return self._ctx.child_count

    def child_values(self, n: int):
        # slice the fused value rows down to the tenant's own width so a
        # width-w program sees exactly the (n, w) a solo run returns
        return self._ctx.child_values(n)[:, : self._sub.value_width]

    def read(self, name: str, index):
        return self._ctx.read(self._prefix + name, index)

    # effects ---------------------------------------------------------------
    def _code(self, task):
        if isinstance(task, str):
            return self._task_off + self._sub.task_id(task)
        return self._task_off + task

    def fork(self, task, argi=(), argf=(), where=True):
        self._ctx.fork(self._code(task), argi=argi, argf=argf, where=where)

    def join(self, task, argi=(), argf=(), where=True):
        self._ctx.join(self._code(task), argi=argi, argf=argf, where=where)

    def emit(self, value, where=True):
        # enforce the tenant's own value width (the fused width may be
        # larger; a solo run would reject the overflow, so must we)
        v = jnp.asarray(value).reshape(-1)
        if v.shape[0] > self._sub.value_width:
            raise ValueError("emit value wider than program.value_width")
        self._ctx.emit(value, where=where)

    def write(self, name: str, index, value, op: str = "set", where=True):
        self._ctx.write(self._prefix + name, index, value, op=op, where=where)

    def map(self, map_fn, argi=(), argf=(), where=True):
        mid = (
            self._sub.map_id(map_fn)
            if isinstance(map_fn, str)
            else int(map_fn)
        )
        self._ctx.map(self._map_off + mid, argi=argi, argf=argf, where=where)


class _TenantMapCtx:
    """MapCtx view with the tenant's heap namespace."""

    __slots__ = ("_ctx", "_prefix")

    def __init__(self, ctx, prefix: str):
        self._ctx = ctx
        self._prefix = prefix

    def argi(self, k: int):
        return self._ctx.argi(k)

    def argf(self, k: int):
        return self._ctx.argf(k)

    @property
    def eid(self):
        return self._ctx.eid

    def read(self, name: str, index):
        return self._ctx.read(self._prefix + name, index)

    def write(self, name: str, index, value, op: str = "set", where=True):
        self._ctx.write(self._prefix + name, index, value, op=op, where=where)


def _wrap_task(fn, sub: Program, task_off: int, map_off: int, prefix: str):
    def wrapped(ctx, _fn=fn):
        _fn(_TenantEpochCtx(ctx, sub, task_off, map_off, prefix))

    return wrapped


def _wrap_map(fn, prefix: str):
    def wrapped(mctx, _fn=fn):
        _fn(_TenantMapCtx(mctx, prefix))

    return wrapped


# --------------------------------------------------------------------------
# Program fusion
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TenantSlot:
    """One tenant's compile-time contribution to the fused program, plus its
    slot region in the shared TV.  The region is sized by the job's quota at
    fuse time; a later tenant re-admitted into this region may use less."""

    index: int
    program: Program
    task_offset: int
    map_offset: int
    prefix: str
    base: int
    quota: int

    @property
    def end(self) -> int:
        return self.base + self.quota


def fuse_programs(
    programs: Sequence[Program], quotas: Sequence[int]
) -> Tuple[Program, List[TenantSlot]]:
    """Concatenate N tenant programs into one fused :class:`Program`.

    Argument-register widths and the value width are the fleet maxima (a
    tenant's own args/emits occupy a prefix; the padding columns stay zero,
    so the tenant-visible slice is bit-identical to solo).  The value dtype
    must be uniform across the fleet (:func:`check_fleet_dtype`).
    """
    value_dtype = check_fleet_dtype(programs)
    tasks: List[TaskType] = []
    maps: List[MapType] = []
    heap: List[HeapVar] = []
    slots: List[TenantSlot] = []
    base = 0
    for j, (p, q) in enumerate(zip(programs, quotas)):
        prefix = f"j{j}/"
        slot = TenantSlot(
            index=j, program=p, task_offset=len(tasks),
            map_offset=len(maps), prefix=prefix, base=base, quota=int(q),
        )
        for t in p.tasks:
            tasks.append(
                TaskType(
                    prefix + t.name,
                    _wrap_task(t.fn, p, slot.task_offset, slot.map_offset,
                               prefix),
                )
            )
        for m in p.maps:
            maps.append(
                MapType(
                    prefix + m.name,
                    _wrap_map(m.fn, prefix),
                    domain=m.domain,
                    max_domain=m.max_domain,
                )
            )
        for hv in p.heap:
            heap.append(HeapVar(prefix + hv.name, hv.shape, hv.dtype))
        slots.append(slot)
        base += int(q)

    fused = Program(
        name="mux[" + "+".join(p.name for p in programs) + "]",
        tasks=tuple(tasks),
        n_arg_i=max(p.n_arg_i for p in programs),
        n_arg_f=max(p.n_arg_f for p in programs),
        value_width=max(p.value_width for p in programs),
        value_dtype=value_dtype,
        maps=tuple(maps),
        heap=tuple(heap),
    )
    return fused, slots


# --------------------------------------------------------------------------
# Shared fleet plumbing
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Region:
    """Runtime state of one slot region: the tenant currently in it (if
    any), its scheduler stacks (host driver only), and its solo-comparable
    stats."""

    slot: TenantSlot
    handle: Optional[JobHandle] = None
    sched: Optional[EpochScheduler] = None
    stats: Optional[JobStats] = None
    active_quota: int = 0

    @property
    def running(self) -> bool:
        return (
            self.handle is not None
            and self.handle.status is JobStatus.RUNNING
        )


class _FleetBase:
    """Shared multi-tenant plumbing: program fusion, the shared TVM state +
    :class:`~repro.core.tvm.JobArena`, per-region bookkeeping, and result
    extraction.  The host and resident drivers differ only in *how* they
    drive epochs; everything either one reads or writes lives here."""

    def __init__(
        self,
        handles: Sequence[JobHandle],
        capacity: Optional[int] = None,
        coalesce: bool = True,
        collect_stats: bool = True,
        stats_factory=None,
    ):
        if not handles:
            raise ValueError(f"{type(self).__name__} needs at least one job")
        jobs = [h.job for h in handles]
        quota_total = sum(j.quota for j in jobs)
        self.capacity = int(capacity) if capacity else quota_total
        if quota_total > self.capacity:
            raise ValueError(
                f"sum of job quotas ({quota_total}) exceeds TV capacity "
                f"({self.capacity})"
            )
        for j in jobs:
            validate_job(j, self.capacity)
        self.coalesce = coalesce
        self._stats_factory = stats_factory
        self._collect_stats = collect_stats

        self.program, self._slots = fuse_programs(
            [j.program for j in jobs], [j.quota for j in jobs]
        )
        self._col = self._collector()
        self._init_fleet(handles)

    def _collector(self) -> StatsCollector:
        if self._stats_factory is not None:
            return self._stats_factory()
        return RunStatsCollector() if self._collect_stats else NullStats()

    def _init_fleet(self, handles: Sequence[JobHandle]) -> None:
        """Build the shared TVM state, arena, heap, and per-job schedulers."""
        fused, C = self.program, self.capacity
        J = len(self._slots)
        npdtype = jnp.dtype(fused.value_dtype)
        task = np.zeros(C, np.int32)
        argi = np.zeros((C, fused.n_arg_i), np.int32)
        argf = np.zeros((C, fused.n_arg_f), np.float32)
        epoch = np.zeros(C, np.int32)
        value = np.zeros((C, fused.value_width), npdtype)
        slot_job = np.full(C, J, np.int32)

        self._regions: List[_Region] = []
        self._heap: Dict[str, jnp.ndarray] = {}
        for slot, h in zip(self._slots, handles):
            job = h.job
            slot_job[slot.base : slot.end] = slot.index
            tid = slot.task_offset + slot.program.task_id(job.initial.task)
            ai, af = pack_args(fused, job.initial.argi, job.initial.argf)
            task[slot.base] = tid
            argi[slot.base] = ai
            argf[slot.base] = af
            epoch[slot.base] = 1
            for k, v in slot.program.init_heap(**dict(job.heap_init)).items():
                self._heap[slot.prefix + k] = v
            sched = EpochScheduler(coalesce=self.coalesce)
            sched.reset(cen=1, start=slot.base, count=1)
            h.status = JobStatus.RUNNING
            self._regions.append(
                _Region(
                    slot=slot, handle=h, sched=sched, stats=JobStats(),
                    active_quota=job.quota,
                )
            )

        self._state = tvm.TVMState(
            task=jnp.asarray(task),
            argi=jnp.asarray(argi),
            argf=jnp.asarray(argf),
            epoch=jnp.asarray(epoch),
            value=jnp.asarray(value),
            child_base=jnp.zeros((C,), jnp.int32),
            child_count=jnp.zeros((C,), jnp.int32),
            next_free=jnp.asarray(max(s.base for s in self._slots) + 1,
                                  jnp.int32),
        )
        self._arena = tvm.JobArena(
            slot_job=jnp.asarray(slot_job),
            base=jnp.asarray([s.base for s in self._slots], jnp.int32),
            end=jnp.asarray([s.end for s in self._slots], jnp.int32),
            next=jnp.asarray([s.base + 1 for s in self._slots], jnp.int32),
        )

    @property
    def live(self) -> bool:
        return any(r.running for r in self._regions)

    def stats(self) -> RunStats:
        """Fleet-level stats: V_inf terms counted per fused dispatch."""
        return self._col.result()

    # ------------------------------------------------- completion / release
    def _finalize(self, j: int) -> JobHandle:
        """Extract the region's solo-equivalent result; free the region."""
        r = self._regions[j]
        s = r.slot
        sub = s.program
        value = self._state.value[
            s.base : s.base + r.active_quota, : sub.value_width
        ]
        heap = {
            hv.name: self._heap[s.prefix + hv.name] for hv in sub.heap
        }
        r.handle.result = JobResult(heap=heap, value=value, stats=r.stats)
        r.handle.status = JobStatus.DONE
        return self._release(j)

    def _fail(self, j: int, reason: Optional[str] = None) -> JobHandle:
        r = self._regions[j]
        r.handle.error = JobFailure(
            reason
            or f"job {r.handle.job.name!r} overflowed its region: "
               f"quota={r.active_quota}"
        )
        r.handle.status = JobStatus.FAILED
        return self._release(j)

    def _release(self, j: int) -> JobHandle:
        r = self._regions[j]
        h = r.handle
        r.handle = None
        r.sched = None
        r.stats = None
        r.active_quota = 0
        return h


# --------------------------------------------------------------------------
# Host-loop driver
# --------------------------------------------------------------------------
class EpochMultiplexer(_FleetBase):
    """Co-schedule a fleet of jobs inside one shared TVM (host loop).

    Each global epoch: select a gang of ready jobs (``pop_policy``), pop one
    dispatch from each job's own scheduler, fuse the ranges into a single
    launch over their covering span with a per-lane epoch-number vector
    (lanes outside every popped range carry 0 and stay inactive), commit
    with the :class:`~repro.core.tvm.JobArena` segmented allocator, and read
    back one fused :class:`~repro.core.tvm.MuxEpochSummary`.  Dispatch +
    readback are counted once per global epoch — the fleet's V_inf — while
    each job's scheduler sees exactly the solo sequence of pops and pushes.
    """

    def __init__(
        self,
        handles: Sequence[JobHandle],
        capacity: Optional[int] = None,
        dispatch: Any = "masked",
        coalesce: bool = True,
        pop_policy: Any = "fuse_all",
        gang: int = 0,
        collect_stats: bool = True,
        stats_factory=None,
        rank_fn=None,
        seg_offsets_fn=None,
    ):
        super().__init__(
            handles, capacity=capacity, coalesce=coalesce,
            collect_stats=collect_stats, stats_factory=stats_factory,
        )
        self.pop_policy = resolve_mux_policy(pop_policy, gang)
        self._loop = EpochLoop(
            self.program, dispatch,
            rank_fn=rank_fn, seg_offsets_fn=seg_offsets_fn,
            # fused fleets have many task types but type-homogeneous epochs
            # stay common, so idle types skip via lax.cond
            skip_idle_types=True,
        )
        self.policy = self._loop.policy
        self._rotor = 0
        self._global_epochs = 0

    @staticmethod
    def _readback(summary, state):
        # one fused readback for the whole fleet (the cross-tenant V_inf win)
        return (
            summary.job_forks, summary.job_join, summary.job_active,
            summary.job_overflow, summary.job_next, summary.map_scheduled,
        )

    # ------------------------------------------------------------ stepping
    def step(self) -> List[JobHandle]:
        """Run one fused global epoch; return handles that completed."""
        ready = [
            j for j, r in enumerate(self._regions) if r.running and r.sched
        ]
        if not ready:
            return []
        depths = [len(self._regions[j].sched) for j in ready]
        chosen = self.pop_policy.select(ready, depths, self._rotor)
        self._rotor += 1
        self._global_epochs += 1
        col = self._col

        pops = {j: self._regions[j].sched.pop() for j in chosen}
        lo = min(d.start for d in pops.values())
        hi = max(d.start + d.count for d in pops.values())
        cen_np = np.zeros(hi - lo, np.int32)
        for d in pops.values():
            cen_np[d.start - lo : d.start - lo + d.count] = d.cen

        (self._state, self._heap, summary, fetched, map_launches, launched,
         by_type, shared_dispatches) = self._loop.run_epoch(
            self._state, self._heap, self._arena, lo, hi - lo, cen_np, col,
            self._readback,
        )
        job_forks, job_join, job_active, job_overflow, job_next, map_sched = (
            fetched
        )
        # the region cursors advance on device; only the readback copy above
        # crosses to the host
        self._arena = dataclasses.replace(self._arena, next=summary.job_next)

        done: List[JobHandle] = []
        for j in chosen:
            r = self._regions[j]
            d = pops[j]
            if bool(job_overflow[j]):
                done.append(self._fail(j))
                continue
            if bool(job_join[j]):
                r.sched.push_join(d.cen, d.start, d.count)
            forks = int(job_forks[j])
            r.sched.push_forked(d.cen + 1, int(job_next[j]) - forks, forks)
            st = r.stats
            st.epochs += 1
            st.tasks_executed += int(job_active[j])
            st.total_forks += forks
            st.peak_tv_slots = max(
                st.peak_tv_slots, int(job_next[j]) - r.slot.base
            )
            st.shared_dispatches += shared_dispatches
            st.shared_transfers += shared_dispatches

        if bool(map_sched):
            self._heap = self._loop.maps.run(map_launches, self._heap, col)

        col.epoch(self._global_epochs,
                  sum(d.n_ranges for d in pops.values()))
        col.lanes(int(job_active.sum()), launched, by_type)
        col.forks(int(job_forks.sum()))
        col.tv_peak(int(job_next.max()))

        for j in chosen:
            r = self._regions[j]
            if r.running and not r.sched:
                done.append(self._finalize(j))
        return done

    def run(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        """Drive every admitted job to completion; return finished handles."""
        out: List[JobHandle] = []
        while self.live:
            if self._global_epochs >= max_epochs:
                raise RuntimeError(f"exceeded max_epochs={max_epochs}")
            out.extend(self.step())
        return out

    # ------------------------------------------------- streaming admission
    def admit(self, handle: JobHandle) -> bool:
        """Seed a queued job into a freed region, mid-flight.

        A region can be reused by any job whose program is *structurally
        equal* to the region's fused-in template (``Program.structural_hash``
        — same task/map/heap tables and task bytecode; the phase-2 trace is
        identical, so nothing retraces).  The new job may carry its own
        initial task, heap init, and a quota up to the region size.  Returns
        False when no compatible free region exists.
        """
        job = handle.job
        for r in self._regions:
            if r.handle is not None:
                continue
            s = r.slot
            if job.quota > s.quota:
                continue
            if s.program is not job.program and (
                s.program.structural_hash() != job.program.structural_hash()
            ):
                continue
            self._seed_region(r, handle)
            return True
        return False

    def _seed_region(self, r: _Region, handle: JobHandle) -> None:
        """Clear a freed region and seed the new tenant's root task."""
        job = handle.job
        s = r.slot
        sub = s.program
        sl = slice(s.base, s.end)
        tid = s.task_offset + sub.task_id(job.initial.task)
        ai, af = pack_args(self.program, job.initial.argi, job.initial.argf)
        st = self._state
        self._state = tvm.TVMState(
            task=st.task.at[sl].set(0).at[s.base].set(tid),
            argi=st.argi.at[sl].set(0).at[s.base].set(jnp.asarray(ai)),
            argf=st.argf.at[sl].set(0.0).at[s.base].set(jnp.asarray(af)),
            epoch=st.epoch.at[sl].set(0).at[s.base].set(1),
            value=st.value.at[sl].set(0),
            child_base=st.child_base.at[sl].set(0),
            child_count=st.child_count.at[sl].set(0),
            next_free=st.next_free,
        )
        self._arena = dataclasses.replace(
            self._arena,
            end=self._arena.end.at[s.index].set(s.base + job.quota),
            next=self._arena.next.at[s.index].set(s.base + 1),
        )
        for k, v in sub.init_heap(**dict(job.heap_init)).items():
            self._heap[s.prefix + k] = v
        sched = EpochScheduler(coalesce=self.coalesce)
        sched.reset(cen=1, start=s.base, count=1)
        r.handle = handle
        r.sched = sched
        r.stats = JobStats()
        r.active_quota = job.quota
        handle.status = JobStatus.RUNNING


# --------------------------------------------------------------------------
# Resident driver
# --------------------------------------------------------------------------
class DeviceMultiplexer(_FleetBase):
    """Device-resident wave execution (DESIGN.md §9).

    The whole admitted fleet runs to completion inside one
    ``lax.while_loop``: per-region scheduler stacks live on device
    (``batched_device_stacks``), the :class:`~repro.core.tvm.JobArena`
    region cursors and per-region trailing reclamation ride the loop carry,
    and every region's pop is fused into one per-lane epoch-number vector
    per iteration.  Per-wave V_inf is O(1): one dispatch + one scalar
    readback for the entire wave, vs one per global epoch on
    :class:`EpochMultiplexer` — while per-job results stay bit-identical to
    solo ``HostEngine.run``.

    The trade (host-mux-only features): no streaming completion, no
    mid-flight region reuse (``admit`` always refuses — queued jobs wait for
    the next wave), no gang policies (every live region pops each global
    epoch, i.e. ``fuse_all``), and masked dispatch only.  A job overflowing
    its region fails alone: its stack pointer zeroes and its neighbours
    keep running.
    """

    def __init__(
        self,
        handles: Sequence[JobHandle],
        capacity: Optional[int] = None,
        dispatch: Any = "masked",
        stack_depth: int = 1 << 10,
        collect_stats: bool = True,
        stats_factory=None,
        seg_offsets_fn=None,
    ):
        super().__init__(
            handles, capacity=capacity,
            collect_stats=collect_stats, stats_factory=stats_factory,
        )
        if resolve_policy(dispatch).name != "masked":
            raise ValueError(_COMPACTED_RESIDENT_MSG)
        self.stack_depth = stack_depth
        self._loop = EpochLoop(
            self.program, dispatch,
            seg_offsets_fn=seg_offsets_fn, skip_idle_types=True,
        )
        self.policy = self._loop.policy
        self._ran = False

    def step(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        """Run the *entire wave* to completion in one resident loop.

        Returns every handle (DONE or FAILED) in region order; subsequent
        calls return [] (the wave is closed — resubmit through a new wave).
        """
        if self._ran or not self.live:
            return []
        self._ran = True
        J = len(self._slots)
        jstack, rstack, sp = batched_device_stacks(
            J, self.stack_depth,
            cens=np.ones(J, np.int32),
            starts=np.asarray([s.base for s in self._slots], np.int32),
            counts=np.ones(J, np.int32),
        )
        carry = _fresh_resident_carry(
            self._state, self._heap, self._arena, jstack, rstack, sp,
            n_regions=J,
        )
        out = self._loop.run_resident(carry, max_epochs, n_regions=J)
        # the wave's one scalar readback
        (failed, failed_stack, sp_left, n_epochs, job_epochs, job_tasks,
         job_forks, job_peak, m_ct, m_el, m_ln) = jax.device_get(
            (
                out.failed, out.failed_stack, out.sp, out.n_epochs,
                out.job_epochs, out.job_tasks, out.job_forks, out.job_peak,
                out.map_launches, out.map_elements, out.map_lanes,
            )
        )
        # a region still holding stack entries hit the epoch guard: fail it
        # (like an overflow — its schedule is unfinished) so the wave always
        # terminates with every handle resolved, never wedged RUNNING
        timed_out = np.asarray(sp_left) > 0
        failed = np.asarray(failed) | timed_out
        self._state = out.state
        self._heap = out.heap
        self._arena = out.arena

        col = self._col
        col.dispatch()
        col.transfer()
        # every global epoch fused all regions still live then; O(1) bulk
        # accounting from the readback, same ledger as the host driver
        col.epoch(int(n_epochs), n_ranges=int(job_epochs.sum()),
                  n=int(n_epochs))
        col.lanes(int(job_tasks.sum()), int(n_epochs) * self.capacity, None)
        col.forks(int(job_forks.sum()))
        col.tv_peak(int((job_peak + np.asarray(
            [s.base for s in self._slots])).max()) if J else 0)
        if int(m_ct):
            # map payloads launched in-loop: fold the carry's totals in
            col.map_launch(_hilo_value(m_el), _hilo_value(m_ln),
                           n=int(m_ct))

        done: List[JobHandle] = []
        for j in range(J):
            r = self._regions[j]
            if not r.running:
                continue
            r.stats = JobStats(
                epochs=int(job_epochs[j]),
                tasks_executed=int(job_tasks[j]),
                total_forks=int(job_forks[j]),
                peak_tv_slots=int(job_peak[j]),
                shared_dispatches=1,
                shared_transfers=1,
            )
            if bool(failed[j]):
                if bool(timed_out[j]):
                    reason = f"exceeded max_epochs={max_epochs}"
                elif bool(failed_stack[j]):
                    reason = (
                        f"job {r.handle.job.name!r} exhausted the resident "
                        f"scheduler stack: stack_depth={self.stack_depth}"
                    )
                else:
                    reason = None  # TV region overflow: the default message
                done.append(self._fail(j, reason=reason))
            else:
                done.append(self._finalize(j))
        return done

    def run(self, max_epochs: int = 1 << 20) -> List[JobHandle]:
        """API parity with :class:`EpochMultiplexer`."""
        return self.step(max_epochs=max_epochs)

    def admit(self, handle: JobHandle) -> bool:
        """Resident waves are closed: no mid-flight admission (the trade for
        O(1) per-wave V_inf — the host never sees a freed region until the
        whole wave drains)."""
        return False
