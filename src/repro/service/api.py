"""Execution surface of the layered serving front door (DESIGN.md §16).

:class:`JobService` is the multi-tenant front door: ``submit`` enqueues a
program (any app, any arguments) with a TV-region quota — optionally under
a :class:`~repro.service.admission.QuotaClass` with a priority and a
deadline — ``poll`` reports its lifecycle state, ``result`` drives the
fleet until that job finishes, and ``completions`` streams handles the
moment each job's scheduler drains.  ``submit_async`` /
:meth:`JobService.stream_results` are the non-blocking face of the same
queue: a :class:`JobFuture` awaits one job while the service keeps pumping
waves cooperatively, so callers never block on a whole wave.

The service stack is three layers (``admission.py`` module docstring):
the :class:`~repro.service.admission.AdmissionController` decides *which*
queued jobs form the next wave and *who* yields a region (EDF within
priority, class shares, token buckets, preemption plans); the wave
drivers in ``multiplexer.py``/``distributed/fleet.py`` execute those
plans at chunk boundaries through the one reseed path; this module is the
surface that wires them together.

The service runs jobs in *waves*: a wave is one fused
:class:`~repro.service.multiplexer.EpochMultiplexer` fleet (up to
``max_jobs`` jobs whose quotas fit the capacity budget and whose value
dtypes agree).  While a wave is in flight, queued jobs whose program
template matches a freed region are admitted mid-flight (streaming
multi-tenancy, no retrace); everything else waits for the next wave.  At
each chunk boundary the admission layer may also *preempt*: a running
job lifts into an engine-agnostic
:class:`~repro.service.jobs.RegionCheckpoint` and re-queues, its region
goes to a strictly-higher-priority waiter, and the resumed run stays
bit-identical to an uninterrupted one.
"""
from __future__ import annotations

import asyncio
import itertools
import time
from typing import (
    Any, AsyncIterator, Callable, Dict, Iterator, List, Mapping, Optional,
)

from ..core.program import InitialTask, Program
from ..core.scheduler import RunStats
from .admission import AdmissionController, QuotaClass
from .jobs import (
    AdmissionError,
    Job,
    JobHandle,
    JobResult,
    JobStatus,
    WaveTemplate,
    WaveTemplateCache,
    canonical_wave_order,
    check_fleet_dtype,
    validate_job,
    wave_template_key,
)
from .multiplexer import DeviceMultiplexer, EpochMultiplexer


def merge_stats(into: RunStats, s: RunStats) -> RunStats:
    """Accumulate one wave's fleet stats into a running total.

    Kept as an exported alias; the merge itself lives on
    :meth:`~repro.core.scheduler.RunStats.merge` (one source of truth,
    next to ``as_dict`` — the shared metric vocabulary).
    """
    return into.merge(s)


class JobFuture:
    """Awaitable face of one submitted job.

    Awaiting it drives the service cooperatively — one :meth:`JobService.
    _pump` per event-loop turn, yielding control between pumps — until
    *this* job reaches a terminal state.  Any number of futures may be
    awaited concurrently (``asyncio.gather``): they share the service's
    single-threaded pump, so progress interleaves without locks and
    whichever future's job finishes first resolves first.
    """

    def __init__(self, service: "JobService", handle: JobHandle):
        self.service = service
        self.handle = handle

    @property
    def job_id(self) -> int:
        return self.handle.job_id

    @property
    def status(self) -> JobStatus:
        return self.handle.status

    def done(self) -> bool:
        return self.handle.done

    async def result(self) -> JobResult:
        h = self.handle
        while not h.done:
            if not self.service._pending():
                raise RuntimeError(
                    f"job {h.job.name!r} cannot make progress"
                )
            self.service._pump()
            await asyncio.sleep(0)
        if h.status is JobStatus.FAILED:
            raise h.error
        return h.result

    def __await__(self):
        return self.result().__await__()


class JobService:
    """Multi-tenant job service over one shared TVM.

    ``capacity`` is the slot budget a wave's quotas must fit in;
    ``max_jobs`` bounds a wave's fan-in; ``dispatch``/``coalesce`` select
    the phase-2 policy for the fused fleet exactly as on ``HostEngine``;
    ``pop_policy``/``gang`` pick the multi-stack pop policy
    (:class:`~repro.core.scheduler.MuxPopPolicy`).

    ``engine`` picks the wave driver: ``"host"`` (default) runs each wave
    on the host-loop :class:`~repro.service.multiplexer.EpochMultiplexer` —
    per-global-epoch V_inf, with streaming completion and mid-flight region
    reuse; ``"device"`` runs each wave resident inside a ``lax.while_loop``
    (:class:`~repro.service.multiplexer.DeviceMultiplexer`, DESIGN.md
    §9–10).  ``chunk`` (device engine only) is the K-knob: the resident
    loop re-enters every K epochs, paying ⌈epochs/K⌉ readbacks per wave in
    exchange for streaming completions and mid-flight region reuse at the
    chunk boundaries; ``chunk=None`` (default) is the fully-resident
    endpoint — O(1) V_inf per wave, completions surface per wave, queued
    jobs wait for the next wave.

    Device waves compile through a :class:`~repro.service.jobs.
    WaveTemplateCache`: structurally identical consecutive waves (same
    member ``structural_hash``es, quotas, capacity, stack depth, K,
    dispatch, and chunk driver) reuse one compiled chunk loop instead of
    retracing; ``trace_count`` exposes the compile-count guard.

    ``megakernel`` (device engine only) runs each resident chunk as one
    persistent Pallas kernel (``kernels/epoch_megakernel.py``) instead of
    the XLA ``while_loop`` — bit-identical results and stats, same ⌈E/K⌉
    readback cadence; ``dispatch="gather"`` on the device engine packs
    each epoch's scheduled lanes into a fixed-shape segmented frontier so
    union-span hole lanes are never stepped (DESIGN.md §12).

    ``engine="sharded"`` scales the device engine out: ``shards`` full
    device waves — same slot layout, one shared compiled template — run
    together on a 1-D ``"fleet"`` device mesh (DESIGN.md §15), one fused
    launch and one stacked readback per collective chunk.  ``placement``
    (``round_robin`` / ``least_loaded`` / ``sticky``) assigns queued jobs
    to shards; ``rebalance`` migrates jobs off hot shards at chunk
    boundaries.  Per-job results stay bit-identical to solo at every P.

    ``calibrate`` (default on) seeds ``dispatch="auto"``'s controller
    with a :meth:`~repro.control.controller.CostModel.calibrated` micro
    -probe of this host at service start — cached per process, so only
    the first service constructed ever pays it (DESIGN.md §14).
    """

    def __init__(
        self,
        capacity: int = 1 << 14,
        max_jobs: int = 8,
        dispatch: Any = "masked",
        coalesce: bool = True,
        pop_policy: Any = "fuse_all",
        gang: int = 0,
        default_quota: int = 1 << 10,
        collect_stats: bool = True,
        rank_fn=None,
        engine: str = "host",
        stack_depth: int = 1 << 10,
        chunk: Optional[int] = None,
        template_cache: Optional[WaveTemplateCache] = None,
        megakernel: bool = False,
        megakernel_impl: str = "auto",
        metrics=None,
        tracer=None,
        shards: int = 1,
        placement: str = "round_robin",
        rebalance: bool = True,
        calibrate: bool = True,
        classes: Optional[List[QuotaClass]] = None,
        admission: Optional[AdmissionController] = None,
        preemption: bool = True,
        evict_over_deadline: bool = False,
        clock: Callable[[], float] = time.monotonic,
    ):
        if engine not in ("host", "device", "sharded"):
            raise ValueError(
                "engine must be 'host', 'device' or 'sharded', "
                f"got {engine!r}"
            )
        if engine == "sharded":
            from ..distributed.fleet import PLACEMENTS

            if shards < 1:
                raise ValueError(f"shards must be >= 1, got {shards}")
            if placement not in PLACEMENTS + ("auto",):
                raise ValueError(
                    f"placement must be one of {PLACEMENTS + ('auto',)}, "
                    f"got {placement!r}"
                )
        elif shards != 1:
            raise ValueError(
                "shards requires engine='sharded' (host/device waves run "
                f"one TVM); got shards={shards}"
            )
        if engine in ("device", "sharded"):
            from ..core.scheduler import resolve_policy

            if resolve_policy(dispatch).name not in (
                "masked", "gather", "auto"
            ):
                raise ValueError(
                    f"engine={engine!r} supports dispatch='masked', "
                    "'gather' or 'auto' (resident launch shapes are fixed "
                    "at trace time; compacted sizes launches from runtime "
                    "populations and is host-only)"
                )
            if gang or pop_policy != "fuse_all":
                raise ValueError(
                    f"engine={engine!r} runs every live region each epoch "
                    "(fuse_all); gang/pop_policy are host-engine options"
                )
            if chunk == "auto":
                pass  # adaptive K: a ChunkController owns the cadence
            elif isinstance(chunk, str):
                raise ValueError(
                    f"chunk must be >= 1, None, or 'auto'; got {chunk!r}"
                )
            elif chunk is not None and chunk < 1:
                raise ValueError(f"chunk must be >= 1 or None, got {chunk}")
        elif chunk is not None:
            raise ValueError(
                "chunk sets the resident readback cadence; it requires "
                "engine='device' (the host engine reads back every epoch)"
            )
        elif megakernel:
            raise ValueError(
                "megakernel fuses the resident chunk loop; it requires "
                "engine='device' (the host engine has no resident loop)"
            )
        self.engine = engine
        self.shards = int(shards)
        self.placement = placement
        self.rebalance = bool(rebalance)
        self.stack_depth = stack_depth
        self.chunk = chunk
        self.megakernel = bool(megakernel)
        self.megakernel_impl = megakernel_impl
        self.template_cache = (
            template_cache if template_cache is not None
            else WaveTemplateCache()
        )
        self.capacity = capacity
        self.max_jobs = max_jobs
        self.dispatch = dispatch
        self.coalesce = coalesce
        self.pop_policy = pop_policy
        self.gang = gang
        self.default_quota = default_quota
        self.collect_stats = collect_stats
        self._rank_fn = rank_fn
        # observability (DESIGN.md §13), both opt-in: ``metrics`` is a
        # MetricsRegistry fed with per-wave run series (via the collector
        # adapter) and the per-tenant job lifecycle series below; ``tracer``
        # receives epoch/chunk span timelines from the wave drivers
        self.metrics = metrics
        self.tracer = tracer
        # self-tuning (DESIGN.md §14): the controllers live on the service
        # so what they learn carries across waves.  The dispatch controller
        # is shared by every wave's loop (host: per-epoch decisions;
        # device: one resolution per new wave shape, sticky via the
        # template cache); the chunk controller owns K across waves.
        from ..core.scheduler import resolve_policy as _rp

        self.controller = None
        if _rp(dispatch).name == "auto":
            from ..control.controller import CostModel, DispatchController

            # calibrate by default: the controller's priors come from a
            # one-shot micro-probe of *this* host (process-cached, so only
            # the first service pays it) instead of the static roofline
            # constants — DESIGN.md §14's "calibrate once, decide often"
            cost = CostModel.calibrated() if calibrate else None
            self.controller = DispatchController(cost=cost)
            if metrics is not None:
                self.controller.bind_registry(
                    metrics, driver=engine, app="service"
                )
        self.chunk_controller = None
        if chunk == "auto":
            from ..control.controller import ChunkController

            self.chunk_controller = ChunkController()
            if metrics is not None:
                self.chunk_controller.bind_registry(metrics, app="service")
        # placement="auto" (sharded): the controller lives here so its
        # workload-mix window carries across waves, like the K controller
        self.placement_controller = None
        if engine == "sharded" and placement == "auto":
            from ..control.controller import PlacementController

            self.placement_controller = PlacementController()
            if metrics is not None:
                self.placement_controller.bind_registry(
                    metrics, app="service"
                )
        # admission layer (DESIGN.md §16): the policy brain this surface
        # delegates wave assembly and preemption planning to.  An explicit
        # controller wins (its clock becomes the service clock so handle
        # stamps and deadline arithmetic share one timebase).
        if admission is not None:
            self.admission = admission
            self._clock = admission.clock
        else:
            self.admission = AdmissionController(
                classes=classes, clock=clock,
                evict_over_deadline=evict_over_deadline,
            )
            self._clock = clock
        self.preemption = bool(preemption)
        self._ids = itertools.count()
        self._queue: List[JobHandle] = []
        self._handles: Dict[int, JobHandle] = {}
        self._mux: Optional[EpochMultiplexer] = None
        self._stats = RunStats()
        self._admit_ready = False  # a region was freed since the last scan

    # ------------------------------------------------------- observability
    def _stats_factory(self):
        """Per-wave collector factory: the plain collector when metrics are
        off (the disabled path allocates nothing extra), the registry
        adapter around it when on."""
        if self.metrics is None:
            return None
        from ..core.scheduler import NullStats, RunStatsCollector, \
            resolve_policy
        from ..obs.metrics import MetricsCollector

        registry = self.metrics
        driver = self.engine
        dispatch = resolve_policy(self.dispatch).name
        collect = self.collect_stats

        def factory():
            inner = RunStatsCollector() if collect else NullStats()
            return MetricsCollector(
                inner, registry, driver=driver, dispatch=dispatch,
                app="service",
            )

        return factory

    def _sharded_stats_factory(self):
        """Per-shard collector factory for the sharded engine: same series
        as :meth:`_stats_factory` with a ``shard`` label on every one, so
        per-shard utilization and work splits are scrapeable directly.
        (A registry pins labelnames per metric name, so keep one registry
        per engine flavor — sharded services label ``shard`` on every
        run-series metric, solo services label none.)"""
        if self.metrics is None:
            return None
        from ..core.scheduler import NullStats, RunStatsCollector, \
            resolve_policy
        from ..obs.metrics import MetricsCollector

        registry = self.metrics
        dispatch = resolve_policy(self.dispatch).name
        collect = self.collect_stats

        def factory(p: int):
            inner = RunStatsCollector() if collect else NullStats()
            return MetricsCollector(
                inner, registry, driver="sharded", dispatch=dispatch,
                app="service", shard=str(p),
            )

        return factory

    def _observe_completions(self, done: List[JobHandle]) -> None:
        """Record deadline outcomes with the admission layer and feed the
        per-tenant/per-class lifecycle series for newly finished jobs:
        queue-wait and run-time latency histograms, completion counters by
        terminal status, and the per-class deadline scoreboard."""
        # admission accounting happens with or without a registry
        outcomes = {
            h.job_id: self.admission.note_finished(h) for h in done
        }
        if self.metrics is None or not done:
            return
        r = self.metrics
        lab = ("tenant",)
        qw = r.histogram(
            "trees_job_queue_wait_seconds",
            "seconds from submit to first co-scheduled epoch", lab,
        )
        rt = r.histogram(
            "trees_job_run_seconds",
            "seconds from first co-scheduled epoch to completion", lab,
        )
        fin = r.counter(
            "trees_jobs_finished_total",
            "jobs reaching a terminal status", ("tenant", "status"),
        )
        # per-class series (new names: the registry pins labelnames per
        # metric, so class-labeled series cannot share the tenant ones)
        cqw = r.histogram(
            "trees_class_queue_wait_seconds",
            "queue wait by quota class", ("klass",),
        )
        dmiss = r.counter(
            "trees_deadline_misses_total",
            "deadlined jobs finishing past their deadline", ("klass",),
        )
        dmet = r.counter(
            "trees_deadlines_met_total",
            "deadlined jobs finishing in time", ("klass",),
        )
        ratio = r.gauge(
            "trees_deadline_miss_ratio",
            "misses / (misses + met) per quota class", ("klass",),
        )
        for h in done:
            tenant = h.job.name or h.job.program.name
            if h.queue_wait is not None:
                qw.labels(tenant=tenant).observe(h.queue_wait)
                cqw.labels(klass=h.klass).observe(h.queue_wait)
            if h.run_time is not None:
                rt.labels(tenant=tenant).observe(h.run_time)
            fin.labels(tenant=tenant, status=h.status.value).inc()
            met = outcomes[h.job_id]
            if met is True:
                dmet.labels(klass=h.klass).inc()
            elif met is False:
                dmiss.labels(klass=h.klass).inc()
            if met is not None:
                ratio.labels(klass=h.klass).set(
                    self.admission.miss_ratio(h.klass)
                )
        # completions follow the wave's compiled steps, so the trace-count
        # gauge set at lookup time (pre-compile) is refreshed here with
        # whatever the wave actually traced
        r.gauge(
            "trees_wave_template_traces",
            "traced builder bodies across all wave templates",
        ).labels().set(self.template_cache.trace_count)

    def _observe_preemption(self, h: JobHandle) -> None:
        """Count one executed preemption, labeled by quota class."""
        if self.metrics is None:
            return
        self.metrics.counter(
            "trees_job_preemptions_total",
            "running jobs checkpointed and re-queued at a chunk boundary",
            ("klass",),
        ).labels(klass=h.klass).inc()

    def _observe_template_cache(self, hit: bool) -> None:
        """Mirror the wave-template cache's reuse counters into the
        registry (hit/miss per wave build, LRU evictions, plus the
        monotone trace-count gauge the compile-regression guard
        watches)."""
        if self.metrics is None:
            return
        r = self.metrics
        r.counter(
            "trees_wave_template_lookups_total",
            "wave-template cache lookups", ("outcome",),
        ).labels(outcome="hit" if hit else "miss").inc()
        r.gauge(
            "trees_wave_template_evictions",
            "wave templates LRU-evicted from the cache so far",
        ).labels().set(self.template_cache.evictions)
        r.gauge(
            "trees_wave_template_traces",
            "traced builder bodies across all wave templates",
        ).labels().set(self.template_cache.trace_count)

    # ------------------------------------------------------------- submit
    def submit(
        self,
        program: Program,
        initial: InitialTask,
        heap_init: Optional[Mapping[str, Any]] = None,
        quota: Optional[int] = None,
        name: str = "",
        priority: int = 0,
        deadline: Optional[float] = None,
        klass: str = "default",
    ) -> JobHandle:
        """Admit a job into the queue; raises AdmissionError if it can
        never run on this service.

        ``priority`` orders admission (higher first; overrides the class
        priority when nonzero) and gates preemption — a queued job evicts
        running work only when strictly higher-priority.  ``deadline`` is
        *relative* seconds from now on the service clock; the admission
        layer schedules EDF within each priority band, tightens the chunk
        cadence as it approaches, and scores met/missed per class.
        ``klass`` names a :class:`~repro.service.admission.QuotaClass`
        configured at service construction."""
        job = Job(
            program=program,
            initial=initial,
            heap_init=dict(heap_init or {}),
            quota=int(quota or self.default_quota),
            name=name or program.name,
        )
        validate_job(job, self.capacity)
        if klass not in self.admission.classes:
            raise AdmissionError(
                f"job {job.name!r}: unknown quota class {klass!r} "
                f"(known: {sorted(self.admission.classes)})"
            )
        handle = JobHandle(
            job_id=next(self._ids), job=job, clock=self._clock,
            priority=int(priority),
            deadline=(
                None if deadline is None else self._clock() + deadline
            ),
            klass=klass,
        )
        self._handles[handle.job_id] = handle
        self._queue.append(handle)
        return handle

    def submit_case(self, case, quota: Optional[int] = None,
                    name: str = "", **kw) -> JobHandle:
        """Submit a registered :class:`~repro.apps.registry.AppCase`."""
        return self.submit(
            case.program,
            case.initial,
            heap_init=dict(case.heap_init),
            quota=quota or case.capacity,
            name=name or case.name,
            **kw,
        )

    def submit_async(self, *args, **kw) -> JobFuture:
        """:meth:`submit`, wrapped in an awaitable :class:`JobFuture`."""
        return JobFuture(self, self.submit(*args, **kw))

    # -------------------------------------------------------------- query
    def poll(self, handle: JobHandle) -> JobStatus:
        return handle.status

    def result(self, handle: JobHandle) -> JobResult:
        """Drive the service until this job finishes; raise on failure."""
        while not handle.done:
            if not self._pending():
                raise RuntimeError(
                    f"job {handle.job.name!r} cannot make progress"
                )
            self._pump()
        if handle.status is JobStatus.FAILED:
            raise handle.error
        return handle.result

    # ------------------------------------------------------------- driving
    def completions(self) -> Iterator[JobHandle]:
        """Stream handles as they complete (DONE or FAILED)."""
        while self._pending():
            for h in self._pump():
                yield h

    def drain(self) -> List[JobHandle]:
        """Run every submitted job to completion; return all handles in
        completion order."""
        return list(self.completions())

    async def stream_results(self) -> AsyncIterator[JobHandle]:
        """Async face of :meth:`completions`: yield handles as they
        finish, ceding the event loop between pumps so concurrent
        coroutines (more submits, per-job awaits) interleave."""
        while self._pending():
            for h in self._pump():
                yield h
            await asyncio.sleep(0)

    def preempt(self, handle: JobHandle) -> bool:
        """Preempt one running job at the next opportunity *now*: lift it
        into its checkpoint, re-queue it, free its region.  Returns False
        if the job is not currently seated (queued, finished, or the wave
        driver cannot checkpoint mid-flight — e.g. an unchunked resident
        wave has no boundary to capture at)."""
        if self._mux is None or not self._mux.preempt(handle):
            return False
        self.admission.note_preempted(handle)
        self._observe_preemption(handle)
        self._queue.append(handle)
        self._admit_ready = True
        return True

    def stats(self) -> RunStats:
        """Fleet-level stats accumulated across every wave so far."""
        total = merge_stats(RunStats(), self._stats)
        if self._mux is not None:
            merge_stats(total, self._mux.stats())
        return total

    @property
    def trace_count(self) -> int:
        """Traced builder bodies across every device wave template — the
        compile-count regression guard: after a wave, an identical
        consecutive wave must leave this unchanged (its chunks run entirely
        on the cached compiled loop)."""
        return self.template_cache.trace_count

    # ------------------------------------------------------------ internal
    def _queue_probe(self):
        """Queue-heat signal for the chunk controller: (queued jobs, the
        oldest queued job's wait in seconds, seconds of slack to the
        nearest outstanding deadline).  The first two are the same
        quantities exported as ``trees_job_queue_wait_seconds``; the third
        lets the controller tighten K *before* a deadline, not after."""
        running = (
            self._mux.running_handles() if self._mux is not None else ()
        )
        slack = self.admission.deadline_slack(self._queue, running)
        if not self._queue:
            return (0, 0.0, slack)
        now = self._clock()
        return (
            len(self._queue),
            max(now - h.submitted_at for h in self._queue),
            slack,
        )

    def _pending(self) -> bool:
        return bool(self._queue) or (self._mux is not None and self._mux.live)

    def _pump(self) -> List[JobHandle]:
        """Make one unit of progress: (re)build or refill the fleet, then
        run one fused global epoch.  Returns newly completed handles."""
        if self._mux is not None and not self._mux.live:
            merge_stats(self._stats, self._mux.stats())
            self._mux = None
        if self._mux is None:
            wave = self._take_wave()
            if not wave:
                return []
            if self.engine in ("device", "sharded"):
                # seat members in canonical order so a permutation of an
                # earlier wave lands on the same slot layout as its cached
                # template (the key is canonical too); each job's results
                # attach to its own handle, so no un-permuting is needed
                order = canonical_wave_order([h.job for h in wave])
                wave = [wave[i] for i in order]
                from ..core.engine import resolve_resident_dispatch

                jobs = [h.job for h in wave]
                cap = sum(h.job.quota for h in wave)

                def _peek(cand: str):
                    # sticky per wave shape: a cached template's baked
                    # mode wins before the controller is ever consulted,
                    # so an identical consecutive wave can never retrace
                    # on a flipped decision; a *new* shape falls through
                    # to the controller's accumulated cross-wave window
                    return self.template_cache.peek(wave_template_key(
                        jobs, cap, self.stack_depth, self.chunk,
                        dispatch=cand, megakernel=self.megakernel,
                    ))

                dispatch_name = resolve_resident_dispatch(
                    self.dispatch, self.controller, cap, peek=_peek
                )
                # the key is deliberately NOT a function of `shards`: a
                # sharded fleet replicates ONE per-shard wave, so the same
                # compiled template serves the solo wave and every P
                key = wave_template_key(
                    jobs, cap,
                    self.stack_depth, self.chunk,
                    dispatch=dispatch_name,
                    megakernel=self.megakernel,
                )
                tpl = self.template_cache.lookup(key)
                self._observe_template_cache(hit=tpl is not None)
                if self.engine == "sharded":
                    from ..distributed.fleet import ShardedFleet

                    self._mux = ShardedFleet(
                        wave,
                        shards=self.shards,
                        dispatch=dispatch_name,
                        stack_depth=self.stack_depth,
                        chunk=self.chunk,
                        placement=self.placement,
                        placement_controller=self.placement_controller,
                        rebalance=self.rebalance,
                        collect_stats=self.collect_stats,
                        stats_factory=self._sharded_stats_factory(),
                        template=tpl,
                        megakernel=self.megakernel,
                        megakernel_impl=self.megakernel_impl,
                        tracer=self.tracer,
                        controller=self.controller,
                        chunk_controller=self.chunk_controller,
                        queue_probe=self._queue_probe,
                    )
                    tpl_built = self._mux.template
                    # the whole queue streams into the fleet's placement
                    # queues up front: the anchor wave sized ONE shard's
                    # layout, the other P-1 shards start vacant and fill
                    # from here (and from later submits via streaming
                    # admission)
                    still = [
                        h for h in self._queue if not self._mux.admit(h)
                    ]
                    self._queue = still
                else:
                    self._mux = DeviceMultiplexer(
                        wave,
                        dispatch=dispatch_name,
                        stack_depth=self.stack_depth,
                        chunk=self.chunk,
                        collect_stats=self.collect_stats,
                        stats_factory=self._stats_factory(),
                        template=tpl,
                        megakernel=self.megakernel,
                        megakernel_impl=self.megakernel_impl,
                        tracer=self.tracer,
                        controller=self.controller,
                        chunk_controller=self.chunk_controller,
                        queue_probe=self._queue_probe,
                    )
                    tpl_built = WaveTemplate(
                        key=key,
                        program=self._mux.program,
                        slots=self._mux.slots,
                        loop=self._mux.loop,
                    )
                if tpl is None:
                    self.template_cache.store(
                        WaveTemplate(
                            key=key,
                            program=tpl_built.program,
                            slots=tpl_built.slots,
                            loop=tpl_built.loop,
                        )
                    )
            else:
                self._mux = EpochMultiplexer(
                    wave,
                    dispatch=self.dispatch,
                    coalesce=self.coalesce,
                    pop_policy=self.pop_policy,
                    gang=self.gang,
                    collect_stats=self.collect_stats,
                    stats_factory=self._stats_factory(),
                    rank_fn=self._rank_fn,
                    tracer=self.tracer,
                    controller=self.controller,
                )
            self._admit_ready = False
        elif self._admit_ready and self._queue:
            # streaming admission: seed queued jobs into regions freed by
            # the completions (or preemptions) of the previous step — a
            # region can only free at those events, so skip the scan on
            # every other epoch
            self._admit_queued()
            self._admit_ready = False
        done = self._mux.step()
        if done:
            self._admit_ready = True
            self._observe_completions(done)
        # preemption (DESIGN.md §16): the step just crossed a chunk
        # boundary, the only place a region can yield.  Seat what free
        # regions absorb first — a free region always beats evicting work
        # — then ask admission who must yield for whoever is still stuck.
        if self.preemption and self._queue and self._mux.live:
            self._admit_queued()
            victims = self.admission.plan_preemptions(
                self._mux.running_handles(), self._queue
            ) if self._queue else []
            for v in victims:
                if self._mux.preempt(v):
                    self.admission.note_preempted(v)
                    self._observe_preemption(v)
                    self._queue.append(v)
                    self._admit_ready = True
        return done

    def _admit_queued(self) -> int:
        """Try to seat queued jobs into free regions of the live wave, in
        admission order, consuming class rate tokens per seat."""
        seated = 0
        still: List[JobHandle] = []
        for h in self.admission.order(self._queue):
            if (
                self.admission.has_token(h)
                and self._mux.admit(h)
                and self.admission.allow(h)
            ):
                seated += 1
            else:
                still.append(h)
        still.sort(key=lambda h: h.job_id)
        self._queue = still
        return seated

    def _take_wave(self) -> List[JobHandle]:
        """Assemble the next wave — delegated to the admission layer.

        :meth:`~repro.service.admission.AdmissionController.take_wave`
        packs first-fit in admission order (priority desc, EDF, FIFO)
        under the capacity / max_jobs / dtype / class-share budgets.
        With no priorities, deadlines, or class limits configured this is
        exactly the greedy FIFO first-fit this method used to inline.
        """
        wave, self._queue = self.admission.take_wave(
            self._queue, self.capacity, self.max_jobs
        )
        return wave
