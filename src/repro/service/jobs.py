"""Job lifecycle for the epoch-multiplexing service.

A *job* is one tenant's task-parallel program — its own :class:`Program`,
seed task, heap initialization, and a slot *quota* (the size of the private
Task Vector region it is granted inside the shared TVM).  The service admits
jobs against a capacity budget, runs them co-scheduled with every other
admitted job (``multiplexer.py``), and reclaims the region the moment the
job's scheduler drains, so a queued job can take its place.

Admission control is deliberately *static*: everything checkable before the
first epoch — quota bounds, seed-task resolution, value-dtype uniformity
across the fleet — is checked at submit/fuse time and raises
:class:`AdmissionError`; the only runtime failure mode left is a job
outgrowing its own quota, which fails *that job alone* (its fork scatters
are bounded by its region end, so a runaway tenant cannot corrupt a
neighbour).
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time
from typing import (
    Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple,
)

import jax.numpy as jnp
import numpy as np

from ..core.program import InitialTask, Program


class JobStatus(enum.Enum):
    QUEUED = "queued"        # submitted, waiting for a region
    RUNNING = "running"      # co-scheduled in the shared TVM
    PREEMPTED = "preempted"  # checkpointed at a boundary, requeued
    DONE = "done"            # scheduler drained; result extracted
    FAILED = "failed"        # outgrew its quota (region overflow)


class AdmissionError(ValueError):
    """Job rejected before execution (quota / compatibility checks)."""


class JobFailure(RuntimeError):
    """Job failed at runtime (its own region overflowed)."""


@dataclasses.dataclass(frozen=True)
class Job:
    """One tenant program: what a solo ``HostEngine.run`` call would take,
    plus the TV-region quota the service reserves for it."""

    program: Program
    initial: InitialTask
    heap_init: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    quota: int = 1 << 10
    name: str = ""


@dataclasses.dataclass
class JobStats:
    """Per-job accounting in solo-comparable terms.

    ``epochs``/``tasks_executed``/``total_forks``/``peak_tv_slots`` match the
    solo run's :class:`~repro.core.RunStats` fields exactly (the region is a
    bit-identical shifted copy of the solo TV).  ``shared_dispatches`` /
    ``shared_transfers`` count the *fused* launches this job rode along on —
    the whole point of the service is that these sum to far less across a
    fleet than the solo runs' totals.
    """

    epochs: int = 0
    tasks_executed: int = 0
    total_forks: int = 0
    peak_tv_slots: int = 0
    shared_dispatches: int = 0
    shared_transfers: int = 0

    def solo_dict(self) -> Dict[str, int]:
        """The four fields a solo ``RunStats`` must match bit-for-bit.

        ``shared_dispatches``/``shared_transfers`` are *service* economics
        (how many fused launches the job rode on) and legitimately differ
        between an uninterrupted run and a preempt/resume round trip; the
        solo-comparable fields may not.
        """
        return {
            "epochs": self.epochs,
            "tasks_executed": self.tasks_executed,
            "total_forks": self.total_forks,
            "peak_tv_slots": self.peak_tv_slots,
        }


@dataclasses.dataclass
class JobResult:
    """What a solo run returns, extracted from the job's region.

    ``heap`` carries the job's *own* heap names (the service strips its
    tenant namespace); ``value`` is the region's TV-value block, shape
    ``[quota, value_width]`` in the job's own value width — bit-identical to
    a solo ``HostEngine.run`` with ``capacity=quota``.
    """

    heap: Dict[str, jnp.ndarray]
    value: jnp.ndarray
    stats: JobStats


@dataclasses.dataclass
class RegionCheckpoint:
    """A preempted job's region, lifted off the wave at a chunk boundary.

    Engine-agnostic: the host multiplexer's per-region
    :class:`~repro.core.scheduler.EpochScheduler` and the resident drivers'
    stack rows share one discipline (list index <-> stack row, LIFO with
    same-CEN coalescing), so both capture into and restore from this one
    form.  Everything position-dependent is stored *region-relative*
    (``child_base``, range starts, the arena cursor), which is exactly the
    "bit-identical shifted copy" invariant that already justifies region
    reuse — restore may land the job in a *different* region of a
    *different* wave and still replay identically.
    """

    structural_hash: Any    # whatever Program.structural_hash() returns
    quota: int
    # TV columns, sliced to [quota, ...]; child_base is region-relative.
    tv: Dict[str, np.ndarray]
    # tenant-local heap (namespace prefix already stripped)
    heap: Dict[str, Any]
    arena_next_off: int        # arena cursor - region base
    sp: int                    # scheduler stack depth at capture
    jstack: np.ndarray         # i32[sp]   pending CENs (bottom -> top)
    rstack: np.ndarray         # i32[sp,2] (start-offset, count) per entry
    job_epochs: int = 0        # accumulator snapshot (solo-comparable)
    job_tasks: int = 0
    job_forks: int = 0
    job_peak: int = 0
    stats: Optional[JobStats] = None


@dataclasses.dataclass
class JobHandle:
    """Submission ticket: poll ``status``, read ``result`` when DONE.

    Lifecycle timestamps are stamped from one injectable monotonic
    ``clock`` (``time.monotonic`` by default) at the QUEUED -> RUNNING ->
    DONE/FAILED transitions, so per-tenant latency splits into the two
    numbers a serving operator actually tunes: ``queue_wait`` (admission
    backpressure — capacity vs quota pressure) and ``run_time``
    (co-scheduled execution).  The service feeds both into the
    ``trees_job_queue_wait_seconds`` / ``trees_job_run_seconds``
    histograms (DESIGN.md §13).  Every stamp goes through the same clock —
    mixing wall-clock submit stamps with monotonic transition stamps would
    let queue-wait go negative across clock adjustments; the injectable
    clock also lets the load generator run on deterministic virtual time.

    ``priority`` / ``deadline`` / ``klass`` feed the admission layer
    (DESIGN.md §16): ``deadline`` is absolute, in clock seconds (the
    service converts a relative deadline at submit).  ``checkpoint`` is
    non-None exactly while the job is PREEMPTED: the region image that a
    later wave restores instead of seeding from scratch.
    """

    job_id: int
    job: Job
    status: JobStatus = JobStatus.QUEUED
    result: Optional[JobResult] = None
    error: Optional[Exception] = None
    submitted_at: Optional[float] = None
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    clock: Callable[[], float] = dataclasses.field(
        default=time.monotonic, repr=False
    )
    priority: int = 0
    deadline: Optional[float] = None
    klass: str = "default"
    preemptions: int = 0
    checkpoint: Optional[RegionCheckpoint] = dataclasses.field(
        default=None, repr=False
    )

    def __post_init__(self) -> None:
        if self.submitted_at is None:
            self.submitted_at = self.clock()

    @property
    def done(self) -> bool:
        return self.status in (JobStatus.DONE, JobStatus.FAILED)

    def mark_running(self) -> None:
        """Stamp the QUEUED -> RUNNING transition (idempotent: a device
        wave reseeds regions across chunks, only the first admit counts)."""
        self.status = JobStatus.RUNNING
        if self.started_at is None:
            self.started_at = self.clock()

    def mark_finished(self) -> None:
        """Stamp the terminal transition (status set by the caller)."""
        if self.finished_at is None:
            self.finished_at = self.clock()

    def mark_preempted(self, checkpoint: RegionCheckpoint) -> None:
        """RUNNING -> PREEMPTED: park the region image on the handle.

        The job re-enters the queue as a restartable unit; admission
        treats it like a QUEUED job whose seed is the checkpoint.  The
        ``started_at`` stamp is kept — queue_wait measures time to *first*
        placement, and run_time keeps covering the whole span (preemption
        is the service's choice, not the tenant's)."""
        self.status = JobStatus.PREEMPTED
        self.checkpoint = checkpoint
        self.preemptions += 1

    @property
    def queue_wait(self) -> Optional[float]:
        """Seconds spent QUEUED, once running (None before that)."""
        if self.started_at is None:
            return None
        return self.started_at - self.submitted_at

    @property
    def run_time(self) -> Optional[float]:
        """Seconds spent RUNNING, once finished (None before that)."""
        if self.started_at is None or self.finished_at is None:
            return None
        return self.finished_at - self.started_at


def validate_job(job: Job, capacity: int) -> None:
    """Static admission checks for one job against the service capacity."""
    if job.quota < 2:
        raise AdmissionError(
            f"job {job.name!r}: quota must be >= 2 (root slot + 1), "
            f"got {job.quota}"
        )
    if job.quota > capacity:
        raise AdmissionError(
            f"job {job.name!r}: quota {job.quota} exceeds service "
            f"capacity {capacity}"
        )
    try:
        job.program.task_id(job.initial.task)
    except KeyError:
        raise AdmissionError(
            f"job {job.name!r}: seed task {job.initial.task!r} not in "
            f"program {job.program.name!r}"
        ) from None


@dataclasses.dataclass
class WaveTemplate:
    """One wave *shape*, compiled: the fused program, its fuse-time slot
    layout, and the :class:`~repro.core.engine.EpochLoop` that owns every
    compiled step / chunk ``while_loop`` traced against it.

    Two waves whose members are structurally equal (``structural_hash``)
    with the same quotas, capacity, stack depth, and chunk size K execute
    the *same* phase-2 trace, so the second wave can run on the first
    wave's template verbatim — only runtime state (TV, heap, stacks) is
    rebuilt.  This is ``Program.structural_hash`` region reuse promoted
    from one region to the whole wave.
    """

    key: Tuple
    program: Any   # fused Program
    slots: Any     # List[TenantSlot] (fuse-time layout)
    loop: Any      # EpochLoop (owns the compiled chunk template)


def canonical_wave_order(jobs: Sequence[Job]) -> Tuple[int, ...]:
    """Canonical member order of a wave: sort by (structural hash, quota).

    Member *order* does not affect the traced chunk loop beyond the slot
    layout it induces, so two waves that are permutations of each other
    execute the same compiled template once their members are seated in
    the same order.  The sort is stable (ties keep submission order), and
    quotas ride the permutation so the slot layout follows the members.
    The service reorders device waves with this permutation before fusing;
    results need no un-permuting — they attach to each job's own handle.
    """
    return tuple(sorted(
        range(len(jobs)),
        key=lambda i: (jobs[i].program.structural_hash(), jobs[i].quota),
    ))


def wave_template_key(jobs: Sequence[Job], capacity: int, stack_depth: int,
                      chunk, dispatch: str = "masked",
                      megakernel: bool = False) -> Tuple:
    """Cache key for one wave shape: everything that determines the traced
    chunk loop — member structure, quota layout, TV capacity, stack depth,
    the chunk size K, the dispatch policy (masked vs gather bake different
    step ladders into the loop), and the chunk driver (while_loop vs the
    Pallas megakernel).  Members are keyed in :func:`canonical_wave_order`
    (not submission order), so permuted waves of the same members share one
    template instead of retracing.

    ``chunk`` is an int, ``None`` (fully resident), or the literal string
    ``"auto"``: adaptive-K waves all key to one slot because K only ever
    feeds the compiled loop's *dynamic* epoch bound — whatever K the
    controller picks, the same template serves it, so K adaptation can
    never retrace.  ``dispatch`` must be a *resolved* mode here ("auto" is
    resolved by the service before keying, sticky per wave shape via
    :meth:`WaveTemplateCache.peek`).

    The key is deliberately *not* a function of the shard count: a sharded
    fleet (DESIGN.md §15) replicates ONE per-shard wave layout, and its
    per-shard chunk body is the very loop this template holds — the fleet
    driver caches its stacked vmap/shard_map wrappers separately, keyed on
    (n_shards, mesh), inside :class:`~repro.core.engine.EpochLoop`.  One
    template therefore serves the solo wave and every P; switching P
    mid-service never rebuilds the template — it costs at most the one
    vmap/shard_map wrapper trace for the new batch shape, after which
    waves at that P are zero-retrace again."""
    order = canonical_wave_order(jobs)
    return (
        tuple(jobs[i].program.structural_hash() for i in order),
        tuple(jobs[i].quota for i in order),
        int(capacity),
        int(stack_depth),
        chunk,
        str(dispatch),
        bool(megakernel),
    )


class WaveTemplateCache:
    """LRU cache of :class:`WaveTemplate` per wave shape.

    ``JobService(engine="device")`` consults it before fusing a wave:
    a hit means structurally identical consecutive waves reuse one
    compiled chunk loop instead of retracing (``hits``/``misses`` make the
    reuse observable; ``trace_count`` sums the owned loops' trace-counter
    hooks so tests can assert *zero* new traces on a hit).
    """

    def __init__(self, max_entries: int = 16):
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._entries: "collections.OrderedDict[Tuple, WaveTemplate]" = (
            collections.OrderedDict()
        )
        # traces owned by templates since evicted: keeps trace_count
        # monotone, so an eviction can never mask a genuine retrace
        self._evicted_traces = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: Tuple) -> Optional[WaveTemplate]:
        t = self._entries.get(key)
        if t is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return t

    def peek(self, key: Tuple) -> Optional[WaveTemplate]:
        """Non-counting probe: dispatch="auto" checks which resolved-mode
        template already exists for a wave shape (the sticky-decision
        rule) without skewing the hit/miss counters or the LRU order."""
        return self._entries.get(key)

    def store(self, template: WaveTemplate) -> None:
        self._entries[template.key] = template
        self._entries.move_to_end(template.key)
        while len(self._entries) > self.max_entries:
            _, evicted = self._entries.popitem(last=False)
            self.evictions += 1
            self._evicted_traces += evicted.loop.trace_count

    @property
    def trace_count(self) -> int:
        """Total traced builder bodies across every template ever cached
        (the compile-count regression guard reads this; evicted templates'
        traces stay counted, so the total is monotone)."""
        return self._evicted_traces + sum(
            t.loop.trace_count for t in self._entries.values()
        )


def check_fleet_dtype(programs) -> Any:
    """All co-scheduled programs must share one TV value dtype.

    The shared value array has a single dtype; admitting a tenant whose
    emits would be silently cast could not stay bit-identical to its solo
    run, so mixed-dtype fleets are rejected up front (they can still run in
    separate waves).
    """
    dtypes = {jnp.dtype(p.value_dtype) for p in programs}
    if len(dtypes) > 1:
        raise AdmissionError(
            f"fleet mixes TV value dtypes {sorted(str(d) for d in dtypes)}; "
            "co-scheduled jobs must share one value dtype"
        )
    return dtypes.pop()
