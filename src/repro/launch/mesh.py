"""Production mesh construction + sharding policy.

``make_production_mesh`` is a *function* (not a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh is
16x16 = 256 chips ("data", "model"); the multi-pod mesh is 2x16x16 = 512
chips ("pod", "data", "model"), with the "pod" axis proving that the config
shards across pod boundaries (DCN-crossing collectives).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.common import (
    ModelConfig,
    ShardingRules,
    logical_to_physical,
)


def make_fleet_mesh(shards: int):
    """1-D ``"fleet"`` device mesh for sharded TVM execution (DESIGN.md
    §15): shard ``p`` of a :class:`~repro.distributed.fleet.ShardedFleet`
    lives on device ``p`` and runs its own resident chunk loop under
    ``shard_map``.

    Returns ``None`` when fewer than ``shards`` devices are attached —
    the fleet then falls back to its single-device ``vmap`` simulation
    (bit-identical, not device-parallel), so P > device_count is a
    degraded mode, never an error.  CI forces 8 host devices
    (``--xla_force_host_platform_device_count=8``) to exercise the real
    path on CPU.
    """
    if shards < 1:
        raise ValueError(f"a fleet needs >= 1 shard, got {shards}")
    if shards == 1 or len(jax.devices()) < shards:
        return None
    try:
        return jax.make_mesh(
            (shards,), ("fleet",),
            axis_types=(jax.sharding.AxisType.Auto,),
        )
    except (AttributeError, TypeError):
        # older jax: no AxisType / no axis_types kwarg
        return jax.make_mesh((shards,), ("fleet",))


def fleet_shard_map(fn, mesh, *, in_specs, out_specs):
    """``shard_map`` across jax versions (``jax.shard_map`` when present,
    the experimental module otherwise).  The fleet's per-shard chunk
    bodies are closed computations — no cross-shard collectives — so
    replication checking is irrelevant and disabled where the API
    requires an explicit opt-out."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def rules_for_mesh(
    mesh,
    base: Optional[ShardingRules] = None,
    sequence_parallel: bool = False,
) -> ShardingRules:
    """Filter logical->mesh rules down to the axes this mesh actually has,
    optionally enabling Megatron-style sequence parallelism (residual-stream
    activations sharded over 'model' between attention/MLP blocks)."""
    base = base or ShardingRules()
    if sequence_parallel:
        base = base.replace(seq="model")
    names = set(mesh.axis_names)
    out = []
    for k, v in base.rules:
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in names)
            v = kept[0] if len(kept) == 1 else (kept or None)
        elif v is not None and v not in names:
            v = None
        out.append((k, v))
    return ShardingRules(rules=tuple(out))


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


def batch_pspec(mesh, global_batch: int) -> P:
    """Shard batch over (pod, data) when divisible, else replicate (e.g. the
    batch=1 long-context cell)."""
    if global_batch % data_size(mesh) == 0:
        ax = data_axes(mesh)
        return P(ax[0] if len(ax) == 1 else ax)
    return P(None)


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """(ShapeDtypeStruct params, logical axes) without allocating anything."""
    from ..models.model import init_model

    axes: Dict[str, tuple] = {}

    def f(key):
        p, a = init_model(cfg, key)
        axes.update(a)
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(seed))
    return shapes, axes


def param_shardings(cfg: ModelConfig, mesh, rules: ShardingRules):
    """ShapeDtypeStructs carrying NamedShardings for every parameter."""
    shapes, axes = abstract_params(cfg)
    out = {
        k: jax.ShapeDtypeStruct(
            v.shape,
            v.dtype,
            sharding=NamedSharding(mesh, logical_to_physical(axes[k], rules)),
        )
        for k, v in shapes.items()
    }
    return out, axes


def cache_specs(
    cfg: ModelConfig, mesh, B: int, max_len: int
) -> Dict[str, jax.ShapeDtypeStruct]:
    """Sharded ShapeDtypeStructs for the decode cache.

    KV heads shard over 'model'.  Batch shards over (pod, data) when it
    divides; for batch=1 long-context the *sequence* dim of the KV cache
    shards over the data axes instead (cache sequence parallelism).
    """
    from ..models.model import init_cache

    shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, B, max_len)
    )
    bspec = batch_pspec(mesh, B)
    b_ax = bspec[0] if len(bspec) else None
    seq_ax = None
    if b_ax is None and max_len % data_size(mesh) == 0:
        ax = data_axes(mesh)
        seq_ax = ax[0] if len(ax) == 1 else ax
    spec_map = {
        "lengths": P(b_ax),
        "k": P(None, b_ax, "model", seq_ax, None),
        "v": P(None, b_ax, "model", seq_ax, None),
        "ssm_conv": P(None, b_ax, None, "model"),
        # shard headdim (always divisible), not n_heads (hymba: 50 heads)
        "ssm_state": P(None, b_ax, None, "model", None),
        "enc_out": P(b_ax, None, None),
        "cross_k": P(None, b_ax, "model", None, None),
        "cross_v": P(None, b_ax, "model", None, None),
    }
    return {
        k: jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=NamedSharding(mesh, spec_map[k])
        )
        for k, v in shapes.items()
    }
