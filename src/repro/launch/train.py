"""Training entry point.

On real hardware this runs the production mesh; on CPU it drives reduced
configs end-to-end (quickstart / examples / tests).  Composes the full
substrate: step-indexed data -> train_step (remat, ZeRO-1 AdamW) ->
fault-tolerant runner (atomic checkpoints, straggler monitor, restart).

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --reduced --steps 60 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..checkpointing import CheckpointManager
from ..data import SyntheticLM
from ..models.common import finalize, sharding_ctx
from ..models.model import init_model, loss_fn
from ..optim import AdamW, cosine_schedule
from ..runtime import FailureInjector, TrainRunner
from . import mesh as meshlib


def make_train_step(cfg, opt, mesh=None, rules=None):
    def train_step(params, opt_state, batch):
        def wrapped(p, b):
            return loss_fn(p, cfg, b)

        if mesh is not None:
            with sharding_ctx(mesh, rules):
                (loss, metrics), grads = jax.value_and_grad(
                    wrapped, has_aux=True
                )(params, batch)
                new_p, new_s, om = opt.update(params, grads, opt_state)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                wrapped, has_aux=True
            )(params, batch)
            new_p, new_s, om = opt.update(params, grads, opt_state)
        return new_p, new_s, dict(loss=loss, **metrics, **om)

    return jax.jit(train_step, donate_argnums=(0, 1))


def build(
    arch: str,
    reduced: bool = True,
    batch: int = 8,
    seq: int = 128,
    steps: int = 100,
    lr: float = 3e-3,
    seed: int = 0,
    use_mesh: bool = False,
):
    cfg = (
        configs.get_reduced(arch) if reduced else configs.get_config(arch)
    )
    mesh = rules = None
    if use_mesh:
        mesh = meshlib.make_production_mesh()
        cfg = finalize(cfg, mesh.shape["model"])
        rules = meshlib.rules_for_mesh(mesh)
    params, axes = init_model(cfg, jax.random.PRNGKey(seed))
    opt = AdamW(lr=cosine_schedule(lr, warmup_steps=10, total_steps=steps))
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, opt, mesh, rules)
    data = SyntheticLM(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed
    )
    return cfg, params, opt_state, step_fn, data, mesh


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (FT demo)")
    args = ap.parse_args(argv)

    cfg, params, opt_state, step_fn, data, mesh = build(
        args.arch, args.reduced, args.batch, args.seq, args.steps, args.lr
    )
    n_params = sum(int(np.prod(v.shape)) for v in params.values())
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    runner = TrainRunner(
        step_fn, data,
        CheckpointManager(args.ckpt_dir, keep=2, async_save=True),
        mesh=mesh,
        ckpt_every=args.ckpt_every,
        failure=FailureInjector(args.fail_at),
    )
    t0 = time.time()
    params, opt_state, hist = runner.run_with_restarts(
        params, opt_state, args.steps
    )
    dt = time.time() - t0
    for h in hist:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.3f}")
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s, "
          f"{len(runner.straggler.events)} straggler events")


if __name__ == "__main__":
    main()
