import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory / cost / collective statistics.

This proves the distribution config is coherent without real hardware:
sharding mismatches, compile-time OOM, and unsupported collectives all fail
here.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]

Artifacts land in artifacts/dryrun/<cell>.json (incremental; safe to re-run
single cells).  benchmarks/roofline.py consumes them.
"""
import argparse
import functools
import json
import pathlib
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..models.common import (
    finalize,
    logical_to_physical,
    sharding_ctx,
    unroll_ctx,
)
from ..models.model import decode_step, loss_fn, prefill
from ..optim import AdamW, OptState, zero1_pspec
from . import mesh as meshlib

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def art_dir(tag: str = "") -> pathlib.Path:
    return (
        ART_DIR if not tag
        else ART_DIR.parent / f"dryrun_{tag}"
    )

# --------------------------------------------------------------- HLO parse
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _wire_bytes(op: str, size: int, g: int) -> float:
    """Per-chip bytes on the wire for a ring implementation of each op.

    ``size`` is the per-chip *result* buffer size from the HLO text (the
    compiled module is the per-device program)."""
    g = max(g, 2)
    if op == "all-reduce":
        return 2.0 * size * (g - 1) / g
    if op == "all-gather":
        return size * (g - 1) / g          # result = full gathered buffer
    if op == "reduce-scatter":
        return size * (g - 1)              # result = 1/g of the operand
    if op == "all-to-all":
        return size * (g - 1) / g
    return float(size)                     # collective-permute


def collective_stats(hlo_text: str, n_devices: int = 512) -> Dict[str, Any]:
    """Per-chip collective statistics parsed from the partitioned module.

    For every all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute we take the result-buffer size and the replica-group
    size and derive ring wire bytes (see _wire_bytes).
    """
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # result type(s): between '=' and the op name
        eq = line.find("=")
        result_part = line[eq + 1 : m.start()] if eq >= 0 else ""
        size = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result_part)
        )
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            g = len(gb.group(1).split(",")) if gb else n_devices
        s = stats.setdefault(op, {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
        s["count"] += 1
        s["result_bytes"] += size
        s["wire_bytes"] += _wire_bytes(op, size, g)
    total = sum(s["wire_bytes"] for s in stats.values())
    return {"ops": stats, "total_bytes_per_chip": total}


# ------------------------------------------------------------ input specs
def input_specs(
    cfg, mesh, shape: configs.ShapeSpec
) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, sharded, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    bspec = meshlib.batch_pspec(mesh, B)
    b_ax = bspec[0] if len(bspec) else None

    def tok(shp):
        return jax.ShapeDtypeStruct(
            shp, jnp.int32, sharding=NamedSharding(mesh, P(b_ax, None))
        )

    if shape.kind == "train":
        batch = {"tokens": tok((B, S)), "labels": tok((B, S))}
        if cfg.encdec:
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_len, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, P(b_ax, None, None)),
            )
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": tok((B, S))}
        if cfg.encdec:
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_len, cfg.d_model), jnp.float32,
                sharding=NamedSharding(mesh, P(b_ax, None, None)),
            )
        return batch
    # decode: one new token against a cache of seq_len
    return {
        "tokens": tok((B, 1)),
        "cache": meshlib.cache_specs(cfg, mesh, B, S),
    }


# ----------------------------------------------------- loop trip inventory
def loop_table(cfg, shape: configs.ShapeSpec):
    """(name, trips, parent) of every while loop the lowered step contains —
    used to correct XLA's body-counted-once cost analysis (see unroll_ctx)."""
    S = shape.seq_len
    loops = [("layer", cfg.n_layers, None)]
    has_attn = cfg.block in ("attn", "hybrid")
    if shape.kind in ("train", "prefill"):
        if has_attn and S > 1024:
            loops.append(("kv_self", -(-S // 512), "layer"))
        if cfg.block in ("ssm", "hybrid"):
            loops.append(("ssd", -(-S // 128), "layer"))
        if cfg.encdec:
            loops.append(("enc", cfg.n_encoder_layers, None))
            if cfg.encoder_len > 1024:
                kvt = -(-cfg.encoder_len // 512)
                loops.append(("kv_enc", kvt, "enc"))
                loops.append(("kv_cross", kvt, "layer"))
    if shape.kind == "train":
        loops.append(("chunk", -(-S // 512), None))
    return loops


# -------------------------------------------------------------- cell build
def build_cell(
    arch: str, shape_name: str, multi_pod: bool,
    overrides: Optional[Dict[str, str]] = None,
):
    import dataclasses as _dc

    import jax.numpy as _jnp

    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    cfg = finalize(configs.get_config(arch), mesh.shape["model"])
    ov = dict(overrides or {})
    master_weights = bool(int(ov.pop("master_weights", "0")))
    seq_par = bool(int(ov.pop("sequence_parallel", "0")))
    replicate_ffn = bool(int(ov.pop("replicate_ffn", "0")))
    if "param_dtype" in ov:
        ov["param_dtype"] = dict(bf16=_jnp.bfloat16, f32=_jnp.float32)[
            ov["param_dtype"]
        ]
    if "dispatch" in ov and cfg.moe is not None:
        cfg = _dc.replace(
            cfg, moe=_dc.replace(cfg.moe, dispatch=ov.pop("dispatch"))
        )
    if ov:
        cfg = _dc.replace(cfg, **ov)
    shape = configs.SHAPES[shape_name]
    skip = configs.skip_reason(cfg, shape)
    if skip:
        return None, None, None, skip
    rules = meshlib.rules_for_mesh(mesh, sequence_parallel=seq_par)
    if replicate_ffn:
        # small models over-TP'd: replicate the FFN/SSM weights (DP-only for
        # the body, vocab stays sharded) -> kills per-layer TP all-reduces
        rules = rules.replace(
            mlp=None, ssm_inner=None, heads=None, kv_heads=None
        )
    specs = input_specs(cfg, mesh, shape)
    pspecs, _ = meshlib.param_shardings(cfg, mesh, rules)

    if shape.kind == "train":
        opt = AdamW(lr=1e-4, master_weights=master_weights)
        dax = meshlib.data_axes(mesh)
        dsz = meshlib.data_size(mesh)
        mom = {
            k: jax.ShapeDtypeStruct(
                v.shape, jnp.float32,
                sharding=NamedSharding(
                    mesh,
                    zero1_pspec(v.sharding.spec, v.shape, dax, dsz),
                ),
            )
            for k, v in pspecs.items()
        }
        opt_specs = OptState(
            m=mom,
            v=dict(mom),
            step=jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P())
            ),
            master=dict(mom) if master_weights else {},
        )

        def make_fn(unroll):
            def train_step(params, opt_state, batch):
                with unroll_ctx(**unroll), sharding_ctx(mesh, rules):
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True
                    )(params, cfg, batch)
                    new_p, new_s, om = opt.update(params, grads, opt_state)
                return new_p, new_s, dict(loss=loss, **om)

            return jax.jit(train_step, donate_argnums=(0, 1))

        args = (pspecs, opt_specs, specs)
    elif shape.kind == "prefill":
        def make_fn(unroll):
            def prefill_step(params, batch):
                with unroll_ctx(**unroll), sharding_ctx(mesh, rules):
                    return prefill(
                        params, cfg, batch["tokens"],
                        enc_frames=batch.get("enc_frames"),
                    )

            return jax.jit(prefill_step)

        args = (pspecs, specs)
    else:
        def make_fn(unroll):
            def serve_step(params, tokens, cache):
                with unroll_ctx(**unroll), sharding_ctx(mesh, rules):
                    return decode_step(params, cfg, tokens, cache)

            return jax.jit(serve_step, donate_argnums=(2,))

        args = (pspecs, specs["tokens"], specs["cache"])
    return make_fn, args, (mesh, cfg, shape), None


def _measure(make_fn, args, unroll: Dict[str, int]):
    """Lower+compile under an unroll assignment; return raw stats."""
    lowered = make_fn(unroll).lower(*args)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    colls = collective_stats(compiled.as_text())
    return dict(
        flops=float(cost.get("flops", 0.0)),
        bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(colls["total_bytes_per_chip"]),
        coll_ops=colls["ops"],
        memory=mem,
    )


def calibrated_stats(make_fn, args, loops):
    """Trip-count-corrected per-device flops/bytes/collective-bytes.

    XLA's cost_analysis counts each while-loop body once.  For every loop we
    lower twice (unroll 1 vs 2) and difference, then scale each loop's
    per-trip cost by its effective trip count (product up the nesting tree):
      corrected = base + sum_i (eff_trips_i - 1) * per_trip_i
    """
    base = _measure(make_fn, args, {})
    D = {}
    for name, trips, parent in loops:
        if trips <= 1:
            D[name] = dict(flops=0.0, bytes=0.0, coll_bytes=0.0)
            continue
        m = _measure(make_fn, args, {name: 2})
        D[name] = {
            k: max(0.0, m[k] - base[k])
            for k in ("flops", "bytes", "coll_bytes")
        }
    parents = {name: parent for name, _, parent in loops}
    trips_of = {name: t for name, t, _ in loops}

    def eff(name):
        t = trips_of[name]
        p = parents[name]
        return t * (eff(p) if p else 1)

    corrected = {k: base[k] for k in ("flops", "bytes", "coll_bytes")}
    per_trip = {}
    for name, trips, parent in loops:
        children = [n for n, p in parents.items() if p == name]
        pt = {
            k: max(0.0, D[name][k] - sum(D[c][k] for c in children))
            for k in ("flops", "bytes", "coll_bytes")
        }
        per_trip[name] = pt
        for k in corrected:
            corrected[k] += (eff(name) - 1) * pt[k]
    return base, corrected, per_trip, {
        n: dict(trips=trips_of[n], eff=eff(n), parent=parents[n])
        for n in trips_of
    }


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, save: bool = True,
    tag: str = "", overrides: Optional[Dict[str, str]] = None,
) -> Dict[str, Any]:
    cell_id = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    rec: Dict[str, Any] = {
        "cell": cell_id, "arch": arch, "shape": shape_name,
        "multi_pod": multi_pod,
    }
    t0 = time.time()
    try:
        make_fn, args, ctx, skip = build_cell(
            arch, shape_name, multi_pod, overrides
        )
        if skip:
            rec["status"] = "skipped"
            rec["skip_reason"] = skip
        else:
            mesh, cfg, shape = ctx
            loops = loop_table(cfg, shape)
            base, corrected, per_trip, trips = calibrated_stats(
                make_fn, args, loops
            )
            mem = base["memory"]
            rec.update(
                status="ok",
                n_devices=int(np.prod(list(mesh.shape.values()))),
                mesh={k: int(v) for k, v in mesh.shape.items()},
                flops_per_device=corrected["flops"],
                bytes_per_device=corrected["bytes"],
                coll_bytes_per_device=corrected["coll_bytes"],
                uncorrected=dict(
                    flops=base["flops"], bytes=base["bytes"],
                    coll_bytes=base["coll_bytes"],
                ),
                loop_calibration=dict(per_trip=per_trip, trips=trips),
                collectives_base=base["coll_ops"],
                memory=dict(
                    argument_bytes=int(mem.argument_size_in_bytes),
                    output_bytes=int(mem.output_size_in_bytes),
                    temp_bytes=int(mem.temp_size_in_bytes),
                    alias_bytes=int(mem.alias_size_in_bytes),
                ),
                n_params_logical=int(cfg.n_params()),
                n_params_active=int(cfg.active_params()),
                kind=shape.kind,
                seq_len=shape.seq_len,
                global_batch=shape.global_batch,
            )
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["elapsed_s"] = round(time.time() - t0, 1)
    if save:
        d = art_dir(tag)
        d.mkdir(parents=True, exist_ok=True)
        (d / f"{cell_id}.json").write_text(json.dumps(rec, indent=1))
    status = rec["status"]
    extra = rec.get("skip_reason") or rec.get("error", "")
    print(f"[dryrun] {cell_id}: {status} ({rec['elapsed_s']}s) {extra}",
          flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(configs.SHAPES))
    ap.add_argument(
        "--multi-pod", default="both", choices=["0", "1", "both"]
    )
    ap.add_argument("--all", action="store_true")
    ap.add_argument(
        "--skip-existing", action="store_true",
        help="skip cells whose artifact already says status=ok",
    )
    ap.add_argument(
        "--tag", default="",
        help="write artifacts to artifacts/dryrun_<tag>/ (perf variants)",
    )
    ap.add_argument(
        "--set", action="append", default=[], metavar="KEY=VAL",
        help="config overrides, e.g. --set remat=dots --set param_dtype=bf16",
    )
    args = ap.parse_args()

    pods = {"0": [False], "1": [True], "both": [False, True]}[args.multi_pod]
    archs = (
        configs.ARCH_IDS
        if args.all or not args.arch
        else [configs.normalize(args.arch)]
    )
    shapes = list(configs.SHAPES) if args.all or not args.shape else [args.shape]

    failed = 0
    for mp in pods:
        for arch in archs:
            for shp in shapes:
                cell_id = (
                    f"{arch}__{shp}__{'pod2' if mp else 'pod1'}"
                )
                if args.skip_existing:
                    f = art_dir(args.tag) / f"{cell_id}.json"
                    if f.exists():
                        old = json.loads(f.read_text())
                        if old.get("status") in ("ok", "skipped"):
                            print(f"[dryrun] {cell_id}: cached", flush=True)
                            continue
                overrides = dict(kv.split("=", 1) for kv in args.set)
                rec = run_cell(
                    arch, shp, mp, tag=args.tag, overrides=overrides
                )
                failed += rec["status"] == "failed"
    if failed:
        raise SystemExit(f"{failed} cells failed")


if __name__ == "__main__":
    main()
