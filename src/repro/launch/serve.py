"""Serving entry point: the epoch-synchronized (TVM) continuous-batching
engine over any architecture config.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \
      --requests 16 --slots 4 --max-new 24
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from .. import configs
from ..models.model import init_model
from ..serving import EpochServer, Request


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch)
    params, _ = init_model(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.RandomState(args.seed)
    enc = None
    if cfg.encdec:
        import jax.numpy as jnp

        enc = jnp.asarray(
            rng.normal(size=(1, cfg.encoder_len, cfg.d_model)), jnp.float32
        )
    server = EpochServer(
        cfg, params, n_slots=args.slots, max_len=args.max_len,
        enc_frames=enc,
    )
    for _ in range(args.requests):
        plen = rng.randint(4, 24)
        server.submit(
            Request(
                prompt=rng.randint(3, cfg.vocab, size=plen).astype(np.int32),
                max_new_tokens=args.max_new,
            )
        )
    t0 = time.time()
    done = server.run_to_completion()
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in done)
    print(
        f"arch={cfg.name} served {len(done)} requests / {n_tok} tokens in "
        f"{server.epochs} epochs ({dt:.1f}s, {n_tok/dt:.1f} tok/s, "
        f"slots={args.slots})"
    )
    for r in done[:3]:
        print(f"  rid={r.rid} len(prompt)={len(r.prompt)} out={r.output[:8]}…")


if __name__ == "__main__":
    main()
