# Launch layer: production mesh builders, sharding policy, the multi-pod
# dry-run driver, and the train/serve entry points.
