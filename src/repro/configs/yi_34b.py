"""Yi-34B: dense llama-arch GQA decoder [arXiv:2403.04652; hf]."""
import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, max_seq_len=128,
    )
