"""Architecture registry: one module per assigned architecture, plus the
input-shape table and per-cell skip rules (DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from ..models.common import ModelConfig

ARCH_IDS = (
    "yi_34b",
    "deepseek_67b",
    "granite_3_8b",
    "command_r_35b",
    "whisper_large_v3",
    "mamba2_1_3b",
    "granite_moe_1b_a400m",
    "llama4_scout_17b_a16e",
    "hymba_1_5b",
    "chameleon_34b",
)


def normalize(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{normalize(arch)}", __package__)
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    mod = importlib.import_module(f".{normalize(arch)}", __package__)
    return mod.reduced()


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    s.name: s
    for s in (
        ShapeSpec("train_4k", "train", 4096, 256),
        ShapeSpec("prefill_32k", "prefill", 32768, 32),
        ShapeSpec("decode_32k", "decode", 32768, 128),
        ShapeSpec("long_500k", "decode", 524288, 1),
    )
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """Per-spec skip rules; None = run the cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full quadratic attention; long_500k needs sub-quadratic"
    return None


def all_cells():
    """Yield (arch_id, shape_name, skip_reason_or_None) for all 40 cells."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            yield a, s.name, skip_reason(cfg, s)
