"""Chameleon-34B: early-fusion VLM decoder with QK-norm; VQ image tokens are
ordinary vocab ids (frontend STUB) [arXiv:2405.09818; unverified]."""
import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    head_dim=128,
    qk_norm=True,
    frontend="vq",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, max_seq_len=128,
    )
