"""Hymba-1.5B: hybrid parallel attention+SSM heads per layer, sliding-window
attention with periodic global layers [arXiv:2411.13676; hf]."""
import dataclasses

from ..models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    block="hybrid",
    ssm=SSMConfig(d_state=16, headdim=64, expand=2),
    sliding_window=2048,
    global_layer_every=16,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, ssm=SSMConfig(d_state=8, headdim=8, expand=2),
        sliding_window=32, global_layer_every=2, max_seq_len=128,
    )
