"""Mamba2-1.3B: attention-free SSD decoder [arXiv:2405.21060; unverified].
The paper's scheduling technique applies at the serving layer; attention
sharding is N/A (attention-free) — noted in DESIGN.md §Arch-applicability."""
import dataclasses

from ..models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=64,            # = d_inner/headdim; attention unused (block=ssm)
    n_kv_heads=64,
    d_ff=0,
    vocab=50280,
    head_dim=64,
    block="ssm",
    ssm=SSMConfig(d_state=128, headdim=64, expand=2),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        vocab=256, ssm=SSMConfig(d_state=16, headdim=8, expand=2),
        max_seq_len=128,
    )
