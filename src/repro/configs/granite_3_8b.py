"""Granite-3.0-8B: dense GQA decoder [hf:ibm-granite/granite-3.0-2b-base]."""
import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    head_dim=128,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=250, max_seq_len=128,
    )
