"""Granite-3.0-1B-A400M: MoE 32 experts top-8, GQA
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import dataclasses

from ..models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=256, moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
        max_seq_len=128,
    )
