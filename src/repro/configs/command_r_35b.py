"""Command-R-35B: GQA, no-bias, parallel attn/FFN blocks, tied embeddings
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    head_dim=128,
    parallel_block=True,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, max_seq_len=128,
    )
