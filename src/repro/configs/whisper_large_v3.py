"""Whisper-large-v3 backbone: encoder-decoder, LayerNorm, MHA (kv=q=20).
The conv/audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, 1500, d) [arXiv:2212.04356; unverified]."""
import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    encdec=True,
    encoder_len=1500,
    norm="ln",
    frontend="audio",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=256, encoder_len=16,
        max_seq_len=128,
    )
