"""DeepSeek-67B: dense llama-arch GQA decoder [arXiv:2401.02954; hf]."""
import dataclasses

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    head_dim=128,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab=256, max_seq_len=128,
    )
