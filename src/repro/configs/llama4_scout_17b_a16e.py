"""Llama-4-Scout-17B-16E backbone: MoE 16 experts top-1 + shared expert,
early fusion (VQ/image frontend STUB: tokens are ordinary vocab ids)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
import dataclasses

from ..models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared_experts=1),
    frontend="vq",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=256,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=64, n_shared_experts=1),
        max_seq_len=128,
    )
