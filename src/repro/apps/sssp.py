"""Task-parallel single-source shortest paths (paper §6.3, Fig. 8).

Same chunked-expansion structure as BFS, with float tentative distances in
``argf`` and edge weights in the heap — the relax-with-min-write formulation
the LonestarGPU ``sssp`` worklist uses.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.program import HeapVar, InitialTask, Program, TaskType
from .registry import AppCase, register_case
from .bfs import random_graph  # noqa: F401  (re-exported for benchmarks)

INF_F = np.float32(3.0e38)
CHUNK = 8


def make_program(n_nodes: int, n_edges: int) -> Program:
    def _relax(ctx):
        v, chunk = ctx.argi(0), ctx.argi(1)
        d = ctx.argf(0)
        off = ctx.read("adj_off", v)
        deg = ctx.read("adj_off", v + 1) - off
        first = chunk == 0
        improve = d < ctx.read("dist", v)
        live = jnp.where(first, improve, True)
        ctx.write("dist", v, d, op="min", where=first & improve)
        base = chunk * CHUNK
        for i in range(CHUNK):
            e = base + i
            u = ctx.read("adj", off + e)
            nd = d + ctx.read("wgt", off + e)
            stale = ctx.read("dist", u) <= nd
            ctx.fork(
                "relax", argi=(u, 0), argf=(nd,),
                where=live & (e < deg) & ~stale,
            )
        ctx.fork(
            "relax", argi=(v, chunk + 1), argf=(d,),
            where=live & (base + CHUNK < deg),
        )

    return Program(
        name="sssp",
        tasks=(TaskType("relax", _relax),),
        n_arg_i=2,
        n_arg_f=1,
        heap=(
            HeapVar("adj_off", (n_nodes + 1,), jnp.int32),
            HeapVar("adj", (max(n_edges, 1),), jnp.int32),
            HeapVar("wgt", (max(n_edges, 1),), jnp.float32),
            HeapVar("dist", (n_nodes,), jnp.float32),
        ),
    )


def initial(src: int = 0) -> InitialTask:
    return InitialTask(task="relax", argi=(src, 0), argf=(0.0,))


def random_weights(n_edges: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    return rng.uniform(0.1, 10.0, size=max(n_edges, 1)).astype(np.float32)


def heap_init(adj_off, adj, wgt, n: int):
    dist = np.full(n, INF_F, np.float32)
    return dict(adj_off=adj_off, adj=adj, wgt=wgt, dist=dist)


def sssp_reference(adj_off, adj, wgt, src: int, n: int) -> np.ndarray:
    """Sequential Dijkstra (CPU comparison point)."""
    import heapq

    dist = np.full(n, np.float64(INF_F))
    dist[src] = 0.0
    pq = [(0.0, src)]
    while pq:
        d, v = heapq.heappop(pq)
        if d > dist[v]:
            continue
        for e in range(adj_off[v], adj_off[v + 1]):
            u, nd = adj[e], d + wgt[e]
            if nd < dist[u]:
                dist[u] = nd
                heapq.heappush(pq, (nd, u))
    return dist.astype(np.float32)


@register_case("sssp")
def case() -> AppCase:
    n = 48
    adj_off, adj = random_graph(n, avg_degree=4, seed=7)
    wgt = random_weights(len(adj), seed=2)
    return AppCase(
        name="sssp",
        program=make_program(n, len(adj)),
        initial=initial(0),
        heap_init=heap_init(adj_off, adj, wgt, n),
        capacity=1 << 14,
    )
