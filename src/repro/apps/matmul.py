"""Task-parallel blocked matrix multiply (programmability study, §6.5).

Recursive 2x2x2 decomposition: each task splits (i, j, k, size) into eight
children until ``size == block``, where a data-parallel ``map`` computes the
block product and accumulates with ``add`` scatters (commutative, so the
eight-way write sharing needs no join ordering).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.program import HeapVar, InitialTask, MapType, Program, TaskType
from .registry import AppCase, register_case


def make_program(n: int, block: int = 4) -> Program:
    assert n % block == 0 and (n // block) & (n // block - 1) == 0

    def _mm(ctx):
        i0, j0, k0, size = (
            ctx.argi(0), ctx.argi(1), ctx.argi(2), ctx.argi(3)
        )
        leaf = size == block
        ctx.map("block_mm", argi=(i0, j0, k0), where=leaf)
        h = size // 2
        for di in (0, 1):
            for dj in (0, 1):
                for dk in (0, 1):
                    ctx.fork(
                        "mm",
                        argi=(i0 + di * h, j0 + dj * h, k0 + dk * h, h),
                        where=~leaf,
                    )

    def _block_mm(mctx):
        i0, j0, k0 = mctx.argi(0), mctx.argi(1), mctx.argi(2)
        r, c = mctx.eid // block, mctx.eid % block
        acc = jnp.float32(0.0)
        for kk in range(block):
            a = mctx.read("A", (i0 + r) * n + (k0 + kk))
            b = mctx.read("B", (k0 + kk) * n + (j0 + c))
            acc = acc + a * b
        mctx.write("C", (i0 + r) * n + (j0 + c), acc, op="add")

    return Program(
        name="matmul",
        tasks=(TaskType("mm", _mm),),
        maps=(
            MapType(
                "block_mm",
                _block_mm,
                domain=lambda argi: jnp.full(argi.shape[:-1], block * block),
                max_domain=block * block,
            ),
        ),
        n_arg_i=4,
        heap=(
            HeapVar("A", (n * n,), jnp.float32),
            HeapVar("B", (n * n,), jnp.float32),
            HeapVar("C", (n * n,), jnp.float32),
        ),
    )


def initial(n: int) -> InitialTask:
    return InitialTask(task="mm", argi=(0, 0, 0, n))


def random_inputs(n: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    return (
        rng.normal(size=(n, n)).astype(np.float32),
        rng.normal(size=(n, n)).astype(np.float32),
    )


@register_case("matmul")
def case() -> AppCase:
    n, block = 8, 4
    A, B = random_inputs(n, seed=9)
    return AppCase(
        name="matmul",
        program=make_program(n, block=block),
        initial=initial(n),
        heap_init=dict(A=A.ravel(), B=B.ravel()),
        capacity=1 << 12,
    )
