"""Task-parallel radix-2 DIT FFT (paper §6.2, Fig. 6 — compute-heavy case).

fork even/odd recursion + join that combines with butterfly ``map`` payloads
(one bulk payload launch per level).  Complex data as separate re/im heap
arrays; levels are double-buffered like mergesort.  Subproblem (base, stride)
reads input element ``j`` at ``base + j*stride``; results land contiguously
at ``[lo, lo+span)`` of the level's buffer.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from ..core.program import HeapVar, InitialTask, MapType, Program, TaskType
from .registry import AppCase, register_case


def make_program(n: int) -> Program:
    assert n & (n - 1) == 0

    def _buf(depth):
        return (depth % 2) * n

    def _fft(ctx):
        base, stride, lo, span, depth = (
            ctx.argi(0), ctx.argi(1), ctx.argi(2), ctx.argi(3), ctx.argi(4)
        )
        leaf = span == 1
        ctx.write("re", _buf(depth) + lo, ctx.read("xr", base), where=leaf)
        ctx.write("im", _buf(depth) + lo, ctx.read("xi", base), where=leaf)
        half = span // 2
        ctx.fork(
            "fft", argi=(base, 2 * stride, lo, half, depth + 1), where=~leaf
        )
        ctx.fork(
            "fft",
            argi=(base + stride, 2 * stride, lo + half, half, depth + 1),
            where=~leaf,
        )
        ctx.join("combine", argi=(lo, span, depth), where=~leaf)

    def _combine(ctx):
        lo, span, depth = ctx.argi(0), ctx.argi(1), ctx.argi(2)
        ctx.map("butterfly", argi=(lo, span, depth))

    def _butterfly(mctx):
        lo, span, depth = mctx.argi(0), mctx.argi(1), mctx.argi(2)
        k = mctx.eid
        half = span // 2
        rbuf = ((depth + 1) % 2) * n
        wbuf = (depth % 2) * n
        er = mctx.read("re", rbuf + lo + k)
        ei = mctx.read("im", rbuf + lo + k)
        orr = mctx.read("re", rbuf + lo + half + k)
        oi = mctx.read("im", rbuf + lo + half + k)
        ang = -2.0 * math.pi * k.astype(jnp.float32) / span.astype(jnp.float32)
        wr, wi = jnp.cos(ang), jnp.sin(ang)
        tr = wr * orr - wi * oi
        ti = wr * oi + wi * orr
        mctx.write("re", wbuf + lo + k, er + tr)
        mctx.write("im", wbuf + lo + k, ei + ti)
        mctx.write("re", wbuf + lo + half + k, er - tr)
        mctx.write("im", wbuf + lo + half + k, ei - ti)

    return Program(
        name="fft",
        tasks=(TaskType("fft", _fft), TaskType("combine", _combine)),
        maps=(
            MapType(
                "butterfly",
                _butterfly,
                domain=lambda argi: argi[..., 1] // 2,
                max_domain=n // 2,
            ),
        ),
        n_arg_i=5,
        heap=(
            HeapVar("xr", (n,), jnp.float32),
            HeapVar("xi", (n,), jnp.float32),
            HeapVar("re", (2 * n,), jnp.float32),
            HeapVar("im", (2 * n,), jnp.float32),
        ),
    )


def initial(n: int) -> InitialTask:
    return InitialTask(task="fft", argi=(0, 1, 0, n, 0))


def random_input(n: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    return (
        rng.normal(size=n).astype(np.float32),
        rng.normal(size=n).astype(np.float32),
    )


def fft_reference(xr: np.ndarray, xi: np.ndarray) -> np.ndarray:
    return np.fft.fft(xr.astype(np.float64) + 1j * xi.astype(np.float64))


@register_case("fft")
def case() -> AppCase:
    n = 32
    xr, xi = random_input(n, seed=7)
    return AppCase(
        name="fft",
        program=make_program(n),
        initial=initial(n),
        heap_init=dict(xr=xr, xi=xi),
        capacity=1 << 12,
    )
