"""Naive task-parallel Fibonacci (paper §6.2, Fig. 5).

The paper's worst case: virtually no computation per task, so the measured
time is almost entirely runtime overhead — fib is the V1/V_inf microscope.

    fib(n): if n < 2: emit n
            else:     fork fib(n-1); fork fib(n-2); join fibsum()
    fibsum: emit child_values[0] + child_values[1]
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.program import InitialTask, Program, TaskType
from .registry import AppCase, register_case


def _fib(ctx):
    n = ctx.argi(0)
    leaf = n < 2
    ctx.emit(n, where=leaf)
    ctx.fork("fib", argi=(n - 1,), where=~leaf)
    ctx.fork("fib", argi=(n - 2,), where=~leaf)
    ctx.join("fibsum", where=~leaf)


def _fibsum(ctx):
    cv = ctx.child_values(2)  # (2, 1)
    ctx.emit(cv[0, 0] + cv[1, 0])


PROGRAM = Program(
    name="fib",
    tasks=(TaskType("fib", _fib), TaskType("fibsum", _fibsum)),
    n_arg_i=1,
    value_width=1,
    value_dtype=jnp.int32,
)


def initial(n: int) -> InitialTask:
    return InitialTask(task="fib", argi=(n,))


def fib_reference(n: int) -> int:
    a, b = 0, 1
    for _ in range(n):
        a, b = b, a + b
    return a


@register_case("fib")
def case() -> AppCase:
    return AppCase(
        name="fib", program=PROGRAM, initial=initial(12), capacity=1 << 13
    )
