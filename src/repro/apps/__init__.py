# Task-parallel applications from the paper's evaluation (§6) plus the
# programmability-study set (§6.5), each written against the TVM primitives,
# with hand-coded "native" baselines under apps/baselines/.  Every app
# registers an engine-ready default case in ``registry`` so benchmarks and
# equivalence tests drive all workloads through one entry point.
from . import (  # noqa: F401
    annealing,
    bfs,
    fft,
    fib,
    matmul,
    mergesort,
    nqueens,
    sssp,
    treewalk,
    tsp,
)
from .registry import (  # noqa: F401
    AppCase,
    all_cases,
    get_case,
    get_fleet,
    register_case,
    register_fleet,
)
