# Task-parallel applications from the paper's evaluation (§6) plus the
# programmability-study set (§6.5), each written against the TVM primitives,
# with hand-coded "native" baselines under apps/baselines/.
from . import (  # noqa: F401
    annealing,
    bfs,
    fft,
    fib,
    matmul,
    mergesort,
    nqueens,
    sssp,
    treewalk,
    tsp,
)
