"""Task-parallel simulated annealing — from the paper's programmability
study (§6.5).

Independent annealing chains over a quadratic pseudo-Boolean objective:
each chain task proposes a bit flip (hash-derived), accepts by Metropolis
with a fixed-point temperature schedule, scatter-mins its energy into the
global best, and forks its successor until the step budget runs out.
Chains are embarrassingly parallel — every epoch runs all live chains as
one bulk step (the regular-parallelism end of the TVM spectrum, like
Fig. 6's FFT).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.program import HeapVar, InitialTask, Program, TaskType
from .registry import AppCase, register_case

ESCALE = 1  # energies are already integral


def make_program(n_bits: int, n_steps: int, n_chains: int) -> Program:
    def _energy(ctx, state):
        """E(state) = sum_ij Q[i,j] b_i b_j  (Q integral, n_bits<=16)."""
        e = jnp.int32(0)
        for i in range(n_bits):
            bi = (state >> i) & 1
            for j in range(i, n_bits):
                bj = (state >> j) & 1
                e = e + ctx.read("Q", i * n_bits + j) * bi * bj
        return e

    def _seed(ctx):
        # root task forks every chain (static sites), paper-style single seed
        for cid in range(n_chains):
            ctx.fork("step", argi=((cid * 26543 + 7) % 65536, 0, cid))

    def _step(ctx):
        state, t, cid = ctx.argi(0), ctx.argi(1), ctx.argi(2)
        h = (state * 31421 + t * 6927 + cid * 97 + 13) & 0x7FFF
        flip = h % n_bits
        cand = state ^ (1 << flip)
        e_cur = _energy(ctx, state)
        e_new = _energy(ctx, cand)
        # Metropolis with linear temperature ramp-down, integer threshold:
        # accept if dE < 0, or with prob ~ temp/(temp+dE) via hash draw
        d_e = e_new - e_cur
        temp = jnp.maximum(1, (n_steps - t) * 4 // n_steps + 1)
        draw = (h >> 7) % 16
        accept = (d_e < 0) | (draw < temp)
        nxt = jnp.where(accept, cand, state)
        e_next = jnp.where(accept, e_new, e_cur)
        ctx.write("best", 0, e_next, op="min")
        ctx.fork("step", argi=(nxt, t + 1, cid), where=t + 1 < n_steps)

    return Program(
        name="annealing",
        tasks=(TaskType("seed", _seed), TaskType("step", _step)),
        n_arg_i=3,
        heap=(
            HeapVar("Q", (n_bits * n_bits,), jnp.int32),
            HeapVar("best", (1,), jnp.int32),
        ),
    )


def initial() -> InitialTask:
    return InitialTask(task="seed")


def random_qubo(n_bits: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    q = rng.randint(-5, 6, size=(n_bits, n_bits))
    return np.triu(q).astype(np.int32)


def brute_force_min(Q: np.ndarray) -> int:
    n = Q.shape[0]
    best = 2**30
    for s in range(1 << n):
        bits = [(s >> i) & 1 for i in range(n)]
        e = sum(
            Q[i, j] * bits[i] * bits[j]
            for i in range(n)
            for j in range(i, n)
        )
        best = min(best, int(e))
    return best


@register_case("annealing")
def case() -> AppCase:
    nb = 6
    return AppCase(
        name="annealing",
        program=make_program(nb, n_steps=20, n_chains=8),
        initial=initial(),
        heap_init=dict(Q=random_qubo(nb, seed=5).ravel()),
        capacity=1 << 10,
    )
