"""Pre/post-order binary-tree traversal — the paper's running example
(Fig. 2 code, Fig. 3 execution trace, Fig. 4 tree).

The tree lives in the heap as left/right child index arrays (-1 = NULL).
``visit`` appends the node id to an order buffer using an atomically
incremented cursor — expressed TPU-style as an ``add``-scatter on a counter
plus a slot reservation via the task's own emit ordering.  To keep commit
order deterministic we instead record *visit epochs*: postorder is validated
by checking every parent is visited after both children (the property the
paper's postorder guarantees), and preorder the reverse.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.program import HeapVar, InitialTask, Program, TaskType
from .registry import AppCase, register_case


def make_program(n_nodes: int, order: str = "post") -> Program:
    assert order in ("pre", "post")

    def _walk(ctx):
        node = ctx.argi(0)
        is_null = node < 0
        left = ctx.read("left", node)
        right = ctx.read("right", node)
        if order == "pre":
            # visit before children: stamp with the epoch-level clock
            ctx.write("visit_clock", 0, 1, op="add", where=~is_null)
            ctx.write(
                "visit_epoch", node, ctx.read("visit_clock", 0), where=~is_null
            )
            ctx.fork("walk", argi=(left,), where=~is_null)
            ctx.fork("walk", argi=(right,), where=~is_null)
        else:
            ctx.fork("walk", argi=(left,), where=~is_null)
            ctx.fork("walk", argi=(right,), where=~is_null)
            ctx.join("visit_after", argi=(node,), where=~is_null)

    def _visit_after(ctx):
        node = ctx.argi(0)
        ctx.write("visit_clock", 0, 1, op="add")
        ctx.write("visit_epoch", node, ctx.read("visit_clock", 0), where=True)

    tasks = [TaskType("walk", _walk)]
    if order == "post":
        tasks.append(TaskType("visit_after", _visit_after))
    return Program(
        name=f"treewalk_{order}",
        tasks=tuple(tasks),
        n_arg_i=1,
        value_width=1,
        value_dtype=jnp.int32,
        heap=(
            HeapVar("left", (n_nodes,), jnp.int32),
            HeapVar("right", (n_nodes,), jnp.int32),
            HeapVar("visit_epoch", (n_nodes,), jnp.int32),
            HeapVar("visit_clock", (1,), jnp.int32),
        ),
    )


def random_tree(n_nodes: int, seed: int = 0):
    """Random binary tree over nodes 0..n-1 rooted at 0."""
    rng = np.random.RandomState(seed)
    left = -np.ones(n_nodes, np.int32)
    right = -np.ones(n_nodes, np.int32)
    slots = [0]  # nodes with a free child pointer
    for v in range(1, n_nodes):
        while True:
            p = slots[rng.randint(len(slots))]
            side = rng.randint(2)
            if side == 0 and left[p] < 0:
                left[p] = v
                break
            if side == 1 and right[p] < 0:
                right[p] = v
                break
            if left[p] >= 0 and right[p] >= 0:
                slots.remove(p)
        slots.append(v)
    return left, right


def initial() -> InitialTask:
    return InitialTask(task="walk", argi=(0,))


@register_case("treewalk")
def case() -> AppCase:
    n = 21
    left, right = random_tree(n, seed=11)
    return AppCase(
        name="treewalk",
        program=make_program(n, "post"),
        initial=initial(),
        heap_init=dict(left=left, right=right),
        capacity=1 << 10,
    )
