"""Task-parallel traveling salesman (exact, branch-and-bound-lite) — from
the paper's programmability study (§6.5).

Each task extends a partial tour by one unvisited city (N static fork
sites); complete tours scatter-min into the best-cost cell.  Pruning
against the pre-epoch best bound trims subtrees — the data-driven
irregularity TREES is built for (subtree sizes are unknowable upfront; the
epoch engine load-balances them for free).

Distances are fixed-point (×1024) int32 so min-scatters stay exact.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.program import HeapVar, InitialTask, Program, TaskType
from .registry import AppCase, register_case

SCALE = 1024


def make_program(n: int) -> Program:
    def _extend(ctx):
        # argi: [current city, visited bitmask, cost so far (fixed point)]
        cur, visited, cost = ctx.argi(0), ctx.argi(1), ctx.argi(2)
        all_visited = visited == (1 << n) - 1
        # close the tour back to city 0
        back = ctx.read("dist", cur * n + 0)
        ctx.write("best", 0, cost + back, op="min", where=all_visited)
        bound = ctx.read("best", 0)
        for c in range(1, n):
            seen = ((visited >> c) & 1) == 1
            step = ctx.read("dist", cur * n + c)
            nc = cost + step
            ctx.fork(
                "extend",
                argi=(c, visited | (1 << c), nc),
                where=~all_visited & ~seen & (nc < bound),
            )

    return Program(
        name="tsp",
        tasks=(TaskType("extend", _extend),),
        n_arg_i=3,
        heap=(
            HeapVar("dist", (n * n,), jnp.int32),
            HeapVar("best", (1,), jnp.int32),
        ),
    )


def initial() -> InitialTask:
    return InitialTask(task="extend", argi=(0, 1, 0))


def random_instance(n: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    pts = rng.rand(n, 2)
    d = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1))
    return np.round(d * SCALE).astype(np.int32)


def greedy_bound(dist: np.ndarray) -> int:
    """Nearest-neighbour tour cost — the initial branch-and-bound bound.

    Breadth-first epoch expansion (the TVM model) completes all tours in the
    *last* epochs, so without an a-priori bound no subtree is ever pruned;
    seeding `best` with a greedy tour restores pruning (a host-side phase-1
    responsibility, exactly where the paper puts serial setup work)."""
    n = dist.shape[0]
    seen = {0}
    cur, cost = 0, 0
    while len(seen) < n:
        nxt = min(
            (c for c in range(n) if c not in seen),
            key=lambda c: dist[cur, c],
        )
        cost += int(dist[cur, nxt])
        seen.add(nxt)
        cur = nxt
    return cost + int(dist[cur, 0])


def heap_init(dist: np.ndarray):
    bound = greedy_bound(dist)
    return dict(dist=dist.ravel(), best=np.asarray([bound], np.int32))


def tsp_reference(dist: np.ndarray) -> int:
    """Exact brute force (n <= ~9)."""
    import itertools

    n = dist.shape[0]
    best = 2**30
    for perm in itertools.permutations(range(1, n)):
        cost = dist[0, perm[0]]
        for a, b in zip(perm, perm[1:]):
            cost += dist[a, b]
        cost += dist[perm[-1], 0]
        best = min(best, int(cost))
    return best


@register_case("tsp")
def case() -> AppCase:
    n = 6
    dist = random_instance(n, seed=3)
    return AppCase(
        name="tsp",
        program=make_program(n),
        initial=initial(),
        heap_init=heap_init(dist),
        capacity=1 << 14,
    )
