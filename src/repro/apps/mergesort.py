"""Task-parallel mergesort, naive and ``map``-accelerated (paper §6.4, Fig 9).

Double-buffered merge: level ``depth`` reads buffer ``(depth+1) % 2`` and
writes buffer ``depth % 2``; leaves sit at depth ``log2(n)``.  Each element's
merged position is its own offset plus its rank in the sibling half (binary
search, static log2 steps).

Two variants, matching the paper's comparison exactly:
  * ``naive``  — each merge **forks one task per element** (the per-element
    placement pays full fork overhead; this is why the paper's naive
    mergesort "performs abysmally");
  * ``map``    — each merge schedules **one data-parallel map** over its
    span; all merges of a level land in a single bulk payload launch
    (§4.2's point: map amortizes overhead over regular data parallelism).
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from ..core.program import HeapVar, InitialTask, MapType, Program, TaskType
from .registry import AppCase, register_case


def _rank_in_other(ctx, v, other_lo, half, from_left, log_max):
    """Rank of v within buf[other_lo : other_lo+half] (binary search).

    Left-half elements win ties (stable merge): left counts strict '<',
    right counts '<='.
    """
    lo = jnp.int32(0)
    hi = half  # search in [lo, hi)
    for _ in range(log_max):
        mid = (lo + hi) // 2
        x = ctx.read("src", other_lo + jnp.clip(mid, 0, half - 1))
        go_right = jnp.where(from_left, x < v, x <= v)
        go_right = go_right & (lo < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
    return lo


def make_program(n: int, use_map: bool) -> Program:
    assert n & (n - 1) == 0, "power-of-two n"
    log_n = int(math.log2(n))

    # src/dst aliases: logical double buffer packed in one heap array of 2n;
    # buffer b occupies [b*n, b*n+n).
    def _buf(depth):
        return (depth % 2) * n

    def _msort(ctx):
        lo, span, depth = ctx.argi(0), ctx.argi(1), ctx.argi(2)
        leaf = span == 1
        # leaf: copy input element into this level's write buffer
        ctx.write(
            "src", _buf_dyn(depth) + lo, ctx.read("inp", lo), where=leaf
        )
        half = span // 2
        ctx.fork("msort", argi=(lo, half, depth + 1), where=~leaf)
        ctx.fork("msort", argi=(lo + half, half, depth + 1), where=~leaf)
        ctx.join("merge", argi=(lo, span, depth), where=~leaf)

    def _buf_dyn(depth):
        return (depth % 2) * n

    def _merge(ctx):
        lo, span, depth = ctx.argi(0), ctx.argi(1), ctx.argi(2)
        if use_map:
            ctx.map("place", argi=(lo, span, depth))
        else:
            # fork one placement task per element (static sites = n)
            for i in range(n):
                ctx.fork("place1", argi=(lo, span, depth, i), where=i < span)

    def _place_common(ctx, lo, span, depth, i):
        half = span // 2
        rbuf = _buf_dyn(depth + 1)  # read children's buffer
        wbuf = _buf_dyn(depth)
        g = lo + i
        from_left = i < half
        own_off = jnp.where(from_left, i, i - half)
        other_lo = rbuf + jnp.where(from_left, lo + half, lo)
        v = ctx.read("src", rbuf + g)
        rank = _rank_in_other(ctx, v, other_lo, half, from_left, log_n)
        ctx.write("src", wbuf + lo + own_off + rank, v)

    def _place1(ctx):
        _place_common(
            ctx, ctx.argi(0), ctx.argi(1), ctx.argi(2), ctx.argi(3)
        )

    def _place_map(mctx):
        _place_common(mctx, mctx.argi(0), mctx.argi(1), mctx.argi(2), mctx.eid)

    # MapCtx lacks fork/join so _place_common only uses read/write/args: OK.
    tasks = [TaskType("msort", _msort), TaskType("merge", _merge)]
    maps = []
    if use_map:
        maps.append(
            MapType(
                "place",
                _place_map,
                domain=lambda argi: argi[..., 1],
                max_domain=n,
            )
        )
    else:
        tasks.append(TaskType("place1", _place1))

    return Program(
        name=f"mergesort_{'map' if use_map else 'naive'}",
        tasks=tuple(tasks),
        maps=tuple(maps),
        n_arg_i=4,
        heap=(
            HeapVar("inp", (n,), jnp.float32),
            HeapVar("src", (2 * n,), jnp.float32),
        ),
    )


def initial(n: int) -> InitialTask:
    return InitialTask(task="msort", argi=(0, n, 0))


def result_buffer(n: int) -> slice:
    """Final sorted data lives in buffer depth-0 (= slice [0, n))."""
    return slice(0, n)


def random_input(n: int, seed: int = 0) -> np.ndarray:
    return np.random.RandomState(seed).uniform(-1, 1, n).astype(np.float32)


@register_case("mergesort")
def case() -> AppCase:
    n = 32
    return AppCase(
        name="mergesort",
        program=make_program(n, use_map=True),
        initial=initial(n),
        heap_init=dict(inp=random_input(n, seed=5)),
        capacity=1 << 12,
    )
