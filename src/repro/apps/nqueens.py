"""N-queens counting — from the paper's programmability study (§6.5).

Classic task-per-partial-placement formulation: ``place(row, cols, d1, d2)``
forks one child per non-attacked column (N static fork sites); completed
boards bump a heap counter with a conflict-free ``add`` scatter.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.program import HeapVar, InitialTask, Program, TaskType
from .registry import AppCase, register_case

SOLUTIONS = {4: 2, 5: 10, 6: 4, 7: 40, 8: 92, 9: 352, 10: 724}


def make_program(n: int) -> Program:
    def _place(ctx):
        row, cols, d1, d2 = (
            ctx.argi(0), ctx.argi(1), ctx.argi(2), ctx.argi(3)
        )
        done = row == n
        ctx.write("count", 0, 1, op="add", where=done)
        for c in range(n):
            attacked = (
                ((cols >> c) & 1)
                | ((d1 >> (row + c)) & 1)
                | ((d2 >> (row - c + n - 1)) & 1)
            ) == 1
            ctx.fork(
                "place",
                argi=(
                    row + 1,
                    cols | (1 << c),
                    d1 | (1 << (row + c)),
                    d2 | (1 << (row - c + n - 1)),
                ),
                where=~done & ~attacked,
            )

    return Program(
        name="nqueens",
        tasks=(TaskType("place", _place),),
        n_arg_i=4,
        heap=(HeapVar("count", (1,), jnp.int32),),
    )


def initial() -> InitialTask:
    return InitialTask(task="place", argi=(0, 0, 0, 0))


@register_case("nqueens")
def case() -> AppCase:
    return AppCase(
        name="nqueens",
        program=make_program(6),
        initial=initial(),
        capacity=1 << 13,
    )
