"""Uniform app registry: one runnable case per paper workload.

Every app module registers a :func:`case` — a fully materialized
(program, initial task, heap init, TV capacity) bundle — so the dispatch A/B
harness (``benchmarks/run.py --dispatch={masked,compacted,gather}``), the engine
equivalence tests, and future sharded/async drivers can iterate *all*
workloads through one entry point instead of re-deriving each app's setup.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional

from ..core.program import InitialTask, Program


@dataclasses.dataclass(frozen=True)
class AppCase:
    """One concrete, engine-ready instantiation of a workload."""

    name: str
    program: Program
    initial: InitialTask
    heap_init: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    capacity: int = 1 << 13

    def run(self, engine_cls=None, **engine_kw):
        """Run this case; defaults to HostEngine with the given kwargs."""
        from ..core import HostEngine

        cls = engine_cls or HostEngine
        kw = dict(capacity=self.capacity)
        kw.update(engine_kw)
        return cls(self.program, **kw).run(
            self.initial, heap_init=dict(self.heap_init) or None
        )


CASES: Dict[str, Callable[[], AppCase]] = {}


def register_case(name: str):
    """Register an app module's default benchmark/test case factory."""

    def deco(fn: Callable[[], AppCase]):
        CASES[name] = fn
        return fn

    return deco


def get_case(name: str) -> AppCase:
    return CASES[name]()


def all_cases() -> Dict[str, AppCase]:
    """Materialize every registered case (imports all app modules)."""
    from . import (  # noqa: F401  (registration side effects)
        annealing, bfs, fft, fib, matmul, mergesort, nqueens, sssp,
        treewalk, tsp,
    )

    return {name: fn() for name, fn in sorted(CASES.items())}


# ---------------------------------------------------------------- fleets
# A *fleet* is a named mix of cases meant to be co-scheduled by the
# epoch-multiplexing job service (``repro.service``): the service benchmark
# (`benchmarks/run.py` service rows) and the multi-tenant equivalence tests
# iterate these so they drive identical mixes.  ``quota`` is the TV-region
# the service grants each member (solo-equivalence runs use the same value
# as the solo engine capacity, keeping layouts bit-comparable).
FLEETS: Dict[str, tuple] = {}


def register_fleet(name: str, members: tuple) -> None:
    """Register a fleet: a tuple of (case_name, quota) pairs."""
    FLEETS[name] = tuple(members)


def get_fleet(name: str):
    """Materialize a fleet as a list of (AppCase, quota) pairs."""
    all_cases()  # ensure every app module has registered
    return [(get_case(case), quota) for case, quota in FLEETS[name]]


# mixed fleets: different programs co-scheduled in one shared TVM
register_fleet("mixed3", (("fib", 512), ("treewalk", 256), ("bfs", 2048)))
# mixed4 adds a map-bearing tenant (mergesort schedules bulk map payloads)
register_fleet(
    "mixed4",
    (("fib", 512), ("treewalk", 256), ("bfs", 2048), ("mergesort", 512)),
)
# homogeneous fleet: the throughput-vs-concurrency scaling benchmark
register_fleet("fib_fleet", (("fib", 512),) * 4)
