"""Native bitonic sort — the paper's "high-performance native OpenCL sort"
baseline (§6.4, Fig. 9), as one fused jitted program of log^2(n) dense
compare-exchange stages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("ascending",))
def bitonic_sort(x: jnp.ndarray, ascending: bool = True) -> jnp.ndarray:
    n = x.shape[0]
    assert n & (n - 1) == 0, "bitonic sort requires power-of-two length"
    idx = jnp.arange(n)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            partner = idx ^ j
            a = x
            b = x[partner]
            up = (idx & k) == 0
            keep_min = (idx < partner) == up
            lo = jnp.minimum(a, b)
            hi = jnp.maximum(a, b)
            x = jnp.where(keep_min, lo, hi)
            j //= 2
        k *= 2
    return x if ascending else x[::-1]
