"""Hand-coded worklist BFS / SSSP — our port of the LonestarGPU benchmarks.

The Lonestar kernels use input/output worklists with an atomically bumped
tail pointer and relaunch until the output list is empty (paper §6.3).  The
TPU-idiomatic equivalent of a push worklist is a dense frontier mask with
edge-parallel relaxation and a segment-min scatter (no atomics); the host
checks a single "anything relaxed?" scalar per round — the exact analogue of
Lonestar's one-int transfer per kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..bfs import INF
from ..sssp import INF_F


def _edge_src(adj_off: np.ndarray) -> np.ndarray:
    deg = np.diff(adj_off)
    return np.repeat(np.arange(len(deg)), deg).astype(np.int32)


@jax.jit
def _bfs_round(dist, frontier, d, edge_src, adj):
    cand = jnp.where(frontier[edge_src], d + 1, INF)
    relaxed = jnp.full_like(dist, INF).at[adj].min(cand)
    new_dist = jnp.minimum(dist, relaxed)
    new_frontier = new_dist < dist
    return new_dist, new_frontier, new_frontier.any()


def bfs_worklist(adj_off, adj, src: int, n: int):
    """Returns (dist, rounds).  One device dispatch + one scalar per round."""
    edge_src = jnp.asarray(_edge_src(adj_off))
    adj = jnp.asarray(adj)
    dist = jnp.full((n,), INF, jnp.int32).at[src].set(0)
    frontier = jnp.zeros((n,), bool).at[src].set(True)
    d = 0
    while True:
        dist, frontier, more = _bfs_round(dist, frontier, jnp.int32(d), edge_src, adj)
        d += 1
        if not bool(more):  # the single-int host transfer, as in Lonestar
            return dist, d


@jax.jit
def _sssp_round(dist, edge_src, adj, wgt):
    cand = dist[edge_src] + wgt
    relaxed = jnp.full_like(dist, INF_F).at[adj].min(cand)
    new_dist = jnp.minimum(dist, relaxed)
    return new_dist, (new_dist < dist).any()


def sssp_worklist(adj_off, adj, wgt, src: int, n: int):
    """Bellman-Ford rounds over the dense edge list (Lonestar-style)."""
    edge_src = jnp.asarray(_edge_src(adj_off))
    adj = jnp.asarray(adj)
    wgt = jnp.asarray(wgt)
    dist = jnp.full((n,), INF_F, jnp.float32).at[src].set(0.0)
    rounds = 0
    while True:
        dist, more = _sssp_round(dist, edge_src, adj, wgt)
        rounds += 1
        if not bool(more):
            return dist, rounds
