# Hand-coded "native" implementations (the paper's LonestarGPU ports and
# native OpenCL bitonic sort, re-expressed as idiomatic dense JAX): these are
# what TREES' generality is benchmarked against (§6.3, §6.4).
from . import worklist, bitonic  # noqa: F401
