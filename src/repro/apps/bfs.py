"""Task-parallel BFS (paper §6.3, Fig. 7 — Lonestar comparison).

Graph is CSR in the heap (``adj_off``, ``adj``).  A ``visit(v, d, chunk)``
task claims vertex ``v`` at depth ``d`` by a scatter-min on ``dist`` and
expands its out-edges in chunks of ``CHUNK`` static fork sites (variable
out-degree -> static site count, the TVM requirement).  Duplicate visits are
filtered against the pre-epoch ``dist`` snapshot — the same duplicated-
worklist-entry behaviour the Lonestar push worklist has; the min-write makes
them harmless.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.program import HeapVar, InitialTask, Program, TaskType
from .registry import AppCase, register_case

INF = np.int32(2**30)
CHUNK = 8


def make_program(n_nodes: int, n_edges: int) -> Program:
    def _visit(ctx):
        v, d, chunk = ctx.argi(0), ctx.argi(1), ctx.argi(2)
        off = ctx.read("adj_off", v)
        deg = ctx.read("adj_off", v + 1) - off
        first = chunk == 0
        improve = d < ctx.read("dist", v)
        live = jnp.where(first, improve, True)
        ctx.write("dist", v, d, op="min", where=first & improve)
        base = chunk * CHUNK
        for i in range(CHUNK):
            e = base + i
            u = ctx.read("adj", off + e)
            stale = ctx.read("dist", u) <= d + 1
            ctx.fork(
                "visit", argi=(u, d + 1, 0),
                where=live & (e < deg) & ~stale,
            )
        ctx.fork(
            "visit", argi=(v, d, chunk + 1),
            where=live & (base + CHUNK < deg),
        )

    return Program(
        name="bfs",
        tasks=(TaskType("visit", _visit),),
        n_arg_i=3,
        heap=(
            HeapVar("adj_off", (n_nodes + 1,), jnp.int32),
            HeapVar("adj", (max(n_edges, 1),), jnp.int32),
            HeapVar("dist", (n_nodes,), jnp.int32),
        ),
    )


def initial(src: int = 0) -> InitialTask:
    return InitialTask(task="visit", argi=(src, 0, 0))


def random_graph(n: int, avg_degree: int = 4, seed: int = 0):
    """Random directed graph in CSR, guaranteed weakly reachable-ish."""
    rng = np.random.RandomState(seed)
    dst = [rng.randint(0, n, size=rng.poisson(avg_degree)) for _ in range(n)]
    # add a random spanning path so most nodes are reachable from 0
    perm = rng.permutation(n)
    for i in range(n - 1):
        dst[perm[i]] = np.append(dst[perm[i]], perm[i + 1])
    dst[0] = np.append(dst[0], perm[0])
    deg = np.array([len(d) for d in dst])
    adj_off = np.zeros(n + 1, np.int32)
    adj_off[1:] = np.cumsum(deg)
    adj = np.concatenate(dst).astype(np.int32) if deg.sum() else np.zeros(1, np.int32)
    return adj_off, adj


def heap_init(adj_off, adj, n: int):
    dist = np.full(n, INF, np.int32)
    return dict(adj_off=adj_off, adj=adj, dist=dist)


def bfs_reference(adj_off, adj, src: int, n: int) -> np.ndarray:
    """Sequential CPU BFS (the paper's CPU comparison point)."""
    dist = np.full(n, INF, np.int64)
    dist[src] = 0
    q = [src]
    while q:
        nxt = []
        for v in q:
            for e in range(adj_off[v], adj_off[v + 1]):
                u = adj[e]
                if dist[u] > dist[v] + 1:
                    dist[u] = dist[v] + 1
                    nxt.append(u)
        q = nxt
    return dist.astype(np.int32)


@register_case("bfs")
def case() -> AppCase:
    n = 64
    adj_off, adj = random_graph(n, avg_degree=4, seed=0)
    return AppCase(
        name="bfs",
        program=make_program(n, len(adj)),
        initial=initial(0),
        heap_init=heap_init(adj_off, adj, n),
        capacity=1 << 14,
    )
