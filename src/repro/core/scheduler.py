"""Epoch scheduling layer: phase-1 policy above the TVM execution substrate.

The paper fuses three concerns into each engine: (a) the join/NDRange stacks
that decide *which* epoch number runs next (§4.3.3), (b) how many lanes the
epoch's kernel launch covers (§5.2.2's NDRange sizing), and (c) how tasks are
laid out inside that launch (§5.4's contiguity principle).  Atos-style
designs show these are a *policy* layer that should be pluggable above the
execution substrate, so this module owns all three:

  * :class:`EpochScheduler` — the host-side join/NDRange stacks with
    same-CEN range coalescing: every range sitting at the current epoch
    number is merged into one dispatch, so the critical-path overhead
    (launch + readback, the V_inf terms) is paid once for the whole system —
    the paper's "work-together" point (a) of §3.
  * :class:`DispatchPolicy` — launch-bucket sizing.  ``masked`` reproduces
    the seed engine: the popped NDRange padded to a power-of-two bucket,
    every task type executed full-width and masked.  ``compacted`` is the
    §5.4 contiguity principle: active lanes are scattered into dense
    per-type ranges (``kernels.fork_compact.type_rank`` + ``fork_scan``) and
    each type launches as one dense slice sized to its own population.
    ``gather`` packs every scheduled lane (all types) into one dense
    frontier (``kernels.ops.lane_pack``) sized to the active population —
    the cross-region hole lanes of a fused fleet are never launched.
  * ``batched_device_stacks`` / ``batched_device_pop`` /
    ``batched_device_push`` — the same stack discipline as fixed-capacity
    ``[n_regions, depth]`` device arrays with per-region stack pointers, for
    the resident engines' ``lax.while_loop`` (GTaP-style fully resident
    dispatch; ``n_regions=1`` is the solo ``DeviceEngine``, ``n_regions=J``
    is the device-resident fleet of the service layer).
  * :class:`StatsCollector` — pluggable work/critical-path accounting
    (:class:`RunStats`), including per-type occupancy for the compacted
    dispatch, consumed by ``benchmarks/run.py`` and ``benchmarks/roofline.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Launch-bucket sizing (dispatch policy)
# --------------------------------------------------------------------------
def launch_bucket(n: int, minimum: int = 8) -> int:
    """Round a launch size up to a power-of-two bucket (jit-cache friendly)."""
    p = max(1, minimum)
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class DispatchPolicy:
    """How phase 2 lays tasks into lanes and sizes the launch.

    ``epoch_min_bucket`` sizes the full-NDRange launch (and the compaction
    pass itself); ``type_min_bucket`` sizes each dense per-type slice under
    the compacted dispatch.  Compacted slices use minimum 1 because their
    whole point is lane-exact launches.
    """

    name: str
    epoch_min_bucket: int = 8
    type_min_bucket: int = 1

    def epoch_bucket(self, count: int) -> int:
        return launch_bucket(count, self.epoch_min_bucket)

    def type_bucket(self, count: int) -> int:
        if count <= 0:
            return 0
        return launch_bucket(count, self.type_min_bucket)


def size_type_buckets(policy: "DispatchPolicy", counts, task_names):
    """Per-type launch plan from the compaction counts readback (§5.4).

    Shared by the solo ``HostEngine`` and the service multiplexer so bucket
    sizing, slice offsets, and the per-type occupancy ledger can never
    diverge between the two drivers.  Returns ``(buckets, toffs, launched,
    by_type)``: the jit-key bucket tuple, the exclusive per-type offsets
    into the compaction permutation, total lanes launched, and the
    ``{name: (active, lanes)}`` dict fed to ``StatsCollector.lanes``.
    """
    counts = np.asarray(counts)
    buckets = tuple(policy.type_bucket(int(c)) for c in counts)
    toffs = np.zeros_like(counts)
    toffs[1:] = np.cumsum(counts)[:-1]
    by_type = {
        task_names[t]: (int(counts[t]), buckets[t])
        for t in range(len(buckets))
        if buckets[t] > 0
    }
    return buckets, toffs, int(sum(buckets)), by_type


MASKED = DispatchPolicy("masked")
COMPACTED = DispatchPolicy("compacted")
# gather: pack the epoch's scheduled lanes into one dense frontier
# (kernels.ops.lane_pack) and run phase 2 over that frontier only — the
# single-launch sibling of ``compacted`` (no per-type splitting), aimed at
# the cross-region hole lanes of masked *fused* epochs.  Pays the same
# extra V_inf dispatch + count transfer as the compaction pass.
GATHER = DispatchPolicy("gather")
# auto: not a traced strategy of its own — a per-epoch *selection* among
# the three above, made by control.DispatchController from the observed
# hole fraction priced against the pack-dispatch cost (DESIGN.md §14).
# Safe because every mode is bit-identical by construction; only the
# critical-path overhead moves.  Bucket params mirror the static modes so
# the full-frontier width P is the same whichever mode the epoch lands on.
AUTO = DispatchPolicy("auto")
_POLICIES = {p.name: p for p in (MASKED, COMPACTED, GATHER, AUTO)}


def resolve_policy(dispatch) -> DispatchPolicy:
    if isinstance(dispatch, DispatchPolicy):
        return dispatch
    try:
        return _POLICIES[dispatch]
    except KeyError:
        raise ValueError(
            f"unknown dispatch policy {dispatch!r}; "
            f"expected one of {sorted(_POLICIES)}"
        ) from None


# --------------------------------------------------------------------------
# Host-side epoch scheduler (paper phase 1, §5.2.2)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EpochDispatch:
    """One popped unit of work: every range at epoch number ``cen``."""

    cen: int
    start: int
    count: int
    n_ranges: int = 1  # how many stack ranges were coalesced into this span


class EpochScheduler:
    """Owns the join/NDRange stacks the paper keeps on the CPU (§5.2.2).

    LIFO pop order gives the paper's depth-first epoch order.  With
    ``coalesce=True`` a pop also drains every other stack entry carrying the
    same epoch number and merges the ranges into one covering span — holes
    between ranges hold lanes with different epoch numbers and are filtered
    by the epoch-number (TMS) check, so the merged dispatch is always
    semantically identical, it just pays phase 1+3 once for the whole system.
    """

    def __init__(self, coalesce: bool = True):
        self.coalesce = coalesce
        self._join: List[int] = []
        self._range: List[Tuple[int, int]] = []

    def reset(self, cen: int = 1, start: int = 0, count: int = 1) -> None:
        """Seed task in slot 0, eligible in the first epoch (paper §4.3)."""
        self._join = [cen]
        self._range = [(start, count)]

    def __bool__(self) -> bool:
        return bool(self._join)

    def __len__(self) -> int:
        return len(self._join)

    def pop(self) -> EpochDispatch:
        if not self._join:
            raise RuntimeError("scheduler empty — program already drained")
        cen = self._join.pop()
        start, count = self._range.pop()
        lo, hi, n = start, start + count, 1
        if self.coalesce:
            while self._join and self._join[-1] == cen:
                self._join.pop()
                s, c = self._range.pop()
                lo, hi, n = min(lo, s), max(hi, s + c), n + 1
        return EpochDispatch(cen=cen, start=lo, count=hi - lo, n_ranges=n)

    def push_join(self, cen: int, start: int, count: int) -> None:
        """Re-arm the current range: a join continuation runs at the same CEN."""
        self._join.append(cen)
        self._range.append((start, count))

    def push_forked(self, cen: int, base: int, count: int) -> None:
        """Schedule this epoch's forked children (eligible at CEN+1)."""
        if count > 0:
            self._join.append(cen)
            self._range.append((base, count))

    # -------------------------------------------------- checkpoint support
    def export_stack(self) -> Tuple[np.ndarray, np.ndarray]:
        """Snapshot the stacks bottom-to-top as ``(cens i32[sp],
        ranges i32[sp, 2])`` — the same layout as one row of the device
        stacks (``jstack[j, :sp]`` / ``rstack[j, :sp]``), so a host
        scheduler and a device stack row round-trip through one
        engine-agnostic :class:`~repro.service.jobs.RegionCheckpoint`."""
        cens = np.asarray(self._join, np.int32)
        ranges = (
            np.asarray(self._range, np.int32).reshape(-1, 2)
            if self._range else np.zeros((0, 2), np.int32)
        )
        return cens, ranges

    def load_stack(self, cens, ranges) -> None:
        """Restore a snapshot taken by :meth:`export_stack` (or sliced off
        a device stack row): entries are bottom-to-top, replacing any
        current content."""
        self._join = [int(c) for c in np.asarray(cens).reshape(-1)]
        self._range = [
            (int(s), int(c))
            for s, c in np.asarray(ranges).reshape(-1, 2)
        ]


# --------------------------------------------------------------------------
# Multi-stack pop policy (service layer: which jobs fuse into one epoch)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MuxPopPolicy:
    """Which per-job scheduler stacks pop into one fused global epoch.

    The epoch multiplexer (``repro.service``) keeps one
    :class:`EpochScheduler` per admitted job; each global epoch it selects a
    *gang* of ready jobs, pops one dispatch from each, and fuses them into a
    single launch + readback.  ``gang`` bounds the fan-in (0 = unlimited);
    the name picks the selection order when the gang is full:

      * ``fuse_all``      — every ready job, maximal work-together fusion.
      * ``round_robin``   — rotate the starting job each global epoch, so a
        bounded gang shares the fused dispatches fairly.
      * ``deepest_first`` — prefer jobs with the deepest stacks (most
        pending frontiers), draining divergent jobs to bound their TV/stack
        residency.
    """

    name: str
    gang: int = 0  # max jobs fused per global epoch; 0 = no limit

    def select(self, ready: List[int], depths: List[int], rotor: int) -> List[int]:
        """Pick which of the ready job indices pop this global epoch."""
        if self.gang <= 0 or len(ready) <= self.gang:
            return list(ready)
        if self.name == "round_robin":
            k = rotor % len(ready)
            rotated = ready[k:] + ready[:k]
            return rotated[: self.gang]
        if self.name == "deepest_first":
            order = sorted(
                range(len(ready)), key=lambda i: -depths[i]
            )
            return [ready[i] for i in order[: self.gang]]
        return list(ready)[: self.gang]


FUSE_ALL = MuxPopPolicy("fuse_all")
_MUX_POLICIES = ("fuse_all", "round_robin", "deepest_first")


def resolve_mux_policy(policy, gang: int = 0) -> MuxPopPolicy:
    if isinstance(policy, MuxPopPolicy):
        # an explicitly requested gang bound overrides the instance's
        if gang and gang != policy.gang:
            return dataclasses.replace(policy, gang=gang)
        return policy
    if policy in _MUX_POLICIES:
        return MuxPopPolicy(policy, gang)
    raise ValueError(
        f"unknown mux pop policy {policy!r}; expected one of {_MUX_POLICIES}"
    )


# --------------------------------------------------------------------------
# Device-side stacks (the same discipline inside one lax.while_loop)
# --------------------------------------------------------------------------
def batched_device_stacks(
    n_regions: int,
    depth: int,
    cens=None,
    starts=None,
    counts=None,
):
    """``[n_regions, depth]`` join/NDRange stacks as device arrays.

    Every region's stack is seeded like :meth:`EpochScheduler.reset` — one
    entry ``(cen, start, count)`` with its stack pointer at 1.  Defaults seed
    region ``j`` with ``(1, 0, 1)``; the resident fleet drivers pass each
    region's base slot as its start.  Returns ``(jstack i32[J, depth],
    rstack i32[J, depth, 2], sp i32[J])``.
    """
    J = n_regions
    cens = jnp.ones((J,), jnp.int32) if cens is None else jnp.asarray(
        cens, jnp.int32)
    starts = jnp.zeros((J,), jnp.int32) if starts is None else jnp.asarray(
        starts, jnp.int32)
    counts = jnp.ones((J,), jnp.int32) if counts is None else jnp.asarray(
        counts, jnp.int32)
    jstack = jnp.zeros((J, depth), jnp.int32).at[:, 0].set(cens)
    rstack = (
        jnp.zeros((J, depth, 2), jnp.int32)
        .at[:, 0, 0].set(starts)
        .at[:, 0, 1].set(counts)
    )
    return jstack, rstack, jnp.ones((J,), jnp.int32)


def batched_device_pop(jstack, rstack, sp):
    """Pop the top entry of every non-empty region stack at once; traced.

    Returns ``(cen, start, count, live, sp')``, all ``[n_regions]``; regions
    with an empty stack report ``live=False`` and zeroed pop values (an
    all-zero range is inert: epoch number 0 matches no valid TV slot).
    """
    J, depth = jstack.shape
    live = sp > 0
    top = jnp.clip(sp - 1, 0, depth - 1)
    rows = jnp.arange(J)
    cen = jnp.where(live, jstack[rows, top], 0)
    start = jnp.where(live, rstack[rows, top, 0], 0)
    count = jnp.where(live, rstack[rows, top, 1], 0)
    return cen, start, count, live, sp - live.astype(jnp.int32)


def batched_device_push(jstack, rstack, sp, cen, start, count, pred, depth: int):
    """Conditionally push one (cen, range) entry per region; traced.

    ``cen``/``start``/``count``/``pred`` are ``[n_regions]``.  Returns
    ``(jstack, rstack, sp', overflow)`` where ``overflow[j]`` flags a push
    attempted on a full stack (the write is clipped; the caller must fail
    that region — its schedule is no longer trustworthy).
    """
    J = jstack.shape[0]
    rows = jnp.arange(J)
    overflow = pred & (sp >= depth)
    ssp = jnp.clip(sp, 0, depth - 1)
    jstack = jstack.at[rows, ssp].set(
        jnp.where(pred, cen, jstack[rows, ssp])
    )
    entry = jnp.stack([start, count], axis=-1)
    rstack = rstack.at[rows, ssp].set(
        jnp.where(pred[:, None], entry, rstack[rows, ssp])
    )
    return jstack, rstack, sp + pred.astype(jnp.int32), overflow


def reseed_region_stacks(jstack, rstack, sp, j: int, cen: int = 1,
                         start: int = 0, count: int = 1):
    """Reset region ``j``'s stack row to a fresh seed, leaving every other
    region untouched.

    The chunked resident driver (DESIGN.md §10) uses this between chunks to
    re-admit a queued tenant into a freed region: the row is cleared and
    reseeded exactly like :meth:`EpochScheduler.reset` / one row of
    :func:`batched_device_stacks`, and the region's stack pointer returns
    to 1 — so the re-entered ``lax.while_loop`` simply sees one more live
    region, mid-wave.  Returns ``(jstack, rstack, sp)``.
    """
    jstack = jnp.asarray(jstack).at[j].set(0).at[j, 0].set(cen)
    rstack = (
        jnp.asarray(rstack)
        .at[j].set(0)
        .at[j, 0, 0].set(start)
        .at[j, 0, 1].set(count)
    )
    sp = jnp.asarray(sp).at[j].set(1)
    return jstack, rstack, sp


def load_region_stacks(jstack, rstack, sp, j: int, cens, ranges):
    """Replace region ``j``'s stack row with a checkpointed stack image.

    The multi-entry sibling of :func:`reseed_region_stacks`, used by the
    preemption path (DESIGN.md §16): a preempted job's
    :class:`~repro.service.jobs.RegionCheckpoint` carries its whole stack
    (``sp`` entries, bottom-to-top, the layout
    :meth:`EpochScheduler.export_stack` emits), and restore writes it back
    into whichever region of whichever wave the job resumes in.  Returns
    ``(jstack, rstack, sp)``.
    """
    cens = np.asarray(cens, np.int32).reshape(-1)
    ranges = np.asarray(ranges, np.int32).reshape(-1, 2)
    n = cens.shape[0]
    depth = int(np.asarray(jstack).shape[1])
    if n > depth:
        raise ValueError(
            f"checkpointed stack depth {n} exceeds this wave's "
            f"stack_depth {depth}"
        )
    jrow = jnp.zeros((depth,), jnp.int32)
    rrow = jnp.zeros((depth, 2), jnp.int32)
    if n:
        jrow = jrow.at[:n].set(jnp.asarray(cens))
        rrow = rrow.at[:n].set(jnp.asarray(ranges))
    jstack = jnp.asarray(jstack).at[j].set(jrow)
    rstack = jnp.asarray(rstack).at[j].set(rrow)
    sp = jnp.asarray(sp).at[j].set(n)
    return jstack, rstack, sp


def device_stacks(depth: int, cen: int = 1, start: int = 0, count: int = 1):
    """Single-region stacks (legacy layout: no leading region axis), seeded
    like :meth:`EpochScheduler.reset`; the stack pointer starts at 1."""
    jstack, rstack, _ = batched_device_stacks(
        1, depth, cens=[cen], starts=[start], counts=[count]
    )
    return jstack[0], rstack[0]


def device_push(jstack, rstack, sp, cen, start, count, pred, depth: int):
    """Conditionally push one (cen, range) entry; traced, race-free.

    Single-region wrapper over :func:`batched_device_push` (overflow is the
    caller's ``sp >= depth`` check, as in the seed engine)."""
    j, r, sp_out, _ = batched_device_push(
        jstack[None],
        rstack[None],
        jnp.reshape(jnp.asarray(sp, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(cen, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(start, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(count, jnp.int32), (1,)),
        jnp.reshape(jnp.asarray(pred), (1,)),
        depth,
    )
    return j[0], r[0], sp_out[0]


# --------------------------------------------------------------------------
# Stats: work / critical-path accounting (paper §4.4.1)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class RunStats:
    """Work/critical-path accounting in the paper's terms (§4.4.1)."""

    epochs: int = 0                 # critical path length T_inf (in epochs)
    tasks_executed: int = 0         # work T_1 (in tasks)
    lanes_launched: int = 0         # includes padding/invalid lanes
    total_forks: int = 0
    map_launches: int = 0
    map_elements: int = 0           # live map element-lanes (useful work)
    map_lanes_launched: int = 0     # incl. padding to the launch domain
    peak_tv_slots: int = 0          # space (paper §4.4.2)
    dispatches: int = 0             # host->device program launches (V_inf)
    scalar_transfers: int = 0       # device->host readbacks (V_inf)
    ranges_coalesced: int = 0       # extra same-CEN ranges merged into pops
    hole_lanes_skipped: int = 0     # lanes a full-span launch would have paid
    tasks_by_type: Dict[str, int] = dataclasses.field(default_factory=dict)
    lanes_by_type: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def utilization(self) -> float:
        """Active lanes / launched lanes — the SIMT-divergence analogue."""
        return self.tasks_executed / max(1, self.lanes_launched)

    @property
    def map_lanes_wasted(self) -> int:
        """Map element-lanes launched beyond the live domains.

        Host launchers size payloads to the live-domain bucket, so waste is
        just padding; resident drivers size them to ``MapType.max_domain``,
        so this surfaces the max-domain vs live-domain divergence — the
        resident path's silent work overhead, made measurable."""
        return max(0, self.map_lanes_launched - self.map_elements)

    @property
    def map_utilization(self) -> float:
        """Live map elements / launched map lanes (1.0 when no maps ran)."""
        if self.map_lanes_launched <= 0:
            return 1.0
        return self.map_elements / self.map_lanes_launched

    @property
    def occupancy_by_type(self) -> Dict[str, float]:
        """Per-type active/launched lanes (known under compacted dispatch)."""
        return {
            t: self.tasks_by_type.get(t, 0) / max(1, lanes)
            for t, lanes in self.lanes_by_type.items()
        }

    def as_dict(self, derived: bool = True) -> Dict[str, object]:
        """Canonical ``metric name -> value`` view of this run.

        The single source of truth for stats metric names: the benchmark
        JSON artifact (``benchmarks/run.py::write_json``) and the metrics
        exporter (``obs/export.py::export_run_stats``) both spell their
        keys from here, so a renamed or added field propagates everywhere
        at once.  ``derived=True`` appends the ratio properties
        (utilization, map waste) next to the raw counters.
        """
        out: Dict[str, object] = {
            f.name: getattr(self, f.name) for f in dataclasses.fields(self)
        }
        out["tasks_by_type"] = dict(self.tasks_by_type)
        out["lanes_by_type"] = dict(self.lanes_by_type)
        if derived:
            out["utilization"] = self.utilization
            out["map_lanes_wasted"] = self.map_lanes_wasted
            out["map_utilization"] = self.map_utilization
        return out

    def merge(self, s: "RunStats") -> "RunStats":
        """Accumulate another run/wave's stats into this one, in place.

        Counters add; ``peak_tv_slots`` is a high-water mark and takes the
        max; the per-type dicts merge per key.  Returns ``self`` so
        ``total = RunStats().merge(a).merge(b)`` chains.
        """
        self.epochs += s.epochs
        self.tasks_executed += s.tasks_executed
        self.lanes_launched += s.lanes_launched
        self.total_forks += s.total_forks
        self.map_launches += s.map_launches
        self.map_elements += s.map_elements
        self.map_lanes_launched += s.map_lanes_launched
        self.peak_tv_slots = max(self.peak_tv_slots, s.peak_tv_slots)
        self.dispatches += s.dispatches
        self.scalar_transfers += s.scalar_transfers
        self.ranges_coalesced += s.ranges_coalesced
        self.hole_lanes_skipped += s.hole_lanes_skipped
        for k, v in s.tasks_by_type.items():
            self.tasks_by_type[k] = self.tasks_by_type.get(k, 0) + v
        for k, v in s.lanes_by_type.items():
            self.lanes_by_type[k] = self.lanes_by_type.get(k, 0) + v
        return self


class StatsCollector:
    """No-op base; engines call these hooks, collectors interpret them.

    ``epoch``/``map_launch`` take bulk counts (``n``) so resident drivers —
    which learn a whole wave's totals from one readback — can record them in
    O(1) host work instead of replaying the loop.
    """

    def epoch(self, cen: int, n_ranges: int = 1, n: int = 1) -> None:
        pass

    def lanes(self, n_active: int, launched: int,
              by_type: Optional[Dict[str, Tuple[int, int]]] = None) -> None:
        pass

    def dispatch(self, n: int = 1) -> None:
        pass

    def transfer(self, n: int = 1) -> None:
        pass

    def forks(self, n: int) -> None:
        pass

    def map_launch(self, elements: int = 0, lanes: int = 0,
                   n: int = 1) -> None:
        pass

    def holes_skipped(self, n: int) -> None:
        """Lanes a full-span launch would have paid that a dense dispatch
        (gather frontier, resident live-span bucket) did not launch."""
        pass

    def tv_peak(self, slots: int) -> None:
        pass

    def result(self) -> RunStats:
        return RunStats()


class NullStats(StatsCollector):
    """Counts only what the driver needs for control plus the V_inf terms
    (epochs, dispatches, transfers, map launches) — no per-lane accounting."""

    def __init__(self):
        self._stats = RunStats()

    def epoch(self, cen: int, n_ranges: int = 1, n: int = 1) -> None:
        self._stats.epochs += n

    def dispatch(self, n: int = 1) -> None:
        self._stats.dispatches += n

    def transfer(self, n: int = 1) -> None:
        self._stats.scalar_transfers += n

    def map_launch(self, elements: int = 0, lanes: int = 0,
                   n: int = 1) -> None:
        self._stats.map_launches += n

    def result(self) -> RunStats:
        return self._stats


class RunStatsCollector(NullStats):
    """Full accounting, including per-type occupancy when the dispatch
    policy knows per-type populations (compacted)."""

    def lanes(self, n_active: int, launched: int,
              by_type: Optional[Dict[str, Tuple[int, int]]] = None) -> None:
        s = self._stats
        s.tasks_executed += n_active
        s.lanes_launched += launched
        if by_type:
            for name, (active, lanes) in by_type.items():
                s.tasks_by_type[name] = s.tasks_by_type.get(name, 0) + active
                s.lanes_by_type[name] = s.lanes_by_type.get(name, 0) + lanes

    def epoch(self, cen: int, n_ranges: int = 1, n: int = 1) -> None:
        super().epoch(cen, n_ranges, n)
        self._stats.ranges_coalesced += n_ranges - n

    def forks(self, n: int) -> None:
        self._stats.total_forks += n

    def map_launch(self, elements: int = 0, lanes: int = 0,
                   n: int = 1) -> None:
        super().map_launch(elements, lanes, n)
        self._stats.map_elements += elements
        self._stats.map_lanes_launched += lanes

    def holes_skipped(self, n: int) -> None:
        self._stats.hole_lanes_skipped += n

    def tv_peak(self, slots: int) -> None:
        self._stats.peak_tv_slots = max(self._stats.peak_tv_slots, slots)
