"""Work / critical-path accounting in the paper's analytical framework.

Paper §1/§2.2/§4.4:  T_P = V1 * T1 / P + V_inf * T_inf.
The oracle gives the ideal T1 (tasks) and T_inf (epochs); engine stats give
the realized work (lanes launched, incl. padding = SIMT-divergence analogue)
and the realized critical path (dispatches + scalar transfers).  This module
derives the overhead factors so benchmarks can report V1 / V_inf directly,
and exposes the greedy-schedule bound used throughout the paper.
"""
from __future__ import annotations

import dataclasses

from .engine import RunStats
from .interp import OracleStats


@dataclasses.dataclass(frozen=True)
class OverheadReport:
    t1_tasks: int            # ideal work
    t_inf_epochs: int        # ideal critical path
    parallelism: float       # T1 / T_inf
    v1_lane_factor: float    # lanes launched / ideal tasks  (work overhead)
    v_inf_dispatches: int    # host->device launches on the critical path
    v_inf_transfers: int     # device->host readbacks on the critical path
    utilization: float       # active / launched lanes

    def greedy_bound(self, p: int) -> float:
        """Greedy offline schedule bound  T_P = O(T1/P) + O(T_inf)  [Brent]."""
        return self.t1_tasks / p + self.t_inf_epochs


def compare(oracle: OracleStats, engine: RunStats) -> OverheadReport:
    """Relate engine-realized cost to the oracle's ideal T1 / T_inf."""
    if engine.tasks_executed and engine.tasks_executed != oracle.tasks_executed:
        raise ValueError(
            "engine executed a different task count than the oracle: "
            f"{engine.tasks_executed} vs {oracle.tasks_executed}"
        )
    t1 = oracle.tasks_executed
    tinf = oracle.epochs
    return OverheadReport(
        t1_tasks=t1,
        t_inf_epochs=tinf,
        parallelism=t1 / max(1, tinf),
        v1_lane_factor=engine.lanes_launched / max(1, t1),
        v_inf_dispatches=engine.dispatches,
        v_inf_transfers=engine.scalar_transfers,
        utilization=engine.utilization,
    )
