# The paper's primary contribution: the TVM abstract machine and the TREES
# epoch-synchronized task-parallel runtime, adapted from GPU/OpenCL to
# TPU/JAX (see DESIGN.md section 2 for the adaptation table).
from .engine import DeviceEngine, EngineError, HostEngine, RunStats
from .interp import OracleStats, run_oracle
from .program import HeapVar, InitialTask, MapType, Program, TaskType
from .analysis import OverheadReport, compare

__all__ = [
    "DeviceEngine",
    "EngineError",
    "HostEngine",
    "RunStats",
    "OracleStats",
    "run_oracle",
    "HeapVar",
    "InitialTask",
    "MapType",
    "Program",
    "TaskType",
    "OverheadReport",
    "compare",
]
