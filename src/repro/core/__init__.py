# The paper's primary contribution: the TVM abstract machine and the TREES
# epoch-synchronized task-parallel runtime, adapted from GPU/OpenCL to
# TPU/JAX (see DESIGN.md section 2 for the adaptation table).  The epoch
# pipeline is layered (DESIGN.md section 1): engines (drivers) over the
# scheduler (phase-1 policy: stacks, coalescing, dispatch sizing) over the
# TVM (phase-2/3 execution substrate).
from .engine import (
    ChunkSummary,
    DeviceEngine,
    EngineError,
    EpochLoop,
    HostEngine,
    MapLauncher,
    ResidentCarry,
    RunStats,
)
from .interp import OracleStats, run_oracle
from .program import HeapVar, InitialTask, MapType, Program, TaskType
from .analysis import OverheadReport, compare
from .scheduler import (
    COMPACTED,
    FUSE_ALL,
    GATHER,
    MASKED,
    DispatchPolicy,
    EpochScheduler,
    MuxPopPolicy,
    NullStats,
    RunStatsCollector,
    StatsCollector,
    batched_device_pop,
    batched_device_push,
    batched_device_stacks,
    launch_bucket,
    reseed_region_stacks,
    resolve_mux_policy,
    resolve_policy,
)

__all__ = [
    "ChunkSummary",
    "DeviceEngine",
    "EngineError",
    "EpochLoop",
    "HostEngine",
    "ResidentCarry",
    "RunStats",
    "OracleStats",
    "run_oracle",
    "HeapVar",
    "InitialTask",
    "MapType",
    "Program",
    "TaskType",
    "OverheadReport",
    "compare",
    "COMPACTED",
    "FUSE_ALL",
    "GATHER",
    "MASKED",
    "DispatchPolicy",
    "EpochScheduler",
    "MapLauncher",
    "MuxPopPolicy",
    "NullStats",
    "RunStatsCollector",
    "StatsCollector",
    "batched_device_pop",
    "batched_device_push",
    "batched_device_stacks",
    "launch_bucket",
    "reseed_region_stacks",
    "resolve_mux_policy",
    "resolve_policy",
]
