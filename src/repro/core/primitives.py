"""The TVM primitives — fork / join / emit / map — as a traced effect API.

Task functions receive an :class:`EpochCtx` and *record* effects; the engine
commits them in bulk at the end of the epoch (paper §4.3.3 / §5.2.4).  This
record-then-commit split is what lets TREES replace the GPU's per-thread
atomics with one cooperative prefix-sum allocation per epoch on TPU.

All ``where=`` predicates default to True; they are the lane-level predication
that replaces SIMT divergence.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax.numpy as jnp

_WRITE_OPS = ("set", "add", "min", "max")


@dataclasses.dataclass
class ForkSite:
    where: Any
    task: Any
    argi: Any  # i32[A]
    argf: Any  # f32[Af]


@dataclasses.dataclass
class WriteSite:
    name: str
    index: Any
    value: Any
    op: str
    where: Any


@dataclasses.dataclass
class MapSite:
    where: Any
    map_id: int
    argi: Any
    argf: Any


class EpochCtx:
    """Per-lane view of one TVM core during epoch phase 2.

    The engine constructs it (vmapped across lanes), runs the task function,
    then reads the recorded effects back out.
    """

    def __init__(
        self,
        program,
        argi,
        argf,
        child_base,
        child_count,
        slot,
        heap: Dict[str, Any],
        values: Any,
    ):
        self._program = program
        self._argi = argi
        self._argf = argf
        self._child_base = child_base
        self._child_count = child_count
        self._slot = slot
        self._heap = heap
        self._values = values
        # recorded effects
        self.forks: List[ForkSite] = []
        self.join_site: Optional[ForkSite] = None
        self.emit_where = jnp.asarray(False)
        self.emit_value = jnp.zeros(
            (program.value_width,), dtype=program.value_dtype
        )
        self.writes: List[WriteSite] = []
        self.map_sites: List[MapSite] = []

    # ------------------------------------------------------------- reads
    def argi(self, k: int):
        """k-th integer argument of this task."""
        return self._argi[k]

    def argf(self, k: int):
        """k-th float argument of this task."""
        return self._argf[k]

    @property
    def slot(self):
        """This task's TV slot index (its abstract core id)."""
        return self._slot

    @property
    def child_count(self):
        """Number of children forked by this task's predecessor (join use)."""
        return self._child_count

    def child_values(self, n: int):
        """Values emitted by up to ``n`` children, shape (n, value_width).

        Children of one task are contiguous (prefix-sum allocation preserves
        the paper's contiguity invariant), starting at ``child_base``.
        Entries >= child_count are zero.
        """
        idx = self._child_base + jnp.arange(n)
        vals = self._values[jnp.clip(idx, 0, self._values.shape[0] - 1)]
        mask = (jnp.arange(n) < self._child_count)[:, None]
        return jnp.where(mask, vals, jnp.zeros_like(vals))

    def read(self, name: str, index):
        """Gather ``heap[name][index]`` (pre-epoch snapshot)."""
        arr = self._heap[name]
        return arr[jnp.clip(index, 0, arr.shape[0] - 1)]

    # ----------------------------------------------------------- effects
    def fork(self, task: Any, argi=(), argf=(), where=True):
        """Spawn ``task(argi, argf)``; eligible from the *next* epoch."""
        self.forks.append(
            ForkSite(
                where=jnp.asarray(where),
                task=self._task_code(task),
                argi=self._pack_i(argi),
                argf=self._pack_f(argf),
            )
        )

    def join(self, task: Any, argi=(), argf=(), where=True):
        """Replace this task with ``task`` to run after all its forks finish."""
        if self.join_site is not None:
            raise ValueError("at most one join per task body (paper §4.3.2)")
        self.join_site = ForkSite(
            where=jnp.asarray(where),
            task=self._task_code(task),
            argi=self._pack_i(argi),
            argf=self._pack_f(argf),
        )

    def emit(self, value, where=True):
        """Return a value to the parent waiting to join this task."""
        v = jnp.asarray(value, dtype=self._program.value_dtype)
        v = v.reshape(-1)
        if v.shape[0] > self._program.value_width:
            raise ValueError("emit value wider than program.value_width")
        v = jnp.pad(v, (0, self._program.value_width - v.shape[0]))
        w = jnp.asarray(where)
        self.emit_value = jnp.where(w, v, self.emit_value)
        self.emit_where = jnp.logical_or(self.emit_where, w)

    def write(self, name: str, index, value, op: str = "set", where=True):
        """Scatter ``heap[name][index] (op)= value`` at end of epoch.

        ``add``/``min``/``max`` are conflict-safe; ``set`` with conflicting
        indices has an unspecified winner (same as the paper's data races).
        """
        if op not in _WRITE_OPS:
            raise ValueError(f"op must be one of {_WRITE_OPS}")
        arr = self._heap[name]
        self.writes.append(
            WriteSite(
                name=name,
                index=jnp.asarray(index, jnp.int32),
                value=jnp.asarray(value, arr.dtype),
                op=op,
                where=jnp.asarray(where),
            )
        )

    def map(self, map_fn: Any, argi=(), argf=(), where=True):
        """Schedule a data-parallel payload to run before the next epoch."""
        mid = (
            self._program.map_id(map_fn)
            if isinstance(map_fn, str)
            else int(map_fn)
        )
        self.map_sites.append(
            MapSite(
                where=jnp.asarray(where),
                map_id=mid,
                argi=self._pack_i(argi),
                argf=self._pack_f(argf),
            )
        )

    # ----------------------------------------------------------- helpers
    def _task_code(self, task):
        if isinstance(task, str):
            return jnp.asarray(self._program.task_id(task), jnp.int32)
        return jnp.asarray(task, jnp.int32)

    def _pack_i(self, argi):
        a = jnp.zeros((self._program.n_arg_i,), jnp.int32)
        for k, v in enumerate(argi):
            a = a.at[k].set(jnp.asarray(v, jnp.int32))
        return a

    def _pack_f(self, argf):
        a = jnp.zeros((self._program.n_arg_f,), jnp.float32)
        for k, v in enumerate(argf):
            a = a.at[k].set(jnp.asarray(v, jnp.float32))
        return a


class MapCtx:
    """Per-element view of a data-parallel ``map`` payload.

    The payload runs over a dense index domain ``[0, domain)``; ``eid`` is the
    element index.  Reads snapshot the pre-map heap; writes commit in bulk.
    """

    def __init__(self, program, argi, argf, eid, heap):
        self._program = program
        self._argi = argi
        self._argf = argf
        self._eid = eid
        self._heap = heap
        self.writes: List[WriteSite] = []

    def argi(self, k: int):
        return self._argi[k]

    def argf(self, k: int):
        return self._argf[k]

    @property
    def eid(self):
        return self._eid

    def read(self, name: str, index):
        arr = self._heap[name]
        return arr[jnp.clip(index, 0, arr.shape[0] - 1)]

    def write(self, name: str, index, value, op: str = "set", where=True):
        if op not in _WRITE_OPS:
            raise ValueError(f"op must be one of {_WRITE_OPS}")
        arr = self._heap[name]
        self.writes.append(
            WriteSite(
                name=name,
                index=jnp.asarray(index, jnp.int32),
                value=jnp.asarray(value, arr.dtype),
                op=op,
                where=jnp.asarray(where),
            )
        )
