"""TREES epoch engines: one ``EpochLoop`` driver core, many configurations.

Every engine in this codebase is the same machine driven three ways.  The
scheduling layer in ``scheduler.py`` owns phase-1 policy (join/NDRange
stacks, same-CEN coalescing, launch-bucket sizing) and the V1/V_inf
accounting; the TVM in ``tvm.py`` owns phase 2/3 execution.  This module
owns the *driver*: :class:`EpochLoop` is the shared core — step builders
(masked full-width, or the §5.4 compaction pass + dense per-type step), a
readback policy (which end-of-epoch scalars the host fetches), and a
termination predicate — and each engine is one configuration of it:

  * :class:`HostEngine` — the paper-faithful CPU/GPU split: the Python host
    performs phases 1 and 3 (stack bookkeeping, flag readback — the paper's
    ``joinScheduled``/``mapScheduled``/``nextFreeCore`` transfers) and
    dispatches one jitted XLA program per epoch.  Readback policy: the
    :class:`~repro.core.tvm.EpochSummary` scalars, once per epoch.
    Termination: the host scheduler drains.  Supports the ``masked``
    (seed), ``compacted`` (§5.4 contiguity), and ``gather`` (§11
    dense-frontier pack) dispatch policies.

  * :class:`DeviceEngine` — the beyond-paper resident variant ("future
    chips with tighter CPU/GPU coupling"): the entire epoch loop runs
    on-device inside one ``lax.while_loop``, with the stacks as
    fixed-capacity device arrays (``scheduler.batched_device_stacks`` with
    ``n_regions=1``).  Readback policy: nothing per epoch — every scalar a
    host loop would fetch accumulates in the :class:`ResidentCarry` and is
    read once at the end (dispatches = transfers = 1).  Termination: the
    traced all-stacks-empty ``while_loop`` cond.  ``masked`` dispatch
    buckets each epoch's step to the live span of the popped ranges via a
    small ``lax.switch`` ladder of compiled widths (DESIGN.md §11);
    ``gather`` packs the active lanes into a dense in-loop frontier and
    buckets to the pack *count* instead (§12; ``compacted`` stays
    host-only — its per-type launch shapes come from runtime populations).
    Optionally the whole chunk runs as one persistent Pallas megakernel
    (``megakernel=True``, ``kernels/epoch_megakernel.py``).

  * the service-layer drivers (``repro.service.multiplexer``) — the host
    ``EpochMultiplexer`` and the resident ``DeviceMultiplexer`` reuse the
    same two configurations with a :class:`~repro.core.tvm.JobArena` and a
    per-lane epoch-number vector, fusing many tenant regions into each
    epoch.

The resident loop is *chunked* (DESIGN.md §10): :meth:`EpochLoop.run_chunk`
runs the resident body until every stack drains **or** a traced epoch bound
``limit`` is reached, and the bound is a dynamic argument of one compiled
loop — so host-mux cadence (K=1), chunked residency (K epochs per
re-entry), and the fully-resident wave (limit = the epoch guard) are the
same compiled template re-entered with different bounds.  Between chunks
the host fetches one compact :class:`ChunkSummary` (per-region stack
pointers, failure flags, solo-comparable accumulators, arena cursors) —
total V_inf for a wave of E epochs is ⌈E/K⌉ dispatches + readbacks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import tvm
from ..obs.trace import NULL_TRACER
from .program import InitialTask, Program
from .scheduler import (  # noqa: F401  (re-exports kept for back-compat)
    COMPACTED,
    MASKED,
    DispatchPolicy,
    EpochScheduler,
    NullStats,
    RunStats,
    RunStatsCollector,
    StatsCollector,
    batched_device_pop,
    batched_device_push,
    batched_device_stacks,
    device_push,
    device_stacks,
    launch_bucket,
    resolve_policy,
    size_type_buckets,
)


class EngineError(RuntimeError):
    pass


# every leaf of a fleet-stacked ResidentCarry shards its leading axis over
# the 1-D "fleet" mesh (launch/mesh.py make_fleet_mesh)
_FLEET_SPEC = jax.sharding.PartitionSpec("fleet")


_COMPACTED_RESIDENT_MSG = (
    "resident (device) execution supports the 'masked' and 'gather' "
    "dispatches: the on-device loop needs launch shapes fixed at trace "
    "time — gather packs into a fixed-shape in-loop frontier, but "
    "'compacted' sizes per-type launches from runtime populations (use a "
    "host-loop driver for compacted dispatch)"
)


def resolve_resident_dispatch(dispatch, controller, capacity: int,
                              peek: Optional[Callable[[str], Any]] = None):
    """Resolve ``dispatch="auto"`` for a resident (traced) loop.

    A resident template bakes its mode in at trace time, so the decision
    is made once per template, masked-vs-gather only (§5.4 compacted
    stays host-side).  With no controller (or a cold observation window)
    the answer is masked — the cheapest critical path when nothing is
    known.

    ``peek`` is the stickiness hook (optional): called with each
    candidate mode name, it returns a truthy value when a compiled
    template for this wave shape already exists under that mode.  A hit
    wins before the controller is ever consulted — identical consecutive
    waves can never retrace on a flipped decision — while a *new* wave
    shape appearing mid-service falls through to the controller, whose
    rolling window has been accumulating fill observations across every
    prior wave's chunks.  New shapes are therefore re-evaluated against
    everything the service has learned so far, not against the cold-start
    default (DESIGN.md §14-§15; the service passes a wave-template cache
    peek here, the sharded fleet the same per-shard-layout peek).
    """
    if resolve_policy(dispatch).name != "auto":
        return dispatch
    if peek is not None:
        for cand in ("masked", "gather"):
            if peek(cand):
                return cand
    if controller is None:
        return "masked"
    return controller.choose_resident(capacity).mode


def _default_rank_fn(types, active, n_types):
    from ..kernels import ops as kops

    return kops.type_rank(types, active, n_types)


def _default_pack_fn(active):
    from ..kernels import ops as kops

    return kops.lane_pack(active)


def _frontier_mask(state, start, count, cen, P: int):
    """Per-lane active predicate of a popped NDRange frontier.

    A lane is active when it is inside the popped range, carries a nonzero
    epoch number (0 tags lanes outside every popped range on fused
    frontiers), and TMS-matches (``epoch[slot] == cen``).  This predicate
    *defines* which lanes every dispatch mode executes — masked, the
    compaction pass, and the gather pack all share it, so the three modes
    can never diverge on what counts as scheduled work.  Returns
    ``(idx, active, cen_l)``.
    """
    idx = start + jnp.arange(P, dtype=jnp.int32)
    in_range = jnp.arange(P, dtype=jnp.int32) < count
    cidx = jnp.clip(idx, 0, state.capacity - 1)
    cen_l = jnp.asarray(cen, jnp.int32)
    active = in_range & (cen_l > 0) & (state.epoch[cidx] == cen_l)
    return idx, active, cen_l


class MapLauncher:
    """Host-side launcher for scheduled ``map`` payloads (paper §5.2.4).

    Sizes each payload launch to the *live* element domain of its scheduled
    lanes, skips payloads whose lanes all have empty domains, and caches the
    jitted step per (map, lane-count, domain-bucket).  Shared by every
    host-loop driver (``HostEngine`` and the service epoch multiplexer);
    resident drivers launch payloads in-loop at ``MapType.max_domain``
    instead (see :meth:`EpochLoop.resident_body`).
    """

    def __init__(self, program: Program, donate: bool = False,
                 on_trace: Optional[Callable[[], None]] = None,
                 tracer=None):
        self.program = program
        self._donate = donate
        self._on_trace = on_trace or (lambda: None)
        self.tracer = tracer or NULL_TRACER
        self._cache: Dict[Tuple[int, int, int], Any] = {}

    def _get_step(self, mid: int, P: int, D: int):
        key = (mid, P, D)
        if key not in self._cache:
            def mfn(heap, where, argi, argf):
                self._on_trace()
                return tvm.run_map_payload(
                    self.program, heap, mid, where, argi, argf, D
                )

            self._cache[key] = jax.jit(
                mfn, donate_argnums=(0,) if self._donate else ()
            )
        return self._cache[key]

    def run(self, map_launches, heap, col: StatsCollector):
        """Launch each scheduled map payload, sized to its live domain."""
        for ml in map_launches:
            where = np.asarray(jax.device_get(ml.where))
            if not where.any():
                continue
            argi = np.asarray(jax.device_get(ml.argi))
            dom = np.asarray(self.program.maps[ml.map_id].domain(argi))
            dmax = int(dom[where].max()) if dom[where].size else 0
            if dmax <= 0:
                # every scheduled lane has an empty element domain: a launch
                # would dispatch a wasted payload (launch_bucket(0) lanes)
                continue
            D = launch_bucket(dmax, minimum=8)
            P = int(where.shape[0])
            mstep = self._get_step(ml.map_id, P, D)
            with self.tracer.span(
                "map", "host", map_id=ml.map_id, lanes=P, width=D,
            ), self.tracer.annotation(f"trees:map{ml.map_id}"):
                heap = mstep(heap, ml.where, ml.argi, ml.argf)
            col.dispatch()
            # what to record is the collector's decision (NullStats ignores
            # the element count), not an engine-level flag's
            col.map_launch(int(dom[where].sum()), P * D)
        return heap


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ResidentCarry:
    """``lax.while_loop`` carry of the resident drivers.

    The TVM + heap + (optional) :class:`~repro.core.tvm.JobArena`, the
    ``[n_regions, depth]`` scheduler stacks with per-region stack pointers,
    and on-device accumulators for every scalar a host loop would have read
    back per epoch — the resident "readback policy" is to fetch them once,
    after the loop.
    """

    state: Any         # TVMState
    heap: Any          # Dict[str, jnp.ndarray]
    arena: Any         # JobArena (fleet) or None (solo)
    jstack: Any        # i32[J, depth]
    rstack: Any        # i32[J, depth, 2]
    sp: Any            # i32[J]   per-region stack pointers
    failed: Any        # bool[J]  region failed (TV or stack overflow)
    failed_stack: Any  # bool[J]  the failure was scheduler stack depth
    n_epochs: Any      # i32[]    global epochs (loop iterations)
    job_epochs: Any    # i32[J]   per-region epochs (== solo epochs)
    job_tasks: Any     # i32[J,2] per-region tasks executed (T1; hi/lo)
    job_forks: Any     # i32[J,2] per-region total forks (hi/lo)
    job_peak: Any      # i32[J]   per-region peak TV cursor (region-relative)
    map_launches: Any  # i32[]    map payload launches
    map_elements: Any  # i32[2]   live map element-lanes (hi/lo, base 2^20)
    map_lanes: Any     # i32[2]   launched element-lanes (hi/lo, base 2^20)
    hole_lanes: Any    # i32[2]   full-TV lanes the span buckets skipped


_HILO_BASE = 1 << 20  # split radix: i32 hi/lo pairs count exactly to ~2^51


def _hilo_add(acc, n):
    """Add ``n`` (i32, < 2^31 - 2^20) into exact i32 (hi, lo) pairs.

    x64 is typically disabled under JAX, so there is no int64 on device;
    long resident waves would wrap a plain i32 accumulator (capacity — or
    capacity x max_domain — per epoch, times up to 2^20 epochs).  Each pair
    holds hi * 2^20 + lo exactly.  ``acc`` is ``[..., 2]`` with ``n``
    broadcast over the leading axes, so the per-region task/fork
    accumulators ([J, 2]) get the same treatment as the scalar lane
    counters ([2])."""
    lo = acc[..., 1] + n
    return jnp.stack([acc[..., 0] + lo // _HILO_BASE, lo % _HILO_BASE],
                     axis=-1)


def _hilo_value(acc):
    """Decode hi/lo pairs to exact int64 (numpy scalar for a [2] pair,
    int64 array for [J, 2] per-region pairs)."""
    a = np.asarray(acc).astype(np.int64)
    return a[..., 0] * _HILO_BASE + a[..., 1]


@dataclasses.dataclass(frozen=True)
class ChunkSummary:
    """Host-side snapshot fetched once per chunk boundary (DESIGN.md §10).

    The chunked driver's readback policy: per-region stack pointers
    (``sp[j] == 0`` means region ``j`` drained — a completion to surface),
    failure flags, the solo-comparable per-region accumulators, map-launch
    volumes, and the :class:`~repro.core.tvm.JobArena` region cursors.
    Everything the host needs to stream completions, reseed freed regions,
    and account stats between chunks — without touching the bulk TV/heap
    state, which stays on device in the :class:`ResidentCarry`.
    """

    n_epochs: int             # global epochs run so far (all chunks)
    sp: np.ndarray            # i32[J] remaining stack entries per region
    failed: np.ndarray        # bool[J] region failed (TV or stack overflow)
    failed_stack: np.ndarray  # bool[J] the failure was scheduler stack depth
    job_epochs: np.ndarray    # i32[J] per-region epochs (== solo epochs)
    job_tasks: np.ndarray     # i64[J] per-region tasks executed (T1)
    job_forks: np.ndarray     # i64[J] per-region total forks
    job_peak: np.ndarray      # i32[J] per-region peak TV cursor (relative)
    map_launches: int
    map_elements: int
    map_lanes: int
    hole_lanes: int           # full-TV lanes the live-span buckets skipped
    arena_next: Optional[np.ndarray]  # i32[J] region cursors (fleet only)


def _map_width_ladder(max_domain: int, minimum: int = 8) -> Tuple[int, ...]:
    """Power-of-2 payload widths, capped at ``max_domain``.

    The resident map launcher picks one of these at runtime from the traced
    max of the scheduled lanes' live domains (a segmented max over the
    ``where`` mask), so short-domain epochs stop paying ``max_domain``-wide
    launches.  The cap keeps the worst case exactly the old fixed-width
    behaviour, never worse.  ``minimum`` is clamped when it reaches
    ``max_domain``: without the clamp any ``max_domain <= minimum``
    degenerates to a single full-width rung (the minimum-width rung is
    dead) and every launch pads to the full domain even when the live
    domains are tiny.
    """
    if max_domain <= minimum:
        minimum = max(1, max_domain // 2)
    widths: List[int] = []
    w = minimum
    while w < max_domain:
        widths.append(w)
        w *= 2
    widths.append(max_domain)
    return tuple(widths)


def _span_width_ladder(capacity: int, levels: int = 4,
                       minimum: int = 8) -> Tuple[int, ...]:
    """Live-span launch widths for the resident epoch step.

    A halving ladder from the full TV down ``levels`` rungs: the resident
    body picks the smallest width covering the union span of this epoch's
    popped ranges (a traced min/max over the per-region stack tops) and
    ``lax.switch``es into that width's compiled step — the §10 map-payload
    bucketing one level up, applied to the task launch itself.  Each width
    traces one branch of the full phase-2/3 body, so the ladder is kept
    short (``levels``) rather than lane-exact; the top rung is always the
    full TV, so the worst case is exactly the old full-width behaviour.

    ``minimum`` is clamped when it reaches ``capacity``: without the clamp
    a TV at or below the minimum width gets a single full-capacity rung
    (the minimum-width rungs are dead), so a single-region tiny fleet pads
    every epoch to the full minimum-sized launch no matter how narrow its
    live span is.
    """
    if capacity <= minimum:
        minimum = max(1, capacity // 2)
    widths = [int(capacity)]
    w = capacity // 2
    while len(widths) < levels and w >= max(1, minimum):
        widths.append(int(w))
        w //= 2
    return tuple(sorted(widths))


def _fresh_resident_carry(
    state, heap, arena, jstack, rstack, sp, n_regions: int
) -> ResidentCarry:
    z = jnp.zeros((n_regions,), jnp.int32)
    zs = jnp.asarray(0, jnp.int32)
    z2 = jnp.zeros((2,), jnp.int32)
    zj2 = jnp.zeros((n_regions, 2), jnp.int32)
    return ResidentCarry(
        state=state, heap=heap, arena=arena,
        jstack=jstack, rstack=rstack, sp=sp,
        failed=jnp.zeros((n_regions,), bool),
        failed_stack=jnp.zeros((n_regions,), bool),
        n_epochs=zs, job_epochs=z, job_tasks=zj2, job_forks=zj2, job_peak=z,
        map_launches=zs, map_elements=z2, map_lanes=z2, hole_lanes=z2,
    )


class EpochLoop:
    """The shared epoch-driver core (step builder x readback policy x
    termination predicate).  See the module docstring for the three
    configurations; no engine owns jit caches or phase-2/3 plumbing of its
    own — they all borrow this class's.
    """

    _MAX_STEP_CACHE = 256  # distinct (P, buckets) jit specializations kept

    def __init__(
        self,
        program: Program,
        dispatch: Any = MASKED,
        *,
        rank_fn: Optional[Callable] = None,
        pack_fn: Optional[Callable] = None,
        fork_offsets_fn: Optional[Callable] = None,
        seg_offsets_fn: Optional[Callable] = None,
        donate: bool = False,
        skip_idle_types: bool = False,
        megakernel: bool = False,
        megakernel_impl: str = "auto",
        tracer=None,
        controller=None,
    ):
        self.program = program
        self.policy: DispatchPolicy = resolve_policy(dispatch)
        self.task_names = [t.name for t in program.tasks]
        # dispatch="auto": a DispatchController picks the mode per fused
        # epoch (DESIGN.md §14).  Safe because all three modes are
        # bit-identical; the hook below only moves critical-path overhead.
        if self.policy.name == "auto" and controller is None:
            from ..control.controller import DispatchController

            controller = DispatchController(n_types=len(program.tasks))
        self.controller = controller
        self.last_decision = None
        self.last_span_bucket = 0
        self._rank_fn = rank_fn or _default_rank_fn
        self._pack_fn = pack_fn or _default_pack_fn
        self._fork_offsets_fn = fork_offsets_fn
        self._seg_offsets_fn = seg_offsets_fn
        self._donate = donate
        self._skip_idle_types = skip_idle_types
        # resident chunks run through the persistent Pallas megakernel
        # (kernels/epoch_megakernel.py) instead of a lax.while_loop; same
        # traced body, same bits, one fused kernel per chunk (DESIGN.md §12)
        self.megakernel = bool(megakernel)
        self.megakernel_impl = megakernel_impl
        # trace-counter hook: every traced builder body bumps this at trace
        # time (tracing executes the Python body; cached executions do not),
        # so "two identical consecutive waves retraced nothing" is a
        # testable invariant of the wave-template cache, not a hope
        self.trace_count = 0
        # span tracing is opt-in: NULL_TRACER's hooks are constant-time
        # no-ops, so the disabled path stays off the critical budget
        self.tracer = tracer or NULL_TRACER
        self.maps = MapLauncher(program, donate=donate,
                                on_trace=self._mark_trace,
                                tracer=self.tracer)
        self._step_cache: Dict[Any, Any] = {}
        self._compact_cache: Dict[int, Any] = {}
        self._gather_cache: Dict[int, Any] = {}
        self._resident_cache: Dict[Any, Any] = {}

    def _mark_trace(self) -> None:
        self.trace_count += 1

    # ---------------------------------------------------- traced step bodies
    def _masked_step_fn(self, P: int):
        """Phase 2+3 masked step; pure traced fn, usable both under jit
        (host loop) and inside a resident ``lax.while_loop``.

        ``cen`` may be a scalar (solo NDRange frontier) or a per-lane i32
        vector (fused multi-region frontier; 0 = lane in no popped range —
        the ``cen > 0`` guard keeps 0-tagged lanes from matching invalid
        TV slots).  ``arena`` is ``None`` (solo: one global ``nextFreeCore``)
        or a :class:`~repro.core.tvm.JobArena` (per-region cursors).
        """
        program = self.program
        skip = self._skip_idle_types

        def step(state, heap, arena, start, count, cen):
            self._mark_trace()
            idx, active, cen_l = _frontier_mask(state, start, count, cen, P)
            per_type, _ = tvm.trace_tasks(
                program, state, heap, idx, active, skip_idle_types=skip
            )
            return tvm.commit_epoch(
                program, state, heap, idx, active, per_type, cen_l,
                fork_offsets_fn=self._fork_offsets_fn,
                seg_offsets_fn=self._seg_offsets_fn,
                arena=arena,
            )

        return step

    def _evict(self):
        # Bucket combinations on k-type programs can be numerous; bound the
        # cache (FIFO eviction — evicted shapes just recompile) so a
        # long-running driver cannot grow it without limit.
        while len(self._step_cache) >= self._MAX_STEP_CACHE:
            self._step_cache.pop(next(iter(self._step_cache)))

    def masked_step(self, P: int):
        key = ("m", P)
        if key not in self._step_cache:
            self._evict()
            self._step_cache[key] = jax.jit(
                self._masked_step_fn(P),
                donate_argnums=(0, 1) if self._donate else (),
            )
        return self._step_cache[key]

    def compact_pass(self, P: int):
        """Compaction pass: types -> (perm, per-type counts), one dispatch
        (§5.4's extra V_inf dispatch + transfer, paid to make phase 2
        lane-exact)."""
        if P not in self._compact_cache:
            program, rank_fn = self.program, self._rank_fn
            offsets_fn = self._fork_offsets_fn

            def cfn(state, start, count, cen):
                self._mark_trace()
                idx, active, _ = _frontier_mask(state, start, count, cen, P)
                return tvm.compact_types(
                    program, state, idx, active,
                    rank_fn=rank_fn, offsets_fn=offsets_fn,
                )

            self._compact_cache[P] = jax.jit(cfn)
        return self._compact_cache[P]

    def compacted_step(self, P: int, buckets: Tuple[int, ...]):
        key = ("c", P, buckets)
        if key not in self._step_cache:
            self._evict()
            program = self.program

            def step(state, heap, arena, start, count, cen, perm, toffs,
                     tcounts):
                self._mark_trace()
                per_type, idx, active = tvm.trace_tasks_compacted(
                    program, state, heap, start, count, cen,
                    perm, toffs, tcounts, buckets,
                )
                return tvm.commit_epoch(
                    program, state, heap, idx, active, per_type, cen,
                    fork_offsets_fn=self._fork_offsets_fn,
                    seg_offsets_fn=self._seg_offsets_fn,
                    arena=arena,
                )

            self._step_cache[key] = jax.jit(
                step, donate_argnums=(0, 1) if self._donate else ()
            )
        return self._step_cache[key]

    def gather_pass(self, P: int):
        """Frontier pack pass: active mask -> (perm, count), one dispatch.

        The gather dispatch's sibling of :meth:`compact_pass` — one extra
        V_inf dispatch + one count transfer, paid to make the task step
        launch only the epoch's dense active frontier instead of the whole
        (hole-ridden) fused span.
        """
        if P not in self._gather_cache:
            pack_fn = self._pack_fn

            def gfn(state, start, count, cen):
                self._mark_trace()
                _, active, _ = _frontier_mask(state, start, count, cen, P)
                return pack_fn(active)

            self._gather_cache[P] = jax.jit(gfn)
        return self._gather_cache[P]

    def gather_step(self, P: int, G: int):
        """Phase 2+3 over the packed dense frontier (gather dispatch).

        The frontier holds *every* active lane of the epoch in increasing
        lane order (the pack is stable), so the fork prefix sum inside
        :func:`~repro.core.tvm.commit_epoch` sees exactly the masked
        dispatch's allocation order restricted to the lanes that matter —
        results are bit-identical, hole lanes between active regions are
        simply never launched.  Each gathered lane's epoch number is read
        from the TV itself (``active`` implies ``epoch[slot] == cen``), so
        the dense step needs no per-lane CEN transfer.
        """
        key = ("g", P, G)
        if key not in self._step_cache:
            self._evict()
            program = self.program
            skip = self._skip_idle_types

            def step(state, heap, arena, start, perm):
                self._mark_trace()
                lanepos = perm[:G]
                valid = lanepos >= 0
                idx = jnp.where(valid, start + lanepos, state.capacity)
                cidx = jnp.clip(idx, 0, state.capacity - 1)
                cen_g = jnp.where(valid, state.epoch[cidx], 0)
                per_type, _ = tvm.trace_tasks(
                    program, state, heap, idx, valid, skip_idle_types=skip
                )
                return tvm.commit_epoch(
                    program, state, heap, idx, valid, per_type, cen_g,
                    fork_offsets_fn=self._fork_offsets_fn,
                    seg_offsets_fn=self._seg_offsets_fn,
                    arena=arena,
                )

            self._step_cache[key] = jax.jit(
                step, donate_argnums=(0, 1) if self._donate else ()
            )
        return self._step_cache[key]

    def _resident_gather_step_fn(self, W: int):
        """Phase 2+3 over the resident *in-loop* packed frontier.

        The resident sibling of :meth:`gather_step`: ``perm`` is the
        stable full-TV pack permutation computed inside the loop body
        (fixed shape, so it traces), and ``W`` is the ladder rung covering
        the pack count — ``perm[:W]`` holds every active lane of the epoch
        in increasing lane order.  Epoch numbers are read from the TV
        itself (``active`` implies ``epoch[slot] == cen``), the commit's
        segmented fork scan sees masked allocation order restricted to the
        active lanes, and the union span's hole lanes are never stepped —
        the §11 gather frontier without leaving the resident loop.
        """
        program = self.program
        skip = self._skip_idle_types

        def step(state, heap, arena, perm):
            self._mark_trace()
            lanepos = perm[:W]
            valid = lanepos >= 0
            idx = jnp.where(valid, lanepos, state.capacity)
            cidx = jnp.clip(idx, 0, state.capacity - 1)
            cen_g = jnp.where(valid, state.epoch[cidx], 0)
            per_type, _ = tvm.trace_tasks(
                program, state, heap, idx, valid, skip_idle_types=skip
            )
            return tvm.commit_epoch(
                program, state, heap, idx, valid, per_type, cen_g,
                fork_offsets_fn=self._fork_offsets_fn,
                seg_offsets_fn=self._seg_offsets_fn,
                arena=arena,
            )

        return step

    # ------------------------------------------------- one host-driven epoch
    def run_epoch(self, state, heap, arena, start, span, cen, col, readback):
        """One fused host-driven epoch: optional compaction or gather-pack
        pass (+ count readback), the phase-2/3 step, then the end-of-epoch
        readback.

        ``cen`` is an int (solo frontier) or an i32 vector of length
        ``span`` (fused multi-region frontier; padded to the launch bucket
        with inert zeros).  ``readback`` is the readback policy:
        ``(summary, state) -> pytree`` of device scalars; its single
        ``device_get`` is the epoch's scalar transfer — the paper's
        ``nextFreeCore``/``joinScheduled``/``mapScheduled`` fetch.

        Returns ``(state, heap, summary, fetched, map_launches, launched,
        by_type, n_dispatches)`` where ``summary`` stays on device (drivers
        that thread device state — the multiplexer's arena — use it) and
        ``fetched`` is the host-side readback.
        """
        P = self.policy.epoch_bucket(span)
        start_j = jnp.asarray(start, jnp.int32)
        count_j = jnp.asarray(span, jnp.int32)
        if np.ndim(cen) == 0:
            cen_j = jnp.asarray(cen, jnp.int32)
        else:
            cen_np = np.zeros(P, np.int32)
            cen_np[: np.shape(cen)[0]] = np.asarray(cen)
            cen_j = jnp.asarray(cen_np)
        dispatches = 1
        by_type = None
        tr = self.tracer
        # decision hook: under dispatch="auto" the controller prices this
        # epoch's modes at the rolling observed fill and picks one; static
        # policies pass through.  The decision (and its evidence) rides the
        # dispatch span args so adaptivity is auditable in perfetto.
        mode = self.policy.name
        decision = None
        if mode == "auto":
            decision = self.controller.choose(P)
            mode = decision.mode
        self.last_decision = decision
        self.last_span_bucket = P
        dargs = {}
        if decision is not None:
            dargs["auto_reason"] = decision.reason
            if decision.hole_fraction is not None:
                dargs["auto_hole_fraction"] = round(decision.hole_fraction, 4)
            if decision.costs:
                dargs["auto_cost_us"] = {
                    m: round(c * 1e6, 2) for m, c in decision.costs.items()
                }
        if mode == "compacted":
            # the pack span includes its count readback (the §5.4 extra
            # V_inf dispatch + transfer), so its duration is that term's
            # real critical-path cost
            with tr.span("pack", "host", mode="compacted", width=P):
                perm, counts_dev = self.compact_pass(P)(
                    state, start_j, count_j, cen_j
                )
                counts = np.asarray(jax.device_get(counts_dev), np.int64)
            col.dispatch()
            col.transfer()
            dispatches += 1
            buckets, toffs, launched, by_type = size_type_buckets(
                self.policy, counts, self.task_names
            )
            with tr.span(
                "dispatch", "host", mode="compacted", launched=launched,
                **dargs,
            ), tr.annotation("trees:epoch_step"):
                state, heap, summary, map_launches = self.compacted_step(
                    P, buckets
                )(
                    state, heap, arena, start_j, count_j, cen_j, perm,
                    jnp.asarray(toffs, jnp.int32),
                    jnp.asarray(counts, jnp.int32),
                )
        elif mode == "gather":
            with tr.span("pack", "host", mode="gather", width=P):
                perm, count_dev = self.gather_pass(P)(
                    state, start_j, count_j, cen_j
                )
                n_sched = int(jax.device_get(count_dev))
            col.dispatch()
            col.transfer()
            dispatches += 1
            G = self.policy.epoch_bucket(n_sched)
            with tr.span(
                "dispatch", "host", mode="gather", launched=G, holes=P - G,
                **dargs,
            ), tr.annotation("trees:epoch_step"):
                state, heap, summary, map_launches = self.gather_step(P, G)(
                    state, heap, arena, start_j, perm
                )
            launched = G
            col.holes_skipped(P - G)
        else:
            with tr.span(
                "dispatch", "host", mode="masked", launched=P, **dargs,
            ), tr.annotation("trees:epoch_step"):
                state, heap, summary, map_launches = self.masked_step(P)(
                    state, heap, arena, start_j, count_j, cen_j
                )
            launched = P
        # dispatch spans measure enqueue time (XLA launches are async); the
        # readback span absorbs the wait — exactly the paper's per-epoch
        # scalar-transfer stall
        with tr.span("readback", "host"):
            fetched = jax.device_get(readback(summary, state))
        col.dispatch()
        col.transfer()
        return (
            state, heap, summary, fetched, map_launches, launched, by_type,
            dispatches,
        )

    # --------------------------------------------------- resident while_loop
    def resident_body(self, capacity: int, stack_depth: int):
        """Body of the resident epoch loop.

        The device "readback policy" is *nothing per epoch*: every scalar a
        host loop fetches accrues in the :class:`ResidentCarry` instead.
        Handles both configurations:

          * solo (``carry.arena is None``): one region; its popped NDRange
            ``[start, start+count)`` is processed masked, exactly the seed
            ``DeviceEngine`` body.
          * fleet (``JobArena``): every live region's pop is fused into one
            per-lane epoch-number vector over the whole TV and committed
            with the segmented per-region allocator; the arena's region
            cursors ride the carry, so the whole wave runs without the host.

        Either way the task step itself launches at the smallest ladder
        width (`_span_width_ladder`) covering the union span of this
        epoch's popped ranges — full-TV (or full-capacity) launches only
        happen when the live span actually demands them; the skipped lanes
        accrue in the carry's ``hole_lanes`` pair (DESIGN.md §11).

        Under ``dispatch="gather"`` the same ladder sizes a *dense*
        frontier instead: the epoch's active lanes are packed in-loop by
        the stable ``lane_pack`` permutation (a fixed-shape traced pass —
        the resident analogue of :meth:`gather_pass`), and the step
        launches at the smallest rung covering the pack *count* rather
        than the union span, so cross-region holes inside the span are
        never stepped either (DESIGN.md §12).

        Region failure (TV-region or stack overflow) zeroes that region's
        stack pointer: the job stops, its neighbours keep running — the same
        isolation the host multiplexer provides.
        """
        if self.policy.name not in ("masked", "gather"):
            raise ValueError(_COMPACTED_RESIDENT_MSG)
        gather = self.policy.name == "gather"
        program = self.program
        pack_fn = self._pack_fn
        span_widths = _span_width_ladder(capacity)
        if gather:
            step_fns = {
                W: self._resident_gather_step_fn(W) for W in span_widths
            }
        else:
            step_fns = {W: self._masked_step_fn(W) for W in span_widths}

        def make_branch(W: int, fleet: bool):
            """One span-bucket branch: the masked step at width ``W`` over
            the window ``[st, st+W)`` covering the live span, with the
            map-launch tensors padded back to full-TV width so every
            ``lax.switch`` branch returns one pytree shape."""
            step_fn = step_fns[W]

            def branch(state, heap, arena_, scen, lo, ct):
                if fleet:
                    # clamp so the window stays inside the TV; W covers the
                    # span, so the clamped window still contains every
                    # popped range (st <= lo and st + W >= span end)
                    st = jnp.clip(lo, 0, capacity - W)
                    cen_w = jax.lax.dynamic_slice(scen, (st,), (W,))
                    s2, h2, summ, mls = step_fn(
                        state, heap, arena_, st,
                        jnp.asarray(W, jnp.int32), cen_w,
                    )
                else:
                    st = lo
                    s2, h2, summ, mls = step_fn(
                        state, heap, arena_, st, ct, scen
                    )
                full = []
                for ml in mls:
                    zw = jnp.zeros((capacity,), bool)
                    zi = jnp.zeros(
                        (capacity,) + ml.argi.shape[1:], ml.argi.dtype
                    )
                    zf = jnp.zeros(
                        (capacity,) + ml.argf.shape[1:], ml.argf.dtype
                    )
                    full.append(tvm.MapLaunch(
                        map_id=ml.map_id,
                        where=jax.lax.dynamic_update_slice(
                            zw, ml.where, (st,)
                        ),
                        argi=jax.lax.dynamic_update_slice(
                            zi, ml.argi, (st,) + (0,) * (ml.argi.ndim - 1)
                        ),
                        argf=jax.lax.dynamic_update_slice(
                            zf, ml.argf, (st,) + (0,) * (ml.argf.ndim - 1)
                        ),
                    ))
                return s2, h2, summ, full

            return branch

        def make_gather_branch(W: int):
            """One pack-count bucket branch: the dense gather step at rung
            ``W``, with the map-launch tensors scattered back to full-TV
            width through the pack permutation so every ``lax.switch``
            branch returns one pytree shape (the gather twin of
            ``make_branch``'s window padding)."""
            step_fn = step_fns[W]

            def branch(state, heap, arena_, perm):
                s2, h2, summ, mls = step_fn(state, heap, arena_, perm)
                lanepos = perm[:W]
                # invalid pack slots scatter to the drop index (capacity)
                scat = jnp.where(lanepos >= 0, lanepos, capacity)
                full = []
                for ml in mls:
                    zw = jnp.zeros((capacity,), bool)
                    zi = jnp.zeros(
                        (capacity,) + ml.argi.shape[1:], ml.argi.dtype
                    )
                    zf = jnp.zeros(
                        (capacity,) + ml.argf.shape[1:], ml.argf.dtype
                    )
                    full.append(tvm.MapLaunch(
                        map_id=ml.map_id,
                        where=zw.at[scat].set(ml.where, mode="drop"),
                        argi=zi.at[scat].set(ml.argi, mode="drop"),
                        argf=zf.at[scat].set(ml.argf, mode="drop"),
                    ))
                return s2, h2, summ, full

            return branch

        def body(carry: ResidentCarry):
            self._mark_trace()
            cen, start, count, live, sp = batched_device_pop(
                carry.jstack, carry.rstack, carry.sp
            )
            arena = carry.arena
            if arena is None:
                lo, ct = start[0], count[0]
                span_w = jnp.where(live[0], count[0], 0)
                if gather:
                    # gather packs over the full TV, so the solo popped
                    # range becomes a per-lane CEN vector like the fleet's
                    lanes = jnp.arange(capacity, dtype=jnp.int32)
                    in_pop = live[0] & (lanes >= lo) & (lanes < lo + ct)
                    step_cen = jnp.where(in_pop, cen[0], 0)
                else:
                    step_cen = jnp.where(live[0], cen[0], 0)
            else:
                # fuse every live region's pop into a per-lane CEN vector
                # over the full TV (work-together across regions); the task
                # launch itself is then bucketed to the union span of the
                # popped ranges — a wave with one hot region stops paying
                # full-TV launches every epoch
                J = arena.n_jobs
                lanes = jnp.arange(capacity, dtype=jnp.int32)
                jl = jnp.clip(arena.slot_job, 0, J - 1)
                owned = arena.slot_job < J
                in_pop = (
                    owned & live[jl]
                    & (lanes >= start[jl])
                    & (lanes < start[jl] + count[jl])
                )
                step_cen = jnp.where(in_pop, cen[jl], 0)
                big = jnp.asarray(capacity, jnp.int32)
                span_lo = jnp.min(jnp.where(live, start, big))
                span_hi = jnp.max(jnp.where(live, start + count, 0))
                lo = jnp.clip(span_lo, 0, capacity)
                ct = jnp.asarray(capacity, jnp.int32)
                span_w = jnp.clip(span_hi - lo, 0, capacity)

            swarr = jnp.asarray(span_widths, jnp.int32)
            if gather:
                # the shared frontier predicate over the full TV: scheduled
                # lanes are exactly those whose TV epoch TMS-matches the
                # per-lane CEN of this epoch's popped ranges
                act = (step_cen > 0) & (carry.state.epoch == step_cen)
                perm, n_sched = pack_fn(act)
                width_key = n_sched
                branches = [make_gather_branch(W) for W in span_widths]
                operands = (carry.state, carry.heap, arena, perm)
            else:
                width_key = span_w
                branches = [
                    make_branch(W, arena is not None) for W in span_widths
                ]
                operands = (
                    carry.state, carry.heap, arena, step_cen, lo, ct
                )
            sidx = jnp.clip(
                jnp.searchsorted(swarr, width_key, side="left"),
                0, len(span_widths) - 1,
            )
            if len(branches) == 1:
                state, heap, summary, map_launches = branches[0](*operands)
            else:
                state, heap, summary, map_launches = jax.lax.switch(
                    sidx, branches, *operands
                )
            hole_lanes = _hilo_add(
                carry.hole_lanes,
                jnp.asarray(capacity, jnp.int32) - swarr[sidx],
            )
            if arena is None:
                job_join = summary.join_scheduled[None]
                job_forks = summary.total_forks[None]
                job_next = state.next_free[None]
                job_over = summary.overflow[None]
                job_active = summary.n_active[None]
                job_peak = jnp.maximum(carry.job_peak, job_next)
            else:
                job_join = summary.job_join
                job_forks = summary.job_forks
                job_next = summary.job_next
                job_over = summary.job_overflow
                job_active = summary.job_active
                job_peak = jnp.maximum(
                    carry.job_peak, summary.job_next - arena.base
                )
                # the region cursors ride the carry — the device-side
                # equivalent of the host multiplexer's arena.next update
                arena = dataclasses.replace(arena, next=summary.job_next)
            failed = carry.failed | (live & job_over)
            ok = live & ~failed
            # LIFO push order exactly as the host scheduler (§4.3.3): join
            # continuation below, this epoch's forked range on top
            jstack, rstack, sp, of1 = batched_device_push(
                carry.jstack, carry.rstack, sp,
                cen, start, count, ok & job_join, stack_depth,
            )
            jstack, rstack, sp, of2 = batched_device_push(
                jstack, rstack, sp,
                cen + 1, job_next - job_forks, job_forks,
                ok & (job_forks > 0), stack_depth,
            )
            failed_stack = carry.failed_stack | of1 | of2
            failed = failed | of1 | of2
            sp = jnp.where(failed, 0, sp)

            # map payloads sized to a power-of-2 width bucket picked by a
            # traced max over the scheduled lanes' live domains: each bucket
            # width traces its own lax.switch branch (shapes stay static),
            # runtime pays only the selected one — instead of always
            # MapType.max_domain.  The *lane* axis is bucketed the same way
            # (DESIGN.md §14): the stable gather pack's permutation gathers
            # the scheduled lanes into `rung(count)` payload rows, so a
            # 4096-lane TV with 3 scheduled map lanes launches an 8-row
            # payload, not 4096 rows.  Heap writes land through the same
            # per-element indices in the same stable lane order, so packing
            # the rows is bit-identical.  Residual padding waste (lane rung
            # x domain rung) stays accounted in ``map_lanes``.
            map_ct = carry.map_launches
            map_el = carry.map_elements
            map_ln = carry.map_lanes
            lane_widths = _span_width_ladder(capacity)
            larr = jnp.asarray(lane_widths, jnp.int32)
            for ml in map_launches:
                mt = program.maps[ml.map_id]
                if mt.max_domain <= 0:
                    raise EngineError(
                        f"map '{mt.name}' needs max_domain>0 for resident "
                        "(device) execution"
                    )
                dom = jnp.clip(
                    jnp.asarray(mt.domain(ml.argi), jnp.int32),
                    0, mt.max_domain,
                )
                live_dom = jnp.where(ml.where, dom, 0)
                dmax = live_dom.max().astype(jnp.int32)
                # all-empty domains skip the launch (and its counters),
                # exactly as the host MapLauncher does
                fired = dmax > 0
                widths = _map_width_ladder(mt.max_domain)
                warr = jnp.asarray(widths, jnp.int32)
                bidx = jnp.clip(
                    jnp.searchsorted(warr, dmax, side="left"),
                    0, len(widths) - 1,
                )
                lperm, lcount = pack_fn(ml.where)
                lidx = jnp.clip(
                    jnp.searchsorted(larr, lcount, side="left"),
                    0, len(lane_widths) - 1,
                )

                def make_lane_branch(L: int, _ml=ml):
                    def lane_branch(h):
                        rows = lperm[:L]
                        valid = rows >= 0
                        crows = jnp.clip(rows, 0, capacity - 1)
                        w_p = valid & _ml.where[crows]
                        argi_p = _ml.argi[crows]
                        argf_p = _ml.argf[crows]
                        inner = [
                            lambda hh, _D=D: tvm.run_map_payload(
                                program, hh, _ml.map_id, w_p, argi_p,
                                argf_p, _D,
                            )
                            for D in widths
                        ]
                        if len(inner) == 1:
                            return inner[0](h)
                        return jax.lax.switch(bidx, inner, h)

                    return lane_branch

                branches = [lambda h: h] + [
                    make_lane_branch(L) for L in lane_widths
                ]
                heap = jax.lax.switch(
                    jnp.where(fired, lidx + 1, 0), branches, heap
                )
                fire_i = fired.astype(jnp.int32)
                map_ct = map_ct + fire_i
                map_el = _hilo_add(map_el, live_dom.sum().astype(jnp.int32))
                map_ln = _hilo_add(
                    map_ln, fire_i * larr[lidx] * warr[bidx]
                )

            return ResidentCarry(
                state=state, heap=heap, arena=arena,
                jstack=jstack, rstack=rstack, sp=sp, failed=failed,
                failed_stack=failed_stack,
                n_epochs=carry.n_epochs + 1,
                job_epochs=carry.job_epochs + live.astype(jnp.int32),
                job_tasks=_hilo_add(carry.job_tasks, job_active),
                job_forks=_hilo_add(carry.job_forks, job_forks),
                job_peak=job_peak,
                map_launches=map_ct, map_elements=map_el, map_lanes=map_ln,
                hole_lanes=hole_lanes,
            )

        return body

    def run_chunk(self, carry: ResidentCarry, limit,
                  n_regions: int) -> ResidentCarry:
        """Run the resident loop until every stack drains or the traced
        global-epoch counter reaches ``limit`` — one *chunk* (DESIGN.md
        §10).

        ``limit`` is a **dynamic** argument of one compiled loop, cached per
        (n_regions, capacity, stack_depth) — so host-mux cadence
        (``limit = n_epochs + 1``), chunked residency (``+ K``), and the
        fully-resident wave (``limit`` = the epoch guard) all re-enter the
        same compiled template; nothing retraces between chunks or between
        K choices.  A call whose carry is already drained (or already at
        ``limit``) is a clean no-op: the cond fails on entry and the carry
        comes back unchanged.

        With ``megakernel=True`` the chunk runs through the persistent
        Pallas megakernel (``kernels/epoch_megakernel.py``) instead of a
        ``lax.while_loop``: same traced body and cond, one fused kernel
        holding the carry resident for the whole chunk — bit-identical by
        construction (the while_loop path *is* the kernel's jnp oracle).
        """
        capacity = carry.state.capacity
        depth = carry.jstack.shape[1]
        key = (n_regions, capacity, depth)
        if key not in self._resident_cache:
            body = self.resident_body(capacity, depth)

            def cond(cc: ResidentCarry, lim):
                return (cc.sp > 0).any() & (cc.n_epochs < lim)

            if self.megakernel:
                from ..kernels import epoch_megakernel as mk

                impl = self.megakernel_impl

                @jax.jit
                def loop(c, lim):
                    return mk.epoch_chunk(cond, body, c, lim, impl=impl)

            else:

                @jax.jit
                def loop(c, lim):
                    return jax.lax.while_loop(
                        lambda cc: cond(cc, lim), body, c
                    )

            self._resident_cache[key] = loop
        return self._resident_cache[key](carry, jnp.asarray(limit, jnp.int32))

    def run_resident(self, carry: ResidentCarry, max_epochs: int,
                     n_regions: int) -> ResidentCarry:
        """Run the resident loop to completion: one chunk bounded only by
        the epoch guard — one dispatch for the whole program (or wave)."""
        return self.run_chunk(carry, max_epochs, n_regions)

    def run_chunk_fleet(self, carry: ResidentCarry, limits,
                        n_regions: int, n_shards: int,
                        mesh=None) -> ResidentCarry:
        """Run P independent shard chunks as ONE fused launch (DESIGN.md
        §15).

        ``carry`` is a :class:`ResidentCarry` whose every leaf carries a
        leading fleet axis of size ``n_shards`` — P full TVM + arena +
        stack blocks stacked together; ``limits`` is ``i32[P]``, each
        shard's own dynamic epoch bound (a drained or boundless shard
        passes 0 / its guard and no-ops — the per-shard cond fails on
        entry, bit-identically to never launching it).

        With ``mesh`` (a 1-D ``"fleet"`` device mesh,
        :func:`repro.launch.mesh.make_fleet_mesh`) the chunk runs under
        ``shard_map``: each device owns one shard's block and drives its
        own resident ``while_loop`` — shards advance *independently* to
        their bounds inside the one launch, no cross-shard lockstep.
        Without a mesh the fleet falls back to ``vmap`` over the shard
        axis (single-device simulation): jax batches the while_loop as
        "while any shard's cond holds" with finished shards' carries
        frozen by ``select`` — bit-identical per shard, just not
        device-parallel.

        ``megakernel=True`` composes on the mesh path (each device runs
        its chunk through the persistent Pallas kernel); the vmap
        fallback drives the kernel's ``lax.while_loop`` oracle instead —
        the two are bit-identical by construction (DESIGN.md §12), so the
        fallback changes nothing observable.

        Compiled once per (shards, regions, capacity, depth, driver) and
        cached next to the solo chunk templates; ``limits`` stays dynamic
        so K adaptation and per-shard staggering never retrace.
        """
        capacity = int(carry.state.task.shape[-1])
        depth = int(carry.jstack.shape[-1])
        key = ("fleet", n_shards, n_regions, capacity, depth,
               mesh is not None)
        if key not in self._resident_cache:
            body = self.resident_body(capacity, depth)

            def cond(cc: ResidentCarry, lim):
                return (cc.sp > 0).any() & (cc.n_epochs < lim)

            use_megakernel = self.megakernel and mesh is not None
            if use_megakernel:
                from ..kernels import epoch_megakernel as mk

                impl = self.megakernel_impl

                def one_shard(c, lim):
                    return mk.epoch_chunk(cond, body, c, lim, impl=impl)

            else:

                def one_shard(c, lim):
                    return jax.lax.while_loop(
                        lambda cc: cond(cc, lim), body, c
                    )

            if mesh is None:
                loop = jax.jit(jax.vmap(one_shard))
            else:
                from ..launch.mesh import fleet_shard_map

                spec = jax.tree.map(lambda _: _FLEET_SPEC, carry)

                def shard_fn(c, lim):
                    # shard_map hands each device its block with the
                    # fleet axis still present (size 1): squeeze, run the
                    # solo chunk, re-expand
                    c1 = jax.tree.map(lambda x: x[0], c)
                    out = one_shard(c1, lim[0])
                    return jax.tree.map(lambda x: x[None], out)

                loop = jax.jit(fleet_shard_map(
                    shard_fn, mesh,
                    in_specs=(spec, _FLEET_SPEC),
                    out_specs=spec,
                ))
            self._resident_cache[key] = loop
        return self._resident_cache[key](
            carry, jnp.asarray(limits, jnp.int32)
        )

    def fleet_chunk_summaries(self, carry: ResidentCarry,
                              n_shards: int) -> List[ChunkSummary]:
        """The fleet boundary readback: ONE ``device_get`` of the stacked
        control scalars, split host-side into per-shard
        :class:`ChunkSummary` views — P shards pay the V_inf transfer
        once per collective chunk, not once each."""
        arena_next = None if carry.arena is None else carry.arena.next
        (sp, failed, failed_stack, n_epochs, job_epochs, job_tasks,
         job_forks, job_peak, m_ct, m_el, m_ln, holes, a_next) = (
            jax.device_get((
                carry.sp, carry.failed, carry.failed_stack, carry.n_epochs,
                carry.job_epochs, carry.job_tasks, carry.job_forks,
                carry.job_peak, carry.map_launches, carry.map_elements,
                carry.map_lanes, carry.hole_lanes, arena_next,
            ))
        )
        return [
            ChunkSummary(
                n_epochs=int(n_epochs[p]),
                sp=np.asarray(sp[p]),
                failed=np.asarray(failed[p]),
                failed_stack=np.asarray(failed_stack[p]),
                job_epochs=np.asarray(job_epochs[p]),
                job_tasks=_hilo_value(job_tasks[p]),
                job_forks=_hilo_value(job_forks[p]),
                job_peak=np.asarray(job_peak[p]),
                map_launches=int(m_ct[p]),
                map_elements=int(_hilo_value(m_el[p])),
                map_lanes=int(_hilo_value(m_ln[p])),
                hole_lanes=int(_hilo_value(holes[p])),
                arena_next=None if a_next is None else np.asarray(a_next[p]),
            )
            for p in range(n_shards)
        ]

    def chunk_summary(self, carry: ResidentCarry) -> ChunkSummary:
        """The chunk-boundary readback: one ``device_get`` of the compact
        control/accounting scalars.  The arena's region cursors ride along
        so a host multiplexer can reseed freed regions between chunks
        without ever fetching the bulk TV/heap state."""
        arena_next = None if carry.arena is None else carry.arena.next
        (sp, failed, failed_stack, n_epochs, job_epochs, job_tasks,
         job_forks, job_peak, m_ct, m_el, m_ln, holes, a_next) = (
            jax.device_get((
                carry.sp, carry.failed, carry.failed_stack, carry.n_epochs,
                carry.job_epochs, carry.job_tasks, carry.job_forks,
                carry.job_peak, carry.map_launches, carry.map_elements,
                carry.map_lanes, carry.hole_lanes, arena_next,
            ))
        )
        return ChunkSummary(
            n_epochs=int(n_epochs),
            sp=np.asarray(sp),
            failed=np.asarray(failed),
            failed_stack=np.asarray(failed_stack),
            job_epochs=np.asarray(job_epochs),
            job_tasks=_hilo_value(job_tasks),
            job_forks=_hilo_value(job_forks),
            job_peak=np.asarray(job_peak),
            map_launches=int(m_ct),
            map_elements=int(_hilo_value(m_el)),
            map_lanes=int(_hilo_value(m_ln)),
            hole_lanes=int(_hilo_value(holes)),
            arena_next=None if a_next is None else np.asarray(a_next),
        )


class HostEngine:
    """Paper-faithful engine: host drives stacks, device runs bulk epochs."""

    def __init__(
        self,
        program: Program,
        capacity: int = 1 << 14,
        collect_stats: bool = True,
        fork_offsets_fn: Optional[Callable] = None,
        donate: bool = False,
        dispatch: Any = MASKED,
        coalesce: bool = True,
        rank_fn: Optional[Callable] = None,
        pack_fn: Optional[Callable] = None,
        stats_factory: Optional[Callable[[], StatsCollector]] = None,
        tracer=None,
        controller=None,
    ):
        self.program = program
        self.capacity = capacity
        self.collect_stats = collect_stats
        self.coalesce = coalesce
        self._stats_factory = stats_factory
        self.loop = EpochLoop(
            program, dispatch,
            rank_fn=rank_fn, pack_fn=pack_fn,
            fork_offsets_fn=fork_offsets_fn, donate=donate,
            tracer=tracer, controller=controller,
        )
        self.tracer = self.loop.tracer
        self.policy = self.loop.policy
        self.controller = self.loop.controller

    def _collector(self) -> StatsCollector:
        if self._stats_factory is not None:
            return self._stats_factory()
        return RunStatsCollector() if self.collect_stats else NullStats()

    @staticmethod
    def _readback(summary, state):
        # the paper's end-of-epoch readback: nextFreeCore, joinScheduled,
        # mapScheduled (§5.2.4) (+ stats counters when enabled)
        return (
            summary.total_forks, summary.join_scheduled,
            summary.map_scheduled, summary.n_active, summary.overflow,
            state.next_free,
        )

    # --------------------------------------------------------------- run
    def run(
        self,
        initial: InitialTask,
        heap_init: Optional[Dict[str, Any]] = None,
        max_epochs: int = 1 << 20,
    ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray, RunStats]:
        """Execute the program to completion.

        Returns (final heap, final TV value array, stats).  The TVM halts
        when the join/NDRange stacks empty (paper §4.3.3).
        """
        program = self.program
        state = tvm.init_state(program, self.capacity, initial)
        heap = program.init_heap(**(heap_init or {}))
        # phase-1 state owned by the CPU, exactly as in the paper (§5.2.2)
        sched = EpochScheduler(coalesce=self.coalesce)
        sched.reset()
        col = self._collector()
        n_epochs = 0  # loop guard lives here, not in the pluggable collector
        tr = self.tracer
        if tr.enabled:
            tr.thread(1, "host-epochs")

        while sched:  # termination predicate: host stacks drained
            if n_epochs >= max_epochs:
                raise EngineError(f"exceeded max_epochs={max_epochs}")
            n_epochs += 1
            d = sched.pop()
            with tr.span(
                "epoch", "host", tid=1,
                cen=d.cen, ranges=d.n_ranges, mode=self.policy.name,
            ) as sargs:
                (state, heap, _summary, fetched, map_launches, launched,
                 by_type, _disp) = self.loop.run_epoch(
                    state, heap, None, d.start, d.count, d.cen, col,
                    self._readback,
                )
                total_forks, join_sched, map_sched, n_active, overflow, nf = (
                    fetched
                )
                if overflow:
                    raise EngineError(
                        f"task vector overflow: capacity={self.capacity}"
                    )
                if join_sched:
                    sched.push_join(d.cen, d.start, d.count)
                sched.push_forked(
                    d.cen + 1, int(nf) - int(total_forks), int(total_forks)
                )

                if map_sched:
                    heap = self.loop.maps.run(map_launches, heap, col)
                # close the feedback loop: the readback's active count vs
                # the *full* frontier width seeds the next epoch's decision
                if self.loop.controller is not None:
                    self.loop.controller.observe(
                        int(n_active), self.loop.last_span_bucket
                    )
                if tr.enabled:
                    dec = self.loop.last_decision
                    sargs.update(
                        launched=launched, active=int(n_active),
                        util=int(n_active) / max(1, launched),
                        **({"mode": dec.mode, "auto_reason": dec.reason}
                           if dec is not None else {}),
                    )

            col.epoch(d.cen, d.n_ranges)
            col.lanes(int(n_active), launched, by_type)
            col.forks(int(total_forks))
            col.tv_peak(int(nf))

        return heap, state.value, col.result()


class DeviceEngine:
    """Whole-program engine: stacks + epoch loop inside one XLA program.

    Beyond-paper optimization (the paper's "tighter coupling" prediction):
    zero per-epoch dispatches/transfers on the critical path — the
    :class:`EpochLoop` resident configuration with ``n_regions=1``.
    Dispatch: ``masked`` (span-ladder launches, §11) or ``gather`` (the
    in-loop dense frontier pack, §12 — the skipped lanes of either mode
    land in ``RunStats.hole_lanes_skipped``); ``compacted`` stays
    host-only (per-type launch shapes come from runtime populations).
    Map payloads are sized by the §10 ``max_domain``-capped width ladder
    (residual padding surfaced in ``RunStats.map_lanes_wasted``).
    ``megakernel=True`` routes each resident chunk through the persistent
    Pallas megakernel instead of the ``lax.while_loop`` (§12).
    """

    def __init__(
        self,
        program: Program,
        capacity: int = 1 << 12,
        stack_depth: int = 1 << 10,
        fork_offsets_fn: Optional[Callable] = None,
        dispatch: Any = MASKED,
        megakernel: bool = False,
        megakernel_impl: str = "auto",
        tracer=None,
        controller=None,
    ):
        self.program = program
        self.capacity = capacity
        self.stack_depth = stack_depth
        # a resident loop bakes its dispatch mode into the traced template,
        # so "auto" resolves *here*, once, via the controller (masked on a
        # cold window) — never per epoch inside the while_loop
        dispatch = resolve_resident_dispatch(dispatch, controller, capacity)
        if resolve_policy(dispatch).name not in ("masked", "gather"):
            raise ValueError(_COMPACTED_RESIDENT_MSG)
        self.loop = EpochLoop(program, dispatch,
                              fork_offsets_fn=fork_offsets_fn,
                              megakernel=megakernel,
                              megakernel_impl=megakernel_impl,
                              tracer=tracer)
        self.tracer = self.loop.tracer
        self.policy = self.loop.policy

    def run(
        self,
        initial: InitialTask,
        heap_init: Optional[Dict[str, Any]] = None,
        max_epochs: int = 1 << 16,
    ):
        program = self.program
        state = tvm.init_state(program, self.capacity, initial)
        heap = program.init_heap(**(heap_init or {}))
        jstack, rstack, sp = batched_device_stacks(1, self.stack_depth)
        carry = _fresh_resident_carry(
            state, heap, None, jstack, rstack, sp, n_regions=1
        )
        tr = self.tracer
        if tr.enabled:
            tr.thread(2, "resident")
        # the resident loop is unobservable per epoch by design (no per-epoch
        # readbacks to hang spans on): one "wave" span covers the whole
        # dispatch, and the per-epoch story is reconstructed from the
        # ChunkSummary deltas attached to it after the single readback
        with tr.span(
            "wave", "resident", tid=2,
            driver="device", mode=self.policy.name,
            megakernel=self.loop.megakernel,
        ) as sargs:
            with tr.annotation("trees:resident_wave"):
                out = self.loop.run_resident(carry, max_epochs, n_regions=1)
            # the one scalar transfer of the whole run
            with tr.span("readback", "resident", tid=2):
                s = self.loop.chunk_summary(out)
            if tr.enabled:
                sargs.update(
                    epochs=s.n_epochs, tasks=int(s.job_tasks[0]),
                    holes=s.hole_lanes,
                )
        if s.failed.any():
            raise EngineError("TV capacity or stack depth exhausted")
        if (s.sp > 0).any():
            raise EngineError(f"exceeded max_epochs={max_epochs}")
        stats = RunStats(
            epochs=s.n_epochs, dispatches=1, scalar_transfers=1,
            tasks_executed=int(s.job_tasks[0]),
            lanes_launched=s.n_epochs * self.capacity - s.hole_lanes,
            total_forks=int(s.job_forks[0]),
            map_launches=s.map_launches, map_elements=s.map_elements,
            map_lanes_launched=s.map_lanes,
            hole_lanes_skipped=s.hole_lanes,
        )
        stats.peak_tv_slots = int(s.job_peak[0])
        return out.heap, out.state.value, stats
