"""TREES epoch engines: host-loop (paper-faithful) and on-device.

Both engines are thin drivers over the scheduling layer in ``scheduler.py``:
the :class:`~repro.core.scheduler.EpochScheduler` owns the join/NDRange
stacks, same-CEN range coalescing, and launch-bucket sizing (phase 1), and a
pluggable :class:`~repro.core.scheduler.StatsCollector` owns the V1/V_inf
accounting.  The engines only own *where* the loop runs.

``HostEngine`` reproduces the paper's CPU/GPU split: the Python host performs
epoch phases 1 and 3 (stack bookkeeping, flag readback — the paper's
``joinScheduled``/``mapScheduled``/``nextFreeCore`` transfers) and dispatches
one jitted XLA program per epoch, sized by the dispatch policy.  Every
host<->device scalar transfer in the paper has a counterpart here, so the
paper's critical-path overhead V_inf stays measurable.  Two dispatch
policies:

  * ``masked`` (seed behaviour) — the popped NDRange padded to a
    power-of-two bucket; every task type executes full-width and masked.
  * ``compacted`` — the §5.4 contiguity principle: a compaction pass
    (``kernels.fork_compact.type_rank`` + ``fork_scan``) scatters active
    lanes into contiguous per-type ranges, and each type launches as one
    dense lane-exact slice.  Results are bit-identical to ``masked`` (the
    commit still sees NDRange lane order); only lane utilization and the
    V_inf dispatch/transfer counts differ — exactly the §5.4 trade.

``DeviceEngine`` is the beyond-paper variant the paper itself predicts
("future chips with tighter CPU/GPU coupling"): the entire epoch loop runs
on-device inside one ``lax.while_loop`` with the join/NDRange stacks as fixed
capacity device arrays (``scheduler.device_stacks``), eliminating the
per-epoch dispatch + transfer from the critical path entirely.  Because every
launch shape is fixed at trace time, it supports only the ``masked``
dispatch.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import tvm
from .program import InitialTask, Program
from .scheduler import (  # noqa: F401  (RunStats re-exported for back-compat)
    COMPACTED,
    MASKED,
    DispatchPolicy,
    EpochScheduler,
    NullStats,
    RunStats,
    RunStatsCollector,
    StatsCollector,
    device_push,
    device_stacks,
    launch_bucket,
    resolve_policy,
    size_type_buckets,
)


class EngineError(RuntimeError):
    pass


def _build_epoch_step(program: Program, fork_offsets_fn=None):
    """Shared masked phase-2+3 step; specialized by jit on the lane count P."""

    def step(state: tvm.TVMState, heap, start, count, cen, P: int):
        idx = start + jnp.arange(P, dtype=jnp.int32)
        in_range = jnp.arange(P, dtype=jnp.int32) < count
        cidx = jnp.clip(idx, 0, state.capacity - 1)
        active = in_range & (state.epoch[cidx] == cen)
        per_type, _ = tvm.trace_tasks(program, state, heap, idx, active)
        return tvm.commit_epoch(
            program, state, heap, idx, active, per_type, cen,
            fork_offsets_fn=fork_offsets_fn,
        )

    return step


def _default_rank_fn(types, active, n_types):
    from ..kernels import ops as kops

    return kops.type_rank(types, active, n_types)


class MapLauncher:
    """Host-side launcher for scheduled ``map`` payloads (paper §5.2.4).

    Sizes each payload launch to the *live* element domain of its scheduled
    lanes, skips payloads whose lanes all have empty domains, and caches the
    jitted step per (map, lane-count, domain-bucket).  Shared by
    :class:`HostEngine` and the service-layer epoch multiplexer, which both
    run phase 1/3 on the host.
    """

    def __init__(self, program: Program, donate: bool = False):
        self.program = program
        self._donate = donate
        self._cache: Dict[Tuple[int, int, int], Any] = {}

    def _get_step(self, mid: int, P: int, D: int):
        key = (mid, P, D)
        if key not in self._cache:
            def mfn(heap, where, argi, argf):
                return tvm.run_map_payload(
                    self.program, heap, mid, where, argi, argf, D
                )

            self._cache[key] = jax.jit(
                mfn, donate_argnums=(0,) if self._donate else ()
            )
        return self._cache[key]

    def run(self, map_launches, heap, col: StatsCollector):
        """Launch each scheduled map payload, sized to its live domain."""
        for ml in map_launches:
            where = np.asarray(jax.device_get(ml.where))
            if not where.any():
                continue
            argi = np.asarray(jax.device_get(ml.argi))
            dom = np.asarray(self.program.maps[ml.map_id].domain(argi))
            dmax = int(dom[where].max()) if dom[where].size else 0
            if dmax <= 0:
                # every scheduled lane has an empty element domain: a launch
                # would dispatch a wasted payload (launch_bucket(0) lanes)
                continue
            D = launch_bucket(dmax, minimum=8)
            mstep = self._get_step(ml.map_id, int(where.shape[0]), D)
            heap = mstep(heap, ml.where, ml.argi, ml.argf)
            col.dispatch()
            # what to record is the collector's decision (NullStats ignores
            # the element count), not an engine-level flag's
            col.map_launch(int(dom[where].sum()))
        return heap


class HostEngine:
    """Paper-faithful engine: host drives stacks, device runs bulk epochs."""

    def __init__(
        self,
        program: Program,
        capacity: int = 1 << 14,
        collect_stats: bool = True,
        fork_offsets_fn: Optional[Callable] = None,
        donate: bool = False,
        dispatch: Any = MASKED,
        coalesce: bool = True,
        rank_fn: Optional[Callable] = None,
        stats_factory: Optional[Callable[[], StatsCollector]] = None,
    ):
        self.program = program
        self.capacity = capacity
        self.collect_stats = collect_stats
        self.policy: DispatchPolicy = resolve_policy(dispatch)
        self.coalesce = coalesce
        self._fork_offsets_fn = fork_offsets_fn
        self._rank_fn = rank_fn or _default_rank_fn
        self._stats_factory = stats_factory
        self._raw_step = _build_epoch_step(program, fork_offsets_fn)
        self._step_cache: Dict[Any, Any] = {}
        self._compact_cache: Dict[int, Any] = {}
        self._maps = MapLauncher(program, donate=donate)
        self._donate = donate

    # ------------------------------------------------------------- steps
    def _collector(self) -> StatsCollector:
        if self._stats_factory is not None:
            return self._stats_factory()
        return RunStatsCollector() if self.collect_stats else NullStats()

    def _get_step(self, P: int):
        if P not in self._step_cache:
            fn = functools.partial(self._raw_step, P=P)
            self._step_cache[P] = jax.jit(
                fn, donate_argnums=(0, 1) if self._donate else ()
            )
        return self._step_cache[P]

    def _get_compact(self, P: int):
        """Compaction pass: types -> (perm, per-type counts), one dispatch."""
        if P not in self._compact_cache:
            program, rank_fn = self.program, self._rank_fn
            offsets_fn = self._fork_offsets_fn

            def cfn(state, start, count, cen):
                idx = start + jnp.arange(P, dtype=jnp.int32)
                in_range = jnp.arange(P, dtype=jnp.int32) < count
                cidx = jnp.clip(idx, 0, state.capacity - 1)
                active = in_range & (state.epoch[cidx] == cen)
                return tvm.compact_types(
                    program, state, idx, active,
                    rank_fn=rank_fn, offsets_fn=offsets_fn,
                )

            self._compact_cache[P] = jax.jit(cfn)
        return self._compact_cache[P]

    _MAX_STEP_CACHE = 256  # distinct (P, buckets) jit specializations kept

    def _get_compacted_step(self, P: int, buckets: Tuple[int, ...]):
        key = (P, buckets)
        if key not in self._step_cache:
            # Bucket combinations on k-type programs can be numerous; bound
            # the cache (FIFO eviction — evicted shapes just recompile) so a
            # long-running engine cannot grow it without limit.
            while len(self._step_cache) >= self._MAX_STEP_CACHE:
                self._step_cache.pop(next(iter(self._step_cache)))
            program = self.program
            fork_offsets_fn = self._fork_offsets_fn

            def step(state, heap, start, count, cen, perm, toffs, tcounts):
                per_type, idx, active = tvm.trace_tasks_compacted(
                    program, state, heap, start, count, cen,
                    perm, toffs, tcounts, buckets,
                )
                return tvm.commit_epoch(
                    program, state, heap, idx, active, per_type, cen,
                    fork_offsets_fn=fork_offsets_fn,
                )

            self._step_cache[key] = jax.jit(
                step, donate_argnums=(0, 1) if self._donate else ()
            )
        return self._step_cache[key]

    # --------------------------------------------------------------- run
    def run(
        self,
        initial: InitialTask,
        heap_init: Optional[Dict[str, Any]] = None,
        max_epochs: int = 1 << 20,
    ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray, RunStats]:
        """Execute the program to completion.

        Returns (final heap, final TV value array, stats).  The TVM halts
        when the join/NDRange stacks empty (paper §4.3.3).
        """
        program = self.program
        state = tvm.init_state(program, self.capacity, initial)
        heap = program.init_heap(**(heap_init or {}))
        # phase-1 state owned by the CPU, exactly as in the paper (§5.2.2)
        sched = EpochScheduler(coalesce=self.coalesce)
        sched.reset()
        col = self._collector()
        task_names = [t.name for t in program.tasks]
        compacted = self.policy.name == "compacted"
        n_epochs = 0  # loop guard lives here, not in the pluggable collector

        while sched:
            if n_epochs >= max_epochs:
                raise EngineError(f"exceeded max_epochs={max_epochs}")
            n_epochs += 1
            d = sched.pop()
            cen, start, count = d.cen, d.start, d.count
            P = self.policy.epoch_bucket(count)
            start_j = jnp.asarray(start, jnp.int32)
            count_j = jnp.asarray(count, jnp.int32)
            cen_j = jnp.asarray(cen, jnp.int32)
            by_type = None
            if compacted:
                # compaction pass + per-type-count readback (§5.4's extra
                # V_inf dispatch/transfer, paid to make phase 2 lane-exact)
                perm, counts_dev = self._get_compact(P)(
                    state, start_j, count_j, cen_j
                )
                counts = np.asarray(jax.device_get(counts_dev), np.int64)
                col.dispatch()
                col.transfer()
                buckets, toffs, launched, by_type = size_type_buckets(
                    self.policy, counts, task_names
                )
                step = self._get_compacted_step(P, buckets)
                state, heap, summary, map_launches = step(
                    state, heap, start_j, count_j, cen_j, perm,
                    jnp.asarray(toffs, jnp.int32),
                    jnp.asarray(counts, jnp.int32),
                )
            else:
                step = self._get_step(P)
                state, heap, summary, map_launches = step(
                    state, heap, start_j, count_j, cen_j
                )
                launched = P
            # the paper's end-of-epoch readback: nextFreeCore, joinScheduled,
            # mapScheduled (§5.2.4) (+ stats counters when enabled)
            total_forks, join_sched, map_sched, n_active, overflow, nf = (
                jax.device_get(
                    (
                        summary.total_forks,
                        summary.join_scheduled,
                        summary.map_scheduled,
                        summary.n_active,
                        summary.overflow,
                        state.next_free,
                    )
                )
            )
            col.dispatch()
            col.transfer()
            if overflow:
                raise EngineError(
                    f"task vector overflow: capacity={self.capacity}"
                )
            if join_sched:
                sched.push_join(cen, start, count)
            sched.push_forked(
                cen + 1, int(nf) - int(total_forks), int(total_forks)
            )

            if map_sched:
                heap = self._maps.run(map_launches, heap, col)

            col.epoch(cen, d.n_ranges)
            col.lanes(int(n_active), launched, by_type)
            col.forks(int(total_forks))
            col.tv_peak(int(nf))

        return heap, state.value, col.result()


class DeviceEngine:
    """Whole-program engine: stacks + epoch loop inside one XLA program.

    Beyond-paper optimization (the paper's "tighter coupling" prediction):
    zero per-epoch dispatches/transfers on the critical path.  Constraints:
    fixed TV capacity processed every epoch (no NDRange bucketing — so only
    the ``masked`` dispatch policy is traceable) and map payloads sized by
    ``MapType.max_domain``.
    """

    def __init__(
        self,
        program: Program,
        capacity: int = 1 << 12,
        stack_depth: int = 1 << 10,
        fork_offsets_fn: Optional[Callable] = None,
        dispatch: Any = MASKED,
    ):
        self.program = program
        self.capacity = capacity
        self.stack_depth = stack_depth
        self.policy = resolve_policy(dispatch)
        if self.policy.name != "masked":
            raise ValueError(
                "DeviceEngine supports only the 'masked' dispatch: the "
                "on-device while_loop needs launch shapes fixed at trace "
                "time, but 'compacted' sizes per-type launches from runtime "
                "populations (use HostEngine for compacted dispatch)"
            )
        self._raw_step = _build_epoch_step(program, fork_offsets_fn)
        self._compiled = None

    def _body(self, carry):
        (state, heap, jstack, rstack, sp, n_epochs, err) = carry
        cen = jstack[sp - 1]
        start, count = rstack[sp - 1, 0], rstack[sp - 1, 1]
        sp = sp - 1
        old_next_free = state.next_free
        state, heap, summary, map_launches = self._raw_step(
            state, heap, start, count, cen, P=self.capacity
        )
        # push join range back, then the forked range (LIFO order, §4.3.3)
        jstack, rstack, sp = device_push(
            jstack, rstack, sp, cen, start, count,
            summary.join_scheduled, self.stack_depth,
        )
        jstack, rstack, sp = device_push(
            jstack, rstack, sp, cen + 1, old_next_free, summary.total_forks,
            summary.total_forks > 0, self.stack_depth,
        )
        for ml in map_launches:
            mt = self.program.maps[ml.map_id]
            if mt.max_domain <= 0:
                raise EngineError(
                    f"map '{mt.name}' needs max_domain>0 for DeviceEngine"
                )
            heap = jax.lax.cond(
                ml.where.any(),
                lambda h: tvm.run_map_payload(
                    self.program, h, ml.map_id, ml.where, ml.argi, ml.argf,
                    mt.max_domain,
                ),
                lambda h: h,
                heap,
            )
        err = err | summary.overflow | (sp >= self.stack_depth)
        return (state, heap, jstack, rstack, sp, n_epochs + 1, err)

    def run(
        self,
        initial: InitialTask,
        heap_init: Optional[Dict[str, Any]] = None,
        max_epochs: int = 1 << 16,
    ):
        program = self.program
        state = tvm.init_state(program, self.capacity, initial)
        heap = program.init_heap(**(heap_init or {}))
        jstack, rstack = device_stacks(self.stack_depth)

        def cond(carry):
            (_, _, _, _, sp, n_epochs, err) = carry
            return (sp > 0) & (n_epochs < max_epochs) & (~err)

        @jax.jit
        def loop(state, heap, jstack, rstack):
            carry = (
                state, heap, jstack, rstack,
                jnp.asarray(1, jnp.int32), jnp.asarray(0, jnp.int32),
                jnp.asarray(False),
            )
            return jax.lax.while_loop(cond, self._body, carry)

        state, heap, _, _, sp, n_epochs, err = loop(state, heap, jstack, rstack)
        if bool(err):
            raise EngineError("TV capacity or stack depth exhausted")
        stats = RunStats(epochs=int(n_epochs), dispatches=1, scalar_transfers=1)
        stats.peak_tv_slots = int(jax.device_get(state.next_free))
        return heap, state.value, stats
