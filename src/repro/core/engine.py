"""TREES epoch engines: host-loop (paper-faithful) and on-device.

``HostEngine`` reproduces the paper's CPU/GPU split: the Python host performs
epoch phases 1 and 3 (stack bookkeeping, flag readback — the paper's
``joinScheduled``/``mapScheduled``/``nextFreeCore`` transfers) and dispatches
one jitted XLA program per epoch, sized to the popped NDRange padded to a
power-of-two bucket (the analogue of launching a kernel with that NDRange).
Every host<->device scalar transfer in the paper has a counterpart here, so
the paper's critical-path overhead V_inf stays measurable.

``DeviceEngine`` is the beyond-paper variant the paper itself predicts
("future chips with tighter CPU/GPU coupling"): the entire epoch loop runs
on-device inside one ``lax.while_loop`` with the join/NDRange stacks as fixed
capacity device arrays, eliminating the per-epoch dispatch + transfer from
the critical path entirely.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import tvm
from .program import InitialTask, Program


@dataclasses.dataclass
class RunStats:
    """Work/critical-path accounting in the paper's terms (§4.4.1)."""

    epochs: int = 0                 # critical path length T_inf (in epochs)
    tasks_executed: int = 0         # work T_1 (in tasks)
    lanes_launched: int = 0         # includes padding/invalid lanes
    total_forks: int = 0
    map_launches: int = 0
    map_elements: int = 0
    peak_tv_slots: int = 0          # space (paper §4.4.2)
    dispatches: int = 0             # host->device program launches (V_inf)
    scalar_transfers: int = 0       # device->host readbacks (V_inf)

    @property
    def utilization(self) -> float:
        """Active lanes / launched lanes — the SIMT-divergence analogue."""
        return self.tasks_executed / max(1, self.lanes_launched)


class EngineError(RuntimeError):
    pass


def _bucket(n: int, minimum: int = 8) -> int:
    """Round the NDRange up to a power-of-two launch bucket."""
    p = minimum
    while p < n:
        p *= 2
    return p


def _build_epoch_step(program: Program, fork_offsets_fn=None):
    """Shared phase-2+3 step; specialized by jit on the lane count P."""

    def step(state: tvm.TVMState, heap, start, count, cen, P: int):
        idx = start + jnp.arange(P, dtype=jnp.int32)
        in_range = jnp.arange(P, dtype=jnp.int32) < count
        cidx = jnp.clip(idx, 0, state.capacity - 1)
        active = in_range & (state.epoch[cidx] == cen)
        per_type, _ = tvm.trace_tasks(program, state, heap, idx, active)
        return tvm.commit_epoch(
            program, state, heap, idx, active, per_type, cen,
            fork_offsets_fn=fork_offsets_fn,
        )

    return step


class HostEngine:
    """Paper-faithful engine: host drives stacks, device runs bulk epochs."""

    def __init__(
        self,
        program: Program,
        capacity: int = 1 << 14,
        collect_stats: bool = True,
        fork_offsets_fn: Optional[Callable] = None,
        donate: bool = False,
    ):
        self.program = program
        self.capacity = capacity
        self.collect_stats = collect_stats
        self._raw_step = _build_epoch_step(program, fork_offsets_fn)
        self._step_cache: Dict[int, Any] = {}
        self._map_cache: Dict[Tuple[int, int, int], Any] = {}
        self._donate = donate

    # ------------------------------------------------------------- steps
    def _get_step(self, P: int):
        if P not in self._step_cache:
            fn = functools.partial(self._raw_step, P=P)
            self._step_cache[P] = jax.jit(
                fn, donate_argnums=(0, 1) if self._donate else ()
            )
        return self._step_cache[P]

    def _get_map_step(self, mid: int, P: int, D: int):
        key = (mid, P, D)
        if key not in self._map_cache:
            def mfn(heap, where, argi, argf):
                return tvm.run_map_payload(
                    self.program, heap, mid, where, argi, argf, D
                )

            self._map_cache[key] = jax.jit(
                mfn, donate_argnums=(0,) if self._donate else ()
            )
        return self._map_cache[key]

    # --------------------------------------------------------------- run
    def run(
        self,
        initial: InitialTask,
        heap_init: Optional[Dict[str, Any]] = None,
        max_epochs: int = 1 << 20,
    ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray, RunStats]:
        """Execute the program to completion.

        Returns (final heap, final TV value array, stats).  The TVM halts
        when the join/NDRange stacks empty (paper §4.3.3).
        """
        program = self.program
        state = tvm.init_state(program, self.capacity, initial)
        heap = program.init_heap(**(heap_init or {}))
        # phase-1 state owned by the CPU, exactly as in the paper (§5.2.2)
        join_stack = [1]
        range_stack = [(0, 1)]
        next_free_host = 1
        stats = RunStats()

        while join_stack:
            if stats.epochs >= max_epochs:
                raise EngineError(f"exceeded max_epochs={max_epochs}")
            cen = join_stack.pop()
            start, count = range_stack.pop()
            P = _bucket(count)
            step = self._get_step(P)
            state, heap, summary, map_launches = step(
                state, heap, jnp.asarray(start, jnp.int32),
                jnp.asarray(count, jnp.int32), jnp.asarray(cen, jnp.int32),
            )
            # the paper's end-of-epoch readback: nextFreeCore, joinScheduled,
            # mapScheduled (§5.2.4) (+ stats counters when enabled)
            total_forks, join_sched, map_sched, n_active, overflow, nf = (
                jax.device_get(
                    (
                        summary.total_forks,
                        summary.join_scheduled,
                        summary.map_scheduled,
                        summary.n_active,
                        summary.overflow,
                        state.next_free,
                    )
                )
            )
            stats.dispatches += 1
            stats.scalar_transfers += 1
            if overflow:
                raise EngineError(
                    f"task vector overflow: capacity={self.capacity}"
                )
            if join_sched:
                join_stack.append(cen)
                range_stack.append((start, count))
            if total_forks > 0:
                join_stack.append(cen + 1)
                range_stack.append((int(nf) - int(total_forks), int(total_forks)))
            next_free_host = int(nf)

            if map_sched:
                for ml in map_launches:
                    where = np.asarray(jax.device_get(ml.where))
                    if not where.any():
                        continue
                    argi = np.asarray(jax.device_get(ml.argi))
                    dom = np.asarray(self.program.maps[ml.map_id].domain(argi))
                    D = _bucket(int(dom[where].max()), minimum=8)
                    mstep = self._get_map_step(ml.map_id, int(where.shape[0]), D)
                    heap = mstep(heap, ml.where, ml.argi, ml.argf)
                    stats.map_launches += 1
                    stats.dispatches += 1
                    if self.collect_stats:
                        stats.map_elements += int(dom[where].sum())

            if self.collect_stats:
                stats.epochs += 1
                stats.tasks_executed += int(n_active)
                stats.lanes_launched += P
                stats.total_forks += int(total_forks)
                stats.peak_tv_slots = max(stats.peak_tv_slots, next_free_host)
            else:
                stats.epochs += 1

        return heap, state.value, stats


class DeviceEngine:
    """Whole-program engine: stacks + epoch loop inside one XLA program.

    Beyond-paper optimization (the paper's "tighter coupling" prediction):
    zero per-epoch dispatches/transfers on the critical path.  Constraints:
    fixed TV capacity processed every epoch (no NDRange bucketing) and map
    payloads sized by ``MapType.max_domain``.
    """

    def __init__(
        self,
        program: Program,
        capacity: int = 1 << 12,
        stack_depth: int = 1 << 10,
        fork_offsets_fn: Optional[Callable] = None,
    ):
        self.program = program
        self.capacity = capacity
        self.stack_depth = stack_depth
        self._raw_step = _build_epoch_step(program, fork_offsets_fn)
        self._compiled = None

    def _body(self, carry):
        (state, heap, jstack, rstack, sp, n_epochs, err) = carry
        cen = jstack[sp - 1]
        start, count = rstack[sp - 1, 0], rstack[sp - 1, 1]
        sp = sp - 1
        old_next_free = state.next_free
        state, heap, summary, map_launches = self._raw_step(
            state, heap, start, count, cen, P=self.capacity
        )
        # push join range back, then the forked range (LIFO order, §4.3.3)
        def push(jstack, rstack, sp, e, s, c, pred):
            ssp = jnp.clip(sp, 0, self.stack_depth - 1)
            jstack = jnp.where(
                pred, jstack.at[ssp].set(e), jstack
            )
            rstack = jnp.where(
                pred, rstack.at[ssp].set(jnp.stack([s, c])), rstack
            )
            return jstack, rstack, sp + pred.astype(jnp.int32)

        jstack, rstack, sp = push(
            jstack, rstack, sp, cen, start, count, summary.join_scheduled
        )
        forked = summary.total_forks > 0
        jstack, rstack, sp = push(
            jstack, rstack, sp, cen + 1, old_next_free, summary.total_forks,
            forked,
        )
        for ml in map_launches:
            mt = self.program.maps[ml.map_id]
            if mt.max_domain <= 0:
                raise EngineError(
                    f"map '{mt.name}' needs max_domain>0 for DeviceEngine"
                )
            heap = jax.lax.cond(
                ml.where.any(),
                lambda h: tvm.run_map_payload(
                    self.program, h, ml.map_id, ml.where, ml.argi, ml.argf,
                    mt.max_domain,
                ),
                lambda h: h,
                heap,
            )
        err = err | summary.overflow | (sp >= self.stack_depth)
        return (state, heap, jstack, rstack, sp, n_epochs + 1, err)

    def run(
        self,
        initial: InitialTask,
        heap_init: Optional[Dict[str, Any]] = None,
        max_epochs: int = 1 << 16,
    ):
        program = self.program
        state = tvm.init_state(program, self.capacity, initial)
        heap = program.init_heap(**(heap_init or {}))
        jstack = jnp.zeros((self.stack_depth,), jnp.int32).at[0].set(1)
        rstack = (
            jnp.zeros((self.stack_depth, 2), jnp.int32)
            .at[0].set(jnp.asarray([0, 1], jnp.int32))
        )

        def cond(carry):
            (_, _, _, _, sp, n_epochs, err) = carry
            return (sp > 0) & (n_epochs < max_epochs) & (~err)

        @jax.jit
        def loop(state, heap, jstack, rstack):
            carry = (
                state, heap, jstack, rstack,
                jnp.asarray(1, jnp.int32), jnp.asarray(0, jnp.int32),
                jnp.asarray(False),
            )
            return jax.lax.while_loop(cond, self._body, carry)

        state, heap, _, _, sp, n_epochs, err = loop(state, heap, jstack, rstack)
        if bool(err):
            raise EngineError("TV capacity or stack depth exhausted")
        stats = RunStats(epochs=int(n_epochs), dispatches=1, scalar_transfers=1)
        stats.peak_tv_slots = int(jax.device_get(state.next_free))
        return heap, state.value, stats
