"""Task Vector Machine state + the bulk epoch step (paper §4, §5.1–5.2).

The TVM's Task Vector is stored struct-of-arrays so that every runtime access
is a unit-stride vector load/store — the TPU analogue of the paper's memory
coalescing (§5.1.2).  The Task Mask Stack is replaced, exactly as in the
paper, by per-slot Epoch Numbers (0 = invalid sentinel) plus host- or
device-side join/NDRange stacks.

The epoch step implements the paper's three phases:
  phase 1 (setup)    — pop stacks, reset fork/join/map flags  (engine)
  phase 2 (execute)  — every task type runs as one masked dense vector op
  phase 3 (commit)   — prefix-sum fork allocation, TMS update  (this module)

The fork allocation replaces the paper's ``atomicInc(nextFreeCore)`` with an
exclusive prefix sum over per-lane fork counts (TPU has no global atomics;
the scan is deterministic and keeps children contiguous).  The scan itself is
the compute hot spot the paper optimizes with wavefront-level cooperation; we
optimize it with the ``fork_compact`` Pallas kernel (``repro.kernels``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .primitives import EpochCtx, MapCtx
from .program import Program


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TVMState:
    """Struct-of-arrays Task Vector (+ bookkeeping scalars)."""

    task: jnp.ndarray        # i32[C]  task type id
    argi: jnp.ndarray        # i32[C, A]
    argf: jnp.ndarray        # f32[C, Af]
    epoch: jnp.ndarray       # i32[C]  epoch number; 0 = invalid
    value: jnp.ndarray       # value_dtype[C, W]  emitted values
    child_base: jnp.ndarray  # i32[C]  first child slot (contiguity invariant)
    child_count: jnp.ndarray  # i32[C]
    next_free: jnp.ndarray   # i32[]   paper's nextFreeCore

    @property
    def capacity(self) -> int:
        return self.task.shape[0]


def init_state(program: Program, capacity: int, initial) -> TVMState:
    """Paper §4.3: seed task in slot 0, eligible in the first epoch (CEN=1)."""
    from .program import pack_args

    ai, af = pack_args(program, initial.argi, initial.argf)
    tid = program.task_id(initial.task)
    state = TVMState(
        task=jnp.zeros((capacity,), jnp.int32).at[0].set(tid),
        argi=jnp.zeros((capacity, program.n_arg_i), jnp.int32).at[0].set(ai),
        argf=jnp.zeros((capacity, program.n_arg_f), jnp.float32).at[0].set(af),
        epoch=jnp.zeros((capacity,), jnp.int32).at[0].set(1),
        value=jnp.zeros((capacity, program.value_width), program.value_dtype),
        child_base=jnp.zeros((capacity,), jnp.int32),
        child_count=jnp.zeros((capacity,), jnp.int32),
        next_free=jnp.asarray(1, jnp.int32),
    )
    return state


def _exclusive_cumsum(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(x) - x


@dataclasses.dataclass(frozen=True)
class EpochSummary:
    """Scalars the CPU reads back at the end of each epoch (paper §5.2.4)."""

    total_forks: jnp.ndarray     # i32[]
    join_scheduled: jnp.ndarray  # bool[]
    map_scheduled: jnp.ndarray   # bool[]
    n_active: jnp.ndarray        # i32[]  (stats: work in tasks, T1)
    overflow: jnp.ndarray        # bool[]  TV capacity exhausted


jax.tree_util.register_dataclass(
    EpochSummary,
    data_fields=[
        "total_forks", "join_scheduled", "map_scheduled", "n_active",
        "overflow",
    ],
    meta_fields=[],
)


@dataclasses.dataclass
class JobArena:
    """Per-job slot regions inside one shared Task Vector (service layer).

    The epoch-multiplexing job service (``repro.service``) co-schedules many
    independent programs in one :class:`TVMState`.  Each job ``j`` owns the
    contiguous slot region ``[base[j], end[j])`` — its private Task Vector,
    laid out exactly as a solo run of capacity ``end[j]-base[j]`` shifted by
    ``base[j]`` — and ``slot_job`` tags every TV slot with its region index
    (``J`` for slots outside every region).  ``next`` is the per-region
    ``nextFreeCore`` cursor; :func:`commit_epoch` allocates each job's forks
    from its own cursor with a segmented prefix sum, so no job's children
    ever land in another job's region and per-job layout stays bit-identical
    to the solo run.
    """

    slot_job: jnp.ndarray  # i32[C] region index per TV slot (J = unowned)
    base: jnp.ndarray      # i32[J] region start (inclusive)
    end: jnp.ndarray       # i32[J] region end (exclusive)
    next: jnp.ndarray      # i32[J] per-region nextFreeCore (absolute slots)

    @property
    def n_jobs(self) -> int:
        return self.base.shape[0]


jax.tree_util.register_dataclass(
    JobArena,
    data_fields=["slot_job", "base", "end", "next"],
    meta_fields=[],
)


def arena_reset_region(arena: JobArena, j: int, base: int,
                       quota: int) -> JobArena:
    """Re-point region ``j``'s cursors at a freshly reseeded tenant.

    The region's ``end`` shrinks (or grows back) to the new tenant's quota
    and its ``nextFreeCore`` cursor returns to ``base + 1`` (root slot
    occupied), exactly the solo ``init_state`` layout shifted by ``base``.
    Shared by the host multiplexer's mid-flight reuse and the chunked
    resident driver's between-chunk admission, so the two paths can never
    drift.
    """
    return dataclasses.replace(
        arena,
        end=arena.end.at[j].set(base + quota),
        next=arena.next.at[j].set(base + 1),
    )


@dataclasses.dataclass(frozen=True)
class MuxEpochSummary:
    """Per-job end-of-epoch scalars for the fused multi-tenant readback.

    One ``device_get`` of this struct replaces J separate solo readbacks —
    the work-together win extended across tenants: the whole fleet pays the
    V_inf transfer once per global epoch.  The first five fields aggregate
    exactly like :class:`EpochSummary`; the ``job_*`` arrays carry each
    region's own ``nextFreeCore``/``joinScheduled``/fork totals so every
    job's scheduler can push its continuations exactly as a solo engine
    would.
    """

    total_forks: jnp.ndarray     # i32[]
    join_scheduled: jnp.ndarray  # bool[]
    map_scheduled: jnp.ndarray   # bool[]
    n_active: jnp.ndarray        # i32[]
    overflow: jnp.ndarray        # bool[]  any region exhausted
    job_forks: jnp.ndarray       # i32[J]  forks allocated per region
    job_join: jnp.ndarray        # bool[J] join scheduled per region
    job_active: jnp.ndarray      # i32[J]  active lanes per region
    job_overflow: jnp.ndarray    # bool[J] region capacity exhausted
    job_next: jnp.ndarray        # i32[J]  post-commit region cursors


jax.tree_util.register_dataclass(
    MuxEpochSummary,
    data_fields=[
        "total_forks", "join_scheduled", "map_scheduled", "n_active",
        "overflow", "job_forks", "job_join", "job_active", "job_overflow",
        "job_next",
    ],
    meta_fields=[],
)


@dataclasses.dataclass
class MapLaunch:
    """One map site's scheduled lanes, for the payload launch."""

    map_id: int
    where: jnp.ndarray  # bool[P]
    argi: jnp.ndarray   # i32[P, A]
    argf: jnp.ndarray   # f32[P, Af]


jax.tree_util.register_dataclass(
    MapLaunch,
    data_fields=["where", "argi", "argf"],
    meta_fields=["map_id"],
)


def _make_lane_fn(program: Program, ttype, heap, values):
    """Per-lane task body -> fixed effects pytree (shared by both dispatches)."""

    def lane_fn(ai, af, cb, cc, slot, _fn=ttype.fn):
        ctx = EpochCtx(program, ai, af, cb, cc, slot, heap, values)
        _fn(ctx)
        return _effects_pytree(program, ctx)

    return lane_fn


def trace_tasks(
    program: Program,
    state: TVMState,
    heap: Dict[str, jnp.ndarray],
    idx: jnp.ndarray,
    active: jnp.ndarray,
    skip_idle_types: bool = False,
):
    """Phase 2: run every task type as one masked dense vector op.

    Baseline "work-together" dispatch: each type executes across all P
    lanes, masked — lane utilization is the divergence term of §4.4.1.

    ``skip_idle_types`` (beyond-paper engine optimization): epochs are very
    often type-homogeneous (fork epochs run forked tasks, join epochs run
    continuations — a direct consequence of the LIFO TMS), so each type's
    body is wrapped in ``lax.cond(any(mask_t))`` and skipped entirely when
    no lane of that type is active.  Effect pytrees are fixed-shape, so the
    skipped branch returns structurally identical no-op effects.
    """
    cidx = jnp.clip(idx, 0, state.capacity - 1)
    g_task = state.task[cidx]
    g_argi = state.argi[cidx]
    g_argf = state.argf[cidx]
    g_cb = state.child_base[cidx]
    g_cc = state.child_count[cidx]

    per_type = []
    for tid, ttype in enumerate(program.tasks):
        lane_fn = _make_lane_fn(program, ttype, heap, state.value)
        mask_t = active & (g_task == tid)

        def run_type(_):
            return jax.vmap(lane_fn)(g_argi, g_argf, g_cb, g_cc, cidx)

        if skip_idle_types and len(program.tasks) > 1:
            zero_eff = jax.tree.map(
                jnp.zeros_like,
                jax.eval_shape(run_type, 0),
            )
            eff = jax.lax.cond(
                mask_t.any(), run_type, lambda _: zero_eff, 0
            )
        else:
            eff = run_type(0)
        per_type.append((mask_t, eff))
    return per_type, cidx


def compact_types(
    program: Program,
    state: TVMState,
    idx: jnp.ndarray,
    active: jnp.ndarray,
    rank_fn: Optional[Callable] = None,
    offsets_fn: Optional[Callable] = None,
):
    """Compaction stage: scatter active lanes into contiguous per-type ranges.

    The §5.4 contiguity principle as a pipeline stage: each active lane gets
    a destination ``dest = type_start[type] + rank`` where ``rank`` is its
    stable within-type rank (``kernels.fork_compact.type_rank``) and
    ``type_start`` is the exclusive prefix sum of the per-type populations
    (``fork_scan`` — the same primitive that allocates fork slots).  The
    resulting permutation groups same-type tasks into dense ranges, so phase
    2 can execute each type as one coherent lane-exact launch instead of a
    full-width masked vmap.

    Returns ``(perm, counts)``:
      * ``perm`` i32[P] — ``perm[d]`` is the *lane position* (offset within
        the epoch's NDRange) of the d-th compacted lane; -1 beyond the
        active population.
      * ``counts`` i32[n_types] — per-type active populations; the host
        reads these back to size the per-type launch buckets (one extra
        V_inf transfer, the §5.4 trade).
    """
    P = idx.shape[0]
    n_types = len(program.tasks)
    cidx = jnp.clip(idx, 0, state.capacity - 1)
    types = state.task[cidx]
    if rank_fn is None:
        from ..kernels import ref as _kref

        rank, counts = _kref.type_rank_ref(types, active, n_types)
    else:
        rank, counts = rank_fn(types, active, n_types)
    if offsets_fn is None:
        type_start = _exclusive_cumsum(counts)
    else:
        type_start, _ = offsets_fn(counts)
    dest = type_start[jnp.clip(types, 0, n_types - 1)] + rank
    drop = jnp.asarray(P, jnp.int32)
    perm = (
        jnp.full((P,), -1, jnp.int32)
        .at[jnp.where(active, dest, drop)]
        .set(jnp.arange(P, dtype=jnp.int32), mode="drop")
    )
    return perm, counts.astype(jnp.int32)


def trace_tasks_compacted(
    program: Program,
    state: TVMState,
    heap: Dict[str, jnp.ndarray],
    start: jnp.ndarray,
    count: jnp.ndarray,
    cen: jnp.ndarray,
    perm: jnp.ndarray,
    type_offsets: jnp.ndarray,
    type_counts: jnp.ndarray,
    buckets: Tuple[int, ...],
):
    """Phase 2 under the compacted dispatch: dense per-type slices.

    Each task type with a nonzero launch bucket runs over a
    ``lax.dynamic_slice`` of the compaction permutation — a contiguous range
    holding only its own lanes — instead of the full padded NDRange.  Lane
    utilization approaches 1 on heterogeneous epochs; types with zero active
    lanes launch nothing at all.

    The per-lane effects (computed at bucket width ``buckets[tid]``) are
    scattered back to full NDRange lane positions so that
    :func:`commit_epoch` observes exactly the same per-lane layout as the
    masked dispatch — fork allocation order, and therefore every result, is
    bit-identical between the two dispatches.

    Returns ``(per_type, idx, active)`` compatible with :func:`commit_epoch`.
    """
    P = perm.shape[0]
    C = state.capacity
    idx = start + jnp.arange(P, dtype=jnp.int32)
    in_range = jnp.arange(P, dtype=jnp.int32) < count
    cidx = jnp.clip(idx, 0, C - 1)
    # ``cen`` may be per-lane (service multiplexer: each lane carries its own
    # job's epoch number, 0 = lane not in any popped range); the cen>0 guard
    # keeps 0-tagged lanes from matching invalid (epoch 0) slots.
    cen_l = jnp.asarray(cen, jnp.int32)
    active = in_range & (cen_l > 0) & (state.epoch[cidx] == cen_l)
    g_task = state.task[cidx]

    pad = max(buckets) if buckets else 1
    perm_p = jnp.pad(perm, (0, max(pad, 1)), constant_values=-1)

    per_type = []
    for tid, ttype in enumerate(program.tasks):
        B = buckets[tid] if tid < len(buckets) else 0
        if B <= 0:
            continue  # no active lanes of this type: no launch at all
        mask_t = active & (g_task == tid)
        ts = type_offsets[tid]
        lanepos = jax.lax.dynamic_slice(perm_p, (ts,), (B,))
        within = jnp.arange(B, dtype=jnp.int32) < type_counts[tid]
        valid = within & (lanepos >= 0)
        src = jnp.clip(start + lanepos, 0, C - 1)
        lane_fn = _make_lane_fn(program, ttype, heap, state.value)
        eff_small = jax.vmap(lane_fn)(
            state.argi[src], state.argf[src],
            state.child_base[src], state.child_count[src], src,
        )
        # scatter effects back to NDRange lane positions for the shared commit
        pos = jnp.where(valid, lanepos, P)

        def scatter(leaf, _pos=pos):
            out = jnp.zeros((P,) + leaf.shape[1:], leaf.dtype)
            return out.at[_pos].set(leaf, mode="drop")

        eff = jax.tree.map(scatter, eff_small)
        per_type.append((mask_t, eff))
    return per_type, idx, active


def _effects_pytree(program: Program, ctx: EpochCtx):
    """Flatten recorded effects into a fixed pytree (static per task type)."""
    forks = [
        dict(where=f.where, task=f.task, argi=f.argi, argf=f.argf)
        for f in ctx.forks
    ]
    join = None
    if ctx.join_site is not None:
        j = ctx.join_site
        join = dict(where=j.where, task=j.task, argi=j.argi, argf=j.argf)
    writes = [
        dict(index=w.index, value=w.value, where=w.where) for w in ctx.writes
    ]
    maps = [
        dict(where=m.where, argi=m.argi, argf=m.argf) for m in ctx.map_sites
    ]
    meta = dict(
        write_names=tuple(w.name for w in ctx.writes),
        write_ops=tuple(w.op for w in ctx.writes),
        map_ids=tuple(m.map_id for m in ctx.map_sites),
    )
    return dict(
        forks=forks,
        join=join,
        emit_where=ctx.emit_where,
        emit_value=ctx.emit_value,
        writes=writes,
        maps=maps,
        meta=_Static(meta),
    )


class _Static:
    """Wrap static metadata so vmap treats it as an aux leaf."""

    def __init__(self, value):
        self.value = value

    def __eq__(self, other):
        return isinstance(other, _Static) and self.value == other.value

    def __hash__(self):
        return hash(repr(self.value))


jax.tree_util.register_pytree_node(
    _Static, lambda s: ((), s.value), lambda aux, _: _Static(aux)
)


def commit_epoch(
    program: Program,
    state: TVMState,
    heap: Dict[str, jnp.ndarray],
    idx: jnp.ndarray,
    active: jnp.ndarray,
    per_type,
    cen: jnp.ndarray,
    fork_offsets_fn: Optional[Callable] = None,
    seg_offsets_fn: Optional[Callable] = None,
    arena: Optional[JobArena] = None,
) -> Tuple[TVMState, Dict[str, jnp.ndarray], EpochSummary, List[MapLaunch]]:
    """Phase 3: prefix-sum fork allocation + TMS (epoch-number) update.

    ``fork_offsets_fn(counts) -> (excl_offsets, total)`` lets the engine swap
    the jnp cumsum for the ``fork_compact.fork_scan`` Pallas kernel.

    With ``arena`` (the service's multi-tenant mode) the single global
    ``nextFreeCore`` becomes one cursor per job region: every lane is tagged
    with its region index (``arena.slot_job``), fork allocation is a
    *segmented* prefix sum so each job's children stay contiguous inside its
    own region, child scatters are bounded by the region end (an overflowing
    job can never scribble into a neighbour), trailing-invalid reclamation
    (paper §5.3) runs per region, ``cen`` may be a per-lane vector (each
    lane's own job epoch number), and the summary is a
    :class:`MuxEpochSummary` carrying the per-job readback scalars.
    ``seg_offsets_fn(counts, seg, n_segs) -> (excl_offsets, seg_totals)`` is
    the arena counterpart of ``fork_offsets_fn``: it defaults to the jnp
    reference and can be swapped for the ``fork_compact.segmented_fork_scan``
    Pallas kernel (``kernels.ops.segmented_fork_offsets``).  This whole
    function is ``lax.while_loop``-traceable in both modes — the resident
    drivers carry the arena (cursors included) through the loop.
    """
    C = state.capacity
    P = idx.shape[0]
    cidx = jnp.clip(idx, 0, C - 1)

    # ---- per-lane fork counts (disjoint across types) -------------------
    lane_count = jnp.zeros((P,), jnp.int32)
    for mask_t, eff in per_type:
        cnt = jnp.zeros((P,), jnp.int32)
        for f in eff["forks"]:
            cnt = cnt + f["where"].astype(jnp.int32)
        lane_count = lane_count + jnp.where(mask_t, cnt, 0)

    lane_cap = None  # per-lane scatter bound (arena mode only)
    if arena is None:
        if fork_offsets_fn is None:
            lane_excl = _exclusive_cumsum(lane_count)
            total_forks = lane_count.sum().astype(jnp.int32)
        else:
            lane_excl, total_forks = fork_offsets_fn(lane_count)
        lane_base = state.next_free + lane_excl
        overflow = (state.next_free + total_forks) > C
    else:
        J = arena.n_jobs
        jl = jnp.clip(arena.slot_job[cidx], 0, J - 1)  # region per lane
        # segmented exclusive scan: each lane's offset among *its own job's*
        # forks — identical to the solo cumsum restricted to that region
        if seg_offsets_fn is None:
            from ..kernels import ref as _kref

            lane_excl, job_forks = _kref.segmented_fork_scan_ref(
                lane_count, jl, J
            )
        else:
            lane_excl, job_forks = seg_offsets_fn(lane_count, jl, J)
        job_forks = job_forks.astype(jnp.int32)
        lane_base = arena.next[jl] + lane_excl
        lane_cap = arena.end[jl]
        job_overflow = (arena.next + job_forks) > arena.end
        total_forks = job_forks.sum().astype(jnp.int32)
        overflow = job_overflow.any()

    new_task = state.task
    new_argi = state.argi
    new_argf = state.argf
    new_epoch = state.epoch
    new_value = state.value
    new_cb = state.child_base
    new_cc = state.child_count

    join_any = jnp.asarray(False)
    lane_join = jnp.zeros((P,), bool)
    map_any = jnp.asarray(False)
    map_launches: List[MapLaunch] = []
    drop = C  # out-of-range slot => dropped scatter

    for mask_t, eff in per_type:
        # -------- forks: scatter children at contiguous prefix-sum slots
        within = jnp.zeros((P,), jnp.int32)
        for f in eff["forks"]:
            fire = mask_t & f["where"]
            raw = lane_base + within
            if lane_cap is not None:
                fire = fire & (raw < lane_cap)
            slots = jnp.where(fire, raw, drop)
            new_task = new_task.at[slots].set(f["task"], mode="drop")
            new_argi = new_argi.at[slots].set(f["argi"], mode="drop")
            new_argf = new_argf.at[slots].set(f["argf"], mode="drop")
            new_epoch = new_epoch.at[slots].set(cen + 1, mode="drop")
            new_cb = new_cb.at[slots].set(0, mode="drop")
            new_cc = new_cc.at[slots].set(0, mode="drop")
            within = within + fire.astype(jnp.int32)

        # -------- join: replace own entry; epoch number stays CEN
        jw = jnp.zeros((P,), bool)
        if eff["join"] is not None:
            j = eff["join"]
            jw = mask_t & j["where"]
            jslots = jnp.where(jw, cidx, drop)
            new_task = new_task.at[jslots].set(j["task"], mode="drop")
            new_argi = new_argi.at[jslots].set(j["argi"], mode="drop")
            new_argf = new_argf.at[jslots].set(j["argf"], mode="drop")
            join_any = jnp.logical_or(join_any, jw.any())
            lane_join = lane_join | jw

        # -------- record children pointers on the (possibly joined) parent
        pslots = jnp.where(mask_t, cidx, drop)
        new_cb = new_cb.at[pslots].set(lane_base, mode="drop")
        new_cc = new_cc.at[pslots].set(lane_count, mode="drop")

        # -------- emit: store value; entry becomes invalid unless joined
        ew = mask_t & eff["emit_where"]
        eslots = jnp.where(ew, cidx, drop)
        new_value = new_value.at[eslots].set(eff["emit_value"], mode="drop")
        done = mask_t & jnp.logical_not(jw)
        dslots = jnp.where(done, cidx, drop)
        new_epoch = new_epoch.at[dslots].set(0, mode="drop")

        # -------- heap writes (reads saw the pre-epoch snapshot)
        meta = eff["meta"].value
        for w, name, op in zip(
            eff["writes"], meta["write_names"], meta["write_ops"]
        ):
            fire = mask_t & w["where"]
            arr = heap[name]
            n = arr.shape[0]
            widx = jnp.where(fire, jnp.clip(w["index"], 0, n - 1), n)
            if op == "set":
                arr = arr.at[widx].set(w["value"], mode="drop")
            elif op == "add":
                arr = arr.at[widx].add(w["value"], mode="drop")
            elif op == "min":
                arr = arr.at[widx].min(w["value"], mode="drop")
            elif op == "max":
                arr = arr.at[widx].max(w["value"], mode="drop")
            heap = dict(heap, **{name: arr})

        # -------- map scheduling
        for m, mid in zip(eff["maps"], meta["map_ids"]):
            fire = mask_t & m["where"]
            map_any = jnp.logical_or(map_any, fire.any())
            map_launches.append(
                MapLaunch(map_id=mid, where=fire, argi=m["argi"], argf=m["argf"])
            )

    # ---- trailing-invalid reclamation (paper §5.3, nextFreeCore decrease)
    iota = jnp.arange(C, dtype=jnp.int32)
    valid = new_epoch > 0
    if arena is None:
        next_free = state.next_free + total_forks
        last_valid = jnp.max(jnp.where(valid, iota, -1))
        next_free = jnp.minimum(next_free, last_valid + 1).astype(jnp.int32)
        summary = EpochSummary(
            total_forks=total_forks,
            join_scheduled=join_any,
            map_scheduled=map_any,
            n_active=active.sum().astype(jnp.int32),
            overflow=overflow,
        )
    else:
        # per-region reclamation: each cursor shrinks to just past its own
        # region's last valid slot, exactly the solo rule shifted by base
        last_valid = jax.ops.segment_max(
            jnp.where(valid, iota, -1), arena.slot_job, num_segments=J + 1
        )[:J]
        job_next = jnp.minimum(
            arena.next + job_forks, jnp.maximum(last_valid + 1, arena.base)
        ).astype(jnp.int32)
        next_free = jnp.max(job_next).astype(jnp.int32)  # fleet high-water
        summary = MuxEpochSummary(
            total_forks=total_forks,
            join_scheduled=join_any,
            map_scheduled=map_any,
            n_active=active.sum().astype(jnp.int32),
            overflow=overflow,
            job_forks=job_forks,
            job_join=jax.ops.segment_max(
                lane_join.astype(jnp.int32), jl, num_segments=J
            ) > 0,
            job_active=jax.ops.segment_sum(
                active.astype(jnp.int32), jl, num_segments=J
            ).astype(jnp.int32),
            job_overflow=job_overflow,
            job_next=job_next,
        )

    new_state = TVMState(
        task=new_task,
        argi=new_argi,
        argf=new_argf,
        epoch=new_epoch,
        value=new_value,
        child_base=new_cb,
        child_count=new_cc,
        next_free=next_free,
    )
    return new_state, heap, summary, map_launches


def run_map_payload(
    program: Program,
    heap: Dict[str, jnp.ndarray],
    map_id: int,
    where: jnp.ndarray,
    argi: jnp.ndarray,
    argf: jnp.ndarray,
    domain_size: int,
) -> Dict[str, jnp.ndarray]:
    """Execute one map site's payload over lanes x dense element domain.

    The paper launches these as a separate data-parallel kernel between
    epochs (§5.2.4); here it is one vectorized masked op.
    """
    mt = program.maps[map_id]
    dom = mt.domain(argi).astype(jnp.int32)  # i32[P]

    def elem_fn(ai, af, lane_on, lane_dom, eid):
        ctx = MapCtx(program, ai, af, eid, heap)
        mt.fn(ctx)
        fire = lane_on & (eid < lane_dom)
        return [
            dict(index=w.index, value=w.value, where=fire & w.where,
                 name=_Static(w.name), op=_Static(w.op))
            for w in ctx.writes
        ]

    eids = jnp.arange(domain_size, dtype=jnp.int32)
    writes = jax.vmap(
        jax.vmap(elem_fn, in_axes=(None, None, None, None, 0)),
        in_axes=(0, 0, 0, 0, None),
    )(argi, argf, where, dom, eids)

    for w in writes:
        name = w["name"].value
        op = w["op"].value
        arr = heap[name]
        n = arr.shape[0]
        widx = jnp.where(w["where"], jnp.clip(w["index"], 0, n - 1), n)
        flat_idx = widx.reshape(-1)
        flat_val = w["value"].reshape((-1,) + arr.shape[1:])
        if op == "set":
            arr = arr.at[flat_idx].set(flat_val, mode="drop")
        elif op == "add":
            arr = arr.at[flat_idx].add(flat_val, mode="drop")
        elif op == "min":
            arr = arr.at[flat_idx].min(flat_val, mode="drop")
        elif op == "max":
            arr = arr.at[flat_idx].max(flat_val, mode="drop")
        heap = dict(heap, **{name: arr})
    return heap
