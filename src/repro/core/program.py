"""Task-program definition for the TVM / TREES runtime.

A *program* is a set of task functions written against the :class:`EpochCtx`
effect API (see ``primitives.py``).  Task functions are written **per lane**
(one TVM core) using jnp scalar ops; the engine vmaps them across the Task
Vector so that every task *type* executes as one dense, masked vector
operation — the TPU analogue of the paper's SIMT "work-together" execution.

Key restrictions (they are what make bulk epoch execution possible):
  * task bodies are straight-line jnp code; data-dependent branching is
    expressed with ``where=`` predicates on the effect calls (fork/join/emit/
    map/write), never Python ``if`` on traced values;
  * each task type has a *static* number of fork sites / write sites; which
    ones actually fire is decided by the predicates;
  * integer args live in ``argi`` (i32), float args in ``argf`` (f32); emitted
    values are a fixed-width vector of the program's ``value_dtype``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import types
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


_MAX_DEPTH = 16  # reference hops before fingerprints truncate to <deep>


def _code_fingerprint(code, g: Dict[str, Any], h, seen, depth: int) -> None:
    """Fingerprint a code object against globals namespace ``g``: bytecode,
    constants (nested code objects recurse against the *same* globals — an
    inner ``def`` resolves module names through its parent's namespace),
    referenced names, and the resolved values of those names."""
    if depth > _MAX_DEPTH:
        h.update(b"<deep>")
        return
    h.update(b"code")
    h.update(code.co_code)
    for c in code.co_consts:
        if isinstance(c, types.CodeType):
            _code_fingerprint(c, g, h, seen, depth + 1)
        else:
            _fingerprint(c, h, seen, depth + 1)
    h.update(repr(code.co_names).encode())
    for name in code.co_names:
        if name in g:
            h.update(name.encode())
            _fingerprint(g[name], h, seen, depth + 1)


def _fingerprint(obj: Any, h, seen: Dict[int, int], depth: int = 0) -> None:
    """Feed a stable structural fingerprint of ``obj`` into hash ``h``.

    Functions fingerprint as bytecode + constants + captured closure values
    + the resolved globals they reference (recursing into helper functions),
    never as object identity — so two functions built independently by the
    same construction path fingerprint equal.  Arrays fingerprint by dtype/
    shape/bytes.  Depth-bounded and cycle-safe: a function met again hashes
    as its *position* in the walk (`<ref:N>`), not a constant token, so two
    programs that reference different already-hashed helpers still differ.
    The depth bound is conservative collision territory: programs differing
    only beyond ``_MAX_DEPTH`` reference hops hash equal — keep task bodies
    shallower than that (every app in this repo is < 5 hops deep).
    """
    if depth > _MAX_DEPTH:
        h.update(b"<deep>")
        return
    if isinstance(obj, types.FunctionType):
        if id(obj) in seen:
            h.update(f"<ref:{seen[id(obj)]}>".encode())
            return
        seen[id(obj)] = len(seen)
        h.update(b"fn")
        _code_fingerprint(obj.__code__, obj.__globals__, h, seen, depth)
        for cell in obj.__closure__ or ():
            try:
                _fingerprint(cell.cell_contents, h, seen, depth + 1)
            except ValueError:  # empty cell
                h.update(b"<empty-cell>")
        for d in obj.__defaults__ or ():
            _fingerprint(d, h, seen, depth + 1)
        for k in sorted(obj.__kwdefaults__ or {}):
            h.update(k.encode())
            _fingerprint(obj.__kwdefaults__[k], h, seen, depth + 1)
        return
    if isinstance(obj, types.CodeType):
        # a bare code object with no owning function: no globals namespace
        # to resolve against
        _code_fingerprint(obj, {}, h, seen, depth)
        return
    if isinstance(obj, (np.ndarray, jnp.ndarray)):
        arr = np.asarray(obj)
        h.update(f"arr{arr.dtype}{arr.shape}".encode())
        h.update(arr.tobytes())
        return
    if isinstance(obj, (tuple, list)):
        h.update(f"seq{len(obj)}".encode())
        for x in obj:
            _fingerprint(x, h, seen, depth + 1)
        return
    if isinstance(obj, (set, frozenset)):
        h.update(f"set{len(obj)}".encode())
        for x in sorted(obj, key=repr):
            _fingerprint(x, h, seen, depth + 1)
        return
    if isinstance(obj, dict):
        h.update(f"map{len(obj)}".encode())
        for k in sorted(obj, key=repr):
            h.update(repr(k).encode())
            _fingerprint(obj[k], h, seen, depth + 1)
        return
    if isinstance(obj, types.ModuleType):
        h.update(f"mod:{obj.__name__}".encode())
        return
    if isinstance(obj, types.MethodType):
        h.update(b"method")
        _fingerprint(obj.__func__, h, seen, depth + 1)
        _fingerprint(obj.__self__, h, seen, depth + 1)
        return
    if obj is None or isinstance(
        obj, (bool, int, float, complex, str, bytes, np.generic)
    ):
        h.update(repr(obj).encode())
        return
    # other object (jnp dtypes, partials, callable class instances, ...):
    # fingerprint by qualified type name — never by identity/address — plus
    # whatever state is inspectable: partial internals, the instance dict,
    # and a class __call__'s code (a callable instance is a task fn too)
    t = type(obj)
    h.update(f"<{t.__module__}.{t.__qualname__}>".encode())
    fn = getattr(obj, "func", None)  # functools.partial and friends
    if callable(fn):
        _fingerprint(fn, h, seen, depth + 1)
        _fingerprint(getattr(obj, "args", ()), h, seen, depth + 1)
        _fingerprint(getattr(obj, "keywords", {}) or {}, h, seen, depth + 1)
        return
    inst = getattr(obj, "__dict__", None)
    if isinstance(inst, dict) and inst:
        _fingerprint(inst, h, seen, depth + 1)
    call = getattr(t, "__call__", None)
    if isinstance(call, types.FunctionType):
        _fingerprint(call, h, seen, depth + 1)


TaskFn = Callable[["EpochCtx"], None]  # noqa: F821  (EpochCtx in primitives)
MapFn = Callable[["MapCtx"], None]  # noqa: F821


@dataclasses.dataclass(frozen=True)
class TaskType:
    """One entry in the program's task-function table."""

    name: str
    fn: TaskFn


@dataclasses.dataclass(frozen=True)
class MapType:
    """A data-parallel ``map`` payload (paper §4.2).

    ``domain`` maps the scheduling task's integer args to the number of
    data-parallel elements the payload covers.  The host engine sizes the
    payload launch from it (the analogue of the paper's separately launched
    map kernel NDRange); the device engine uses ``max_domain``.
    """

    name: str
    fn: MapFn
    domain: Callable[[np.ndarray], int]
    max_domain: int = 0


@dataclasses.dataclass(frozen=True)
class HeapVar:
    """A named global array tasks may read (gather) and write (scatter)."""

    name: str
    shape: tuple
    dtype: Any


@dataclasses.dataclass(frozen=True)
class Program:
    """A TVM task-parallel program.

    Attributes:
      name: program name (used in benchmarks / stats).
      tasks: task-function table; the task *type id* is the index here.
      n_arg_i / n_arg_f: width of the integer / float argument registers.
      value_width / value_dtype: shape of the per-task ``emit`` value.
      maps: optional table of data-parallel map payloads.
      heap: declarations of the global arrays.
    """

    name: str
    tasks: Sequence[TaskType]
    n_arg_i: int = 2
    n_arg_f: int = 0
    value_width: int = 1
    value_dtype: Any = jnp.int32
    maps: Sequence[MapType] = ()
    heap: Sequence[HeapVar] = ()

    def structural_hash(self) -> str:
        """Hash of the program's *structure*, ignoring its display name.

        Covers the task/map/heap tables (names, order), register widths,
        value shape/dtype, and the structural fingerprint of every task,
        map, and domain function (bytecode + captured constants, see
        :func:`_fingerprint`) — everything that determines the phase-2
        trace.  Two programs built independently by the same construction
        path hash equal, so the job service can reseed a freed TV region
        with any same-shape tenant instead of demanding the identical
        ``Program`` object.  Cached after the first call.
        """
        cached = getattr(self, "_structural_hash_cache", None)
        if cached is not None:
            return cached
        h = hashlib.sha256()
        seen: Dict[int, int] = {}
        h.update(
            f"w{self.n_arg_i},{self.n_arg_f},{self.value_width},"
            f"{jnp.dtype(self.value_dtype)}".encode()
        )
        for t in self.tasks:
            h.update(f"task:{t.name}".encode())
            _fingerprint(t.fn, h, seen)
        for m in self.maps:
            h.update(f"map:{m.name},{m.max_domain}".encode())
            _fingerprint(m.fn, h, seen)
            _fingerprint(m.domain, h, seen)
        for hv in self.heap:
            h.update(
                f"heap:{hv.name},{tuple(hv.shape)},{jnp.dtype(hv.dtype)}"
                .encode()
            )
        digest = h.hexdigest()
        object.__setattr__(self, "_structural_hash_cache", digest)
        return digest

    def task_id(self, name: str) -> int:
        for i, t in enumerate(self.tasks):
            if t.name == name:
                return i
        raise KeyError(name)

    def map_id(self, name: str) -> int:
        for i, m in enumerate(self.maps):
            if m.name == name:
                return i
        raise KeyError(name)

    def init_heap(self, **overrides: Any) -> Dict[str, jnp.ndarray]:
        out: Dict[str, jnp.ndarray] = {}
        for hv in self.heap:
            if hv.name in overrides:
                arr = jnp.asarray(overrides[hv.name], dtype=hv.dtype)
                if arr.shape != tuple(hv.shape):
                    raise ValueError(
                        f"heap var {hv.name}: expected shape {hv.shape}, got {arr.shape}"
                    )
            else:
                arr = jnp.zeros(hv.shape, dtype=hv.dtype)
            out[hv.name] = arr
        unknown = set(overrides) - {hv.name for hv in self.heap}
        if unknown:
            raise KeyError(f"unknown heap overrides: {sorted(unknown)}")
        return out


@dataclasses.dataclass(frozen=True)
class InitialTask:
    """The seed task placed in TV slot 0 (paper §4.3: initial state)."""

    task: str
    argi: Sequence[int] = ()
    argf: Sequence[float] = ()


def pack_args(program: Program, argi: Sequence[int], argf: Sequence[float]):
    ai = np.zeros(program.n_arg_i, np.int32)
    ai[: len(argi)] = list(argi)
    af = np.zeros(program.n_arg_f, np.float32)
    af[: len(argf)] = list(argf)
    return ai, af
