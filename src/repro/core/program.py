"""Task-program definition for the TVM / TREES runtime.

A *program* is a set of task functions written against the :class:`EpochCtx`
effect API (see ``primitives.py``).  Task functions are written **per lane**
(one TVM core) using jnp scalar ops; the engine vmaps them across the Task
Vector so that every task *type* executes as one dense, masked vector
operation — the TPU analogue of the paper's SIMT "work-together" execution.

Key restrictions (they are what make bulk epoch execution possible):
  * task bodies are straight-line jnp code; data-dependent branching is
    expressed with ``where=`` predicates on the effect calls (fork/join/emit/
    map/write), never Python ``if`` on traced values;
  * each task type has a *static* number of fork sites / write sites; which
    ones actually fire is decided by the predicates;
  * integer args live in ``argi`` (i32), float args in ``argf`` (f32); emitted
    values are a fixed-width vector of the program's ``value_dtype``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

TaskFn = Callable[["EpochCtx"], None]  # noqa: F821  (EpochCtx in primitives)
MapFn = Callable[["MapCtx"], None]  # noqa: F821


@dataclasses.dataclass(frozen=True)
class TaskType:
    """One entry in the program's task-function table."""

    name: str
    fn: TaskFn


@dataclasses.dataclass(frozen=True)
class MapType:
    """A data-parallel ``map`` payload (paper §4.2).

    ``domain`` maps the scheduling task's integer args to the number of
    data-parallel elements the payload covers.  The host engine sizes the
    payload launch from it (the analogue of the paper's separately launched
    map kernel NDRange); the device engine uses ``max_domain``.
    """

    name: str
    fn: MapFn
    domain: Callable[[np.ndarray], int]
    max_domain: int = 0


@dataclasses.dataclass(frozen=True)
class HeapVar:
    """A named global array tasks may read (gather) and write (scatter)."""

    name: str
    shape: tuple
    dtype: Any


@dataclasses.dataclass(frozen=True)
class Program:
    """A TVM task-parallel program.

    Attributes:
      name: program name (used in benchmarks / stats).
      tasks: task-function table; the task *type id* is the index here.
      n_arg_i / n_arg_f: width of the integer / float argument registers.
      value_width / value_dtype: shape of the per-task ``emit`` value.
      maps: optional table of data-parallel map payloads.
      heap: declarations of the global arrays.
    """

    name: str
    tasks: Sequence[TaskType]
    n_arg_i: int = 2
    n_arg_f: int = 0
    value_width: int = 1
    value_dtype: Any = jnp.int32
    maps: Sequence[MapType] = ()
    heap: Sequence[HeapVar] = ()

    def task_id(self, name: str) -> int:
        for i, t in enumerate(self.tasks):
            if t.name == name:
                return i
        raise KeyError(name)

    def map_id(self, name: str) -> int:
        for i, m in enumerate(self.maps):
            if m.name == name:
                return i
        raise KeyError(name)

    def init_heap(self, **overrides: Any) -> Dict[str, jnp.ndarray]:
        out: Dict[str, jnp.ndarray] = {}
        for hv in self.heap:
            if hv.name in overrides:
                arr = jnp.asarray(overrides[hv.name], dtype=hv.dtype)
                if arr.shape != tuple(hv.shape):
                    raise ValueError(
                        f"heap var {hv.name}: expected shape {hv.shape}, got {arr.shape}"
                    )
            else:
                arr = jnp.zeros(hv.shape, dtype=hv.dtype)
            out[hv.name] = arr
        unknown = set(overrides) - {hv.name for hv in self.heap}
        if unknown:
            raise KeyError(f"unknown heap overrides: {sorted(unknown)}")
        return out


@dataclasses.dataclass(frozen=True)
class InitialTask:
    """The seed task placed in TV slot 0 (paper §4.3: initial state)."""

    task: str
    argi: Sequence[int] = ()
    argf: Sequence[float] = ()


def pack_args(program: Program, argi: Sequence[int], argf: Sequence[float]):
    ai = np.zeros(program.n_arg_i, np.int32)
    ai[: len(argi)] = list(argi)
    af = np.zeros(program.n_arg_f, np.float32)
    af[: len(argf)] = list(argf)
    return ai, af
