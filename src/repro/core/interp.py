"""Sequential reference interpreter for TVM programs (the runtime's oracle).

Implements the abstract TVM of paper §4 directly with Python lists and
numpy scalars — no vectorization, no padding, no buckets — and runs the very
same task functions through the same ``EpochCtx`` effect API, one lane at a
time.  The vectorized engines must produce identical heaps and identical
emitted values; hypothesis property tests drive both on random programs.

It also returns the *ideal* work/critical-path numbers (T1 = total tasks,
T_inf = number of epochs), which ``analysis.py`` compares against engine
stats to isolate the runtime overheads V1 / V_inf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .primitives import EpochCtx, MapCtx
from .program import InitialTask, Program, pack_args


@dataclasses.dataclass
class OracleStats:
    epochs: int = 0          # T_inf in epochs
    tasks_executed: int = 0  # T_1 in tasks
    total_forks: int = 0
    map_elements: int = 0
    peak_tv_slots: int = 0


def run_oracle(
    program: Program,
    initial: InitialTask,
    heap_init: Optional[Dict[str, Any]] = None,
    capacity: int = 1 << 14,
    max_epochs: int = 1 << 20,
) -> Tuple[Dict[str, np.ndarray], np.ndarray, OracleStats]:
    """Run the TVM semantics sequentially; returns (heap, values, stats)."""
    import jax.numpy as jnp

    heap_j = program.init_heap(**(heap_init or {}))
    heap = {k: np.asarray(v).copy() for k, v in heap_j.items()}

    task = np.zeros(capacity, np.int64)
    argi = np.zeros((capacity, program.n_arg_i), np.int64)
    argf = np.zeros((capacity, program.n_arg_f), np.float64)
    epoch = np.zeros(capacity, np.int64)
    value = np.zeros(
        (capacity, program.value_width),
        np.asarray(jnp.zeros((), program.value_dtype)).dtype,
    )
    child_base = np.zeros(capacity, np.int64)
    child_count = np.zeros(capacity, np.int64)

    ai, af = pack_args(program, initial.argi, initial.argf)
    task[0] = program.task_id(initial.task)
    argi[0] = ai
    argf[0] = af
    epoch[0] = 1
    next_free = 1

    join_stack = [1]
    range_stack = [(0, 1)]
    stats = OracleStats(peak_tv_slots=1)

    while join_stack:
        if stats.epochs >= max_epochs:
            raise RuntimeError("oracle exceeded max_epochs")
        cen = join_stack.pop()
        start, count = range_stack.pop()
        stats.epochs += 1

        # ---- phase 2: execute each active lane sequentially -------------
        effects = []
        for slot in range(start, start + count):
            if epoch[slot] != cen:
                continue
            ctx = EpochCtx(
                program,
                np.int32(argi[slot]),
                np.float32(argf[slot]),
                int(child_base[slot]),
                int(child_count[slot]),
                slot,
                {k: v.copy() for k, v in heap.items()},  # pre-epoch snapshot
                value.copy(),
            )
            program.tasks[int(task[slot])].fn(ctx)
            effects.append((slot, ctx))
            stats.tasks_executed += 1

        # ---- phase 3: commit in slot order ------------------------------
        old_next_free = next_free
        join_sched = False
        map_calls: List[Tuple[int, np.ndarray, np.ndarray]] = []
        heap_writes = []
        for slot, ctx in effects:
            my_children = 0
            for f in ctx.forks:
                if not bool(f.where):
                    continue
                s = next_free
                if s >= capacity:
                    raise RuntimeError("oracle TV overflow")
                task[s] = int(f.task)
                argi[s] = np.asarray(f.argi)
                argf[s] = np.asarray(f.argf)
                epoch[s] = cen + 1
                child_base[s] = 0
                child_count[s] = 0
                next_free += 1
                my_children += 1
                stats.total_forks += 1
            base = next_free - my_children
            child_base[slot] = base
            child_count[slot] = my_children
            joined = ctx.join_site is not None and bool(ctx.join_site.where)
            if joined:
                j = ctx.join_site
                task[slot] = int(j.task)
                argi[slot] = np.asarray(j.argi)
                argf[slot] = np.asarray(j.argf)
                join_sched = True
            if bool(ctx.emit_where):
                value[slot] = np.asarray(ctx.emit_value)
            if not joined:
                epoch[slot] = 0
            for w in ctx.writes:
                heap_writes.append(w)
            for m in ctx.map_sites:
                if bool(m.where):
                    map_calls.append(
                        (m.map_id, np.asarray(m.argi), np.asarray(m.argf))
                    )

        for w in heap_writes:
            if not bool(w.where):
                continue
            arr = heap[w.name]
            i = int(np.clip(int(w.index), 0, arr.shape[0] - 1))
            v = np.asarray(w.value)
            if w.op == "set":
                arr[i] = v
            elif w.op == "add":
                arr[i] = arr[i] + v
            elif w.op == "min":
                arr[i] = np.minimum(arr[i], v)
            elif w.op == "max":
                arr[i] = np.maximum(arr[i], v)

        # ---- map payloads (between epochs, paper §5.2.4) -----------------
        for mid, mai, maf in map_calls:
            mt = program.maps[mid]
            dom = int(np.asarray(mt.domain(mai[None, :]))[0])
            snapshot = {k: v.copy() for k, v in heap.items()}
            writes = []
            for eid in range(dom):
                mctx = MapCtx(
                    program, np.int32(mai), np.float32(maf), eid, snapshot
                )
                mt.fn(mctx)
                writes.extend(mctx.writes)
                stats.map_elements += 1
            for w in writes:
                if not bool(w.where):
                    continue
                arr = heap[w.name]
                i = int(np.clip(int(w.index), 0, arr.shape[0] - 1))
                v = np.asarray(w.value)
                if w.op == "set":
                    arr[i] = v
                elif w.op == "add":
                    arr[i] = arr[i] + v
                elif w.op == "min":
                    arr[i] = np.minimum(arr[i], v)
                elif w.op == "max":
                    arr[i] = np.maximum(arr[i], v)

        # ---- TMS update ---------------------------------------------------
        if join_sched:
            join_stack.append(cen)
            range_stack.append((start, count))
        if next_free > old_next_free:
            join_stack.append(cen + 1)
            range_stack.append((old_next_free, next_free - old_next_free))
        stats.peak_tv_slots = max(stats.peak_tv_slots, next_free)
        # trailing-invalid reclamation
        valid = np.nonzero(epoch > 0)[0]
        next_free = int(valid[-1]) + 1 if valid.size else 0

    return heap, value, stats
