"""Epoch-synchronized serving engine: the TVM applied to LLM serving.

The mapping to the paper's machine (§4) is exact:

  TV slot          <-> request slot (fixed batch position + its KV cache)
  task type        <-> {prefill, decode}
  fork             <-> admitting a request's first decode task (prefill
                       forks the decode chain); each decode forks its
                       successor until EOS/max_tokens
  emit             <-> completing a request (slot contents retired)
  epoch (phase 2)  <-> one bulk ``decode_step`` over *all* active slots —
                       work-together: every active task executes in one
                       dispatch, load-balanced by the batch dimension
  nextFreeCore     <-> free-slot allocation by prefix sum over the free
                       mask (kernels/fork_compact machinery; no atomics)
  phase 1/3 (CPU)  <-> admission + retirement bookkeeping on the host

Prefills are batched per epoch (bucketed padding) and their caches are
scattered into the slots they were allocated — the analogue of the paper's
coalesced TV writes at fork time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..models.common import ModelConfig
from ..models.model import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (len,) i32
    max_new_tokens: int = 32
    eos: Optional[int] = None
    # filled by the engine
    rid: int = -1
    output: Optional[List[int]] = None


def _bucket(n: int, minimum: int = 8) -> int:
    p = minimum
    while p < n:
        p *= 2
    return p


class EpochServer:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Dict[str, jnp.ndarray],
        n_slots: int = 8,
        max_len: int = 256,
        enc_frames: Optional[jnp.ndarray] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = init_cache(cfg, n_slots, max_len)
        self._enc_frames = enc_frames
        if cfg.encdec:
            assert enc_frames is not None
            from ..models.model import build_cross_cache, encode

            self.cache["enc_out"] = jnp.broadcast_to(
                encode(params, cfg, enc_frames[:1]),
                (n_slots, cfg.encoder_len, cfg.d_model),
            ).astype(cfg.compute_dtype)
            ck, cv = build_cross_cache(params, cfg, self.cache["enc_out"])
            self.cache["cross_k"] = ck.astype(cfg.compute_dtype)
            self.cache["cross_v"] = cv.astype(cfg.compute_dtype)
        # host-side TV bookkeeping (paper phase 1/3 state)
        self.active = np.zeros(n_slots, bool)
        self.remaining = np.zeros(n_slots, np.int64)
        self.last_token = np.zeros(n_slots, np.int64)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.epochs = 0
        self._rid = 0
        self._decode = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c)
        )
        self._prefill_cache: Dict[int, object] = {}

    # ----------------------------------------------------------- frontend
    def submit(self, req: Request) -> int:
        req.rid = self._rid
        req.output = []
        self._rid += 1
        self.queue.append(req)
        return req.rid

    # ----------------------------------------------------- fork: admission
    def _admit(self):
        """Allocate free slots to queued requests by prefix sum (fork)."""
        free = ~self.active
        n_free = int(free.sum())
        n_new = min(n_free, len(self.queue))
        if n_new == 0:
            return
        # prefix-sum slot allocation: contiguous ranks over the free mask —
        # the same cooperative allocation the engine/kernels use (no atomics)
        offsets, _ = kops.fork_offsets(jnp.asarray(free, jnp.int32))
        rank = np.asarray(offsets)
        slots = np.nonzero(free & (rank < n_new))[0]
        reqs = [self.queue.pop(0) for _ in range(n_new)]

        # bulk prefill at a bucketed length (one epoch-style dispatch)
        plens = [len(r.prompt) for r in reqs]
        Lp = _bucket(max(plens))
        toks = np.zeros((n_new, Lp), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : len(r.prompt)] = r.prompt  # right-pad: ragged prompts
        pf_key = (n_new, Lp)
        ef = None
        if self.cfg.encdec:
            ef = jnp.broadcast_to(
                self._enc_frames[:1],
                (n_new,) + tuple(self._enc_frames.shape[1:]),
            )
        if pf_key not in self._prefill_cache:
            cfg = self.cfg
            self._prefill_cache[pf_key] = jax.jit(
                lambda p, t, lp, ef_: prefill(
                    p, cfg, t, max_len=self.max_len, last_positions=lp,
                    enc_frames=ef_,
                )
            )
        logits, new_cache = self._prefill_cache[pf_key](
            self.params, jnp.asarray(toks),
            jnp.asarray(np.asarray(plens, np.int32) - 1), ef,
        )
        next_tok = np.asarray(jnp.argmax(logits, -1))

        # scatter the prefilled caches into the allocated slots (coalesced
        # TV write at fork time)
        sl = jnp.asarray(slots)
        for key in ("k", "v", "ssm_state", "ssm_conv"):
            if key in self.cache and key in new_cache:
                self.cache[key] = self.cache[key].at[:, sl].set(
                    new_cache[key].astype(self.cache[key].dtype)
                )
        self.cache["lengths"] = self.cache["lengths"].at[sl].set(
            jnp.asarray(plens, jnp.int32)
        )
        for i, r in enumerate(reqs):
            s = slots[i]
            self.active[s] = True
            self.remaining[s] = r.max_new_tokens
            self.last_token[s] = next_tok[i]
            self.slot_req[s] = r
            r.output.append(int(next_tok[i]))

    # ------------------------------------------------------------- epochs
    def step(self):
        """One serving epoch: phase 1 admit, phase 2 bulk decode, phase 3
        retire (the paper's three-phase structure)."""
        self._admit()
        if not self.active.any():
            return False
        toks = jnp.asarray(self.last_token[:, None].astype(np.int32))
        logits, self.cache = self._decode(self.params, toks, self.cache)
        self.epochs += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in range(self.n_slots):
            if not self.active[s]:
                continue
            r = self.slot_req[s]
            self.remaining[s] -= 1
            tok = int(nxt[s])
            done = self.remaining[s] <= 0 or (
                r.eos is not None and tok == r.eos
            )
            if not done:
                r.output.append(tok)
                self.last_token[s] = tok
            if done:
                # emit: retire the slot (entry invalid; reclaimed by admit)
                self.active[s] = False
                self.slot_req[s] = None
                self.completed.append(r)
        return True

    def run_to_completion(self, max_epochs: int = 10_000):
        while (self.queue or self.active.any()) and self.epochs < max_epochs:
            self.step()
        return self.completed
