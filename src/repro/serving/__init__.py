# Serving substrate: epoch-synchronized continuous batching — the TVM's
# task vector realized as request slots (DESIGN.md §3).
from .engine import EpochServer, Request  # noqa: F401
