# Checkpointing substrate: atomic on-disk checkpoints (keep-k, async write
# thread, exact-resume manifests) and elastic resharding across meshes.
from .manager import CheckpointManager  # noqa: F401
from .reshard import restore_resharded  # noqa: F401
