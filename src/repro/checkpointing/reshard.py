"""Elastic resharding: restore a checkpoint written under one mesh onto a
*different* mesh (fewer/more pods after failure or scale-up).

Checkpoints are stored unsharded-on-disk (full arrays), so resharding is a
device_put with the new mesh's NamedShardings — the elastic-scaling path of
runtime/elastic.py.  At 1000+ node scale the same layout works per-host with
a sharded npz per data-parallel group; the manifest records enough to stitch
(see DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding

from ..models.common import ShardingRules, logical_to_physical
from .manager import CheckpointManager


def restore_resharded(
    mgr: CheckpointManager,
    axes: Dict[str, tuple],
    mesh,
    rules: ShardingRules,
    step: Optional[int] = None,
):
    """Restore a params dict onto ``mesh`` using logical->physical rules."""
    step, flat, extra = mgr.restore_flat(step)
    out = {}
    for name, arr in flat.items():
        if name in axes:
            spec = logical_to_physical(axes[name], rules)
            out[name] = jax.device_put(arr, NamedSharding(mesh, spec))
        else:
            out[name] = jax.device_put(arr)
    return step, out, extra
