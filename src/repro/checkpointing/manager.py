"""Atomic, async, keep-k checkpointing in pure numpy — no orbax dependency.

Layout:
  <dir>/step_0000100.tmp-<nonce>/   (written fully, then atomically renamed)
  <dir>/step_0000100/
      manifest.json   {step, keys, shapes, dtypes, extra}
      arrays.npz      flat name->array
Atomic rename is the crash-consistency boundary: a partially written
checkpoint can never be picked up by ``latest_step``.  Writes can run on a
background thread (``async_save``) so the train loop overlaps checkpoint I/O
with compute — the paper's "pay critical-path overheads in bulk" applied to
checkpointing.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _key_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    if isinstance(p, jax.tree_util.FlattenedIndexKey):
        return str(p.key)
    return str(p)


def _path_key(path) -> str:
    return "/".join(_key_str(p) for p in path)


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    """Flatten any pytree (dicts, tuples, registered dataclasses like
    OptState) into name->numpy with stable keypath names."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {
        _path_key(path): np.asarray(jax.device_get(leaf))
        for path, leaf in leaves
    }


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep: int = 3,
        async_save: bool = False,
    ):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- write
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Checkpoint ``tree`` at ``step``; blocks unless async_save."""
        flat = _flatten(tree)  # device_get happens on the caller thread
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}),
                daemon=True,
            )
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def wait(self):
        """Block until any in-flight async save lands."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: dict):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp-{os.getpid()}-{time.time_ns()}"
        tmp.mkdir(parents=True)
        try:
            np.savez(tmp / "arrays.npz", **flat)
            manifest = dict(
                step=step,
                keys=sorted(flat),
                shapes={k: list(v.shape) for k, v in flat.items()},
                dtypes={k: str(v.dtype) for k, v in flat.items()},
                extra=extra,
            )
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -------------------------------------------------------------- read
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_????????"):
            if p.is_dir() and (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_flat(
        self, step: Optional[int] = None
    ) -> Tuple[int, Dict[str, np.ndarray], dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        return step, flat, manifest.get("extra", {})

    def restore_like(self, template: Any, step: Optional[int] = None):
        """Restore into the structure (and shardings) of ``template``."""
        step, flat, extra = self.restore_flat(step)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        new_leaves = []
        for path, leaf in leaves:
            arr = flat[_path_key(path)]
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None and hasattr(sharding, "mesh"):
                new_leaves.append(jax.device_put(arr, sharding))
            else:
                new_leaves.append(jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return step, tree, extra
