"""Straggler detection/mitigation for the host-side step loop.

At multi-pod scale a straggling host shows up as a slow step (everything is
bulk-synchronous — exactly the paper's epoch model, where one slow lane
delays the whole epoch).  The monitor keeps an EMA of step wall-time and
flags steps beyond ``threshold`` x EMA; the runner's mitigation policy is
pluggable (log / skip-data-refill / trigger elastic re-mesh).  On real pods
the same hook receives per-host heartbeat latencies.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    elapsed: float
    ema: float


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, ema_decay: float = 0.9):
        self.threshold = threshold
        self.ema_decay = ema_decay
        self.ema: Optional[float] = None
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> Optional[StragglerEvent]:
        elapsed = time.monotonic() - self._t0
        ev = None
        if self.ema is not None and elapsed > self.threshold * self.ema:
            ev = StragglerEvent(step=step, elapsed=elapsed, ema=self.ema)
            self.events.append(ev)
            # a straggler step must not poison the baseline
        else:
            self.ema = (
                elapsed
                if self.ema is None
                else self.ema_decay * self.ema + (1 - self.ema_decay) * elapsed
            )
        return ev
