"""Fault-tolerant training runner.

Exact-resume contract (tested in tests/test_runtime.py):
  * model params + full optimizer state + step live in every checkpoint;
  * the data pipeline is step-indexed (data/pipeline.py), so no reader state;
  * therefore kill-at-any-step + restart == uninterrupted run, bitwise.

``FailureInjector`` simulates node failures (raises at a chosen step);
``TrainRunner.run_with_restarts`` is the supervisor loop a cluster scheduler
would provide: catch, restore from latest checkpoint, continue.  Elastic
re-meshing on restart goes through checkpointing.reshard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpointing import CheckpointManager
from ..data import place_batch
from ..obs.log import get_logger, kv
from .stragglers import StragglerMonitor

log = get_logger("runtime")


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises InjectedFailure the first time ``step`` is reached."""

    fail_at_step: Optional[int] = None
    fired: bool = False

    def check(self, step: int):
        if (
            self.fail_at_step is not None
            and step == self.fail_at_step
            and not self.fired
        ):
            self.fired = True
            raise InjectedFailure(f"injected node failure at step {step}")


class TrainRunner:
    """Drives (train_step, data, optimizer state) with checkpoint/restart."""

    def __init__(
        self,
        train_step: Callable,     # (params, opt_state, batch) -> (p, s, metrics)
        dataset,                  # .batch_at(step) -> host batch
        ckpt: CheckpointManager,
        mesh=None,
        ckpt_every: int = 50,
        straggler: Optional[StragglerMonitor] = None,
        failure: Optional[FailureInjector] = None,
    ):
        self.train_step = train_step
        self.dataset = dataset
        self.ckpt = ckpt
        self.mesh = mesh
        self.ckpt_every = ckpt_every
        self.straggler = straggler or StragglerMonitor()
        self.failure = failure
        self.metrics_history: list = []

    def _save(self, step, params, opt_state):
        self.ckpt.save(step, {"params": params, "opt": opt_state})

    def _restore(self, params, opt_state):
        step, tree, _ = self.ckpt.restore_like(
            {"params": params, "opt": opt_state}
        )
        return step, tree["params"], tree["opt"]

    def run(self, params, opt_state, n_steps: int, start_step: int = 0):
        """Run to n_steps; returns (params, opt_state, metrics_history)."""
        step = start_step
        while step < n_steps:
            self.straggler.start_step()
            if self.failure is not None:
                self.failure.check(step)
            batch = place_batch(self.dataset.batch_at(step), self.mesh)
            params, opt_state, metrics = self.train_step(
                params, opt_state, batch
            )
            ev = self.straggler.end_step(step)
            if ev is not None:
                log.warning(
                    "straggler %s",
                    kv(step=ev.step, elapsed_s=ev.elapsed, ema_s=ev.ema),
                )
            step += 1
            if step % self.ckpt_every == 0 or step == n_steps:
                m = {
                    k: float(np.asarray(jax.device_get(v)))
                    for k, v in metrics.items()
                }
                self.metrics_history.append({"step": step, **m})
                self._save(step, params, opt_state)
        self.ckpt.wait()
        return params, opt_state, self.metrics_history

    def run_with_restarts(
        self, params, opt_state, n_steps: int, max_restarts: int = 3
    ):
        """Supervisor loop: restart from the latest checkpoint on failure.

        ``params``/``opt_state`` are the *initial* state; they are replaced
        by checkpointed state after a failure (a restarted worker would
        reconstruct them from disk the same way).
        """
        restarts = 0
        start = 0
        while True:
            try:
                return self.run(params, opt_state, n_steps, start_step=start)
            except InjectedFailure as e:
                restarts += 1
                if restarts > max_restarts:
                    raise
                log.warning(
                    "restarting from checkpoint %s",
                    kv(failure=e, restarts=restarts),
                )
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    start = 0  # no checkpoint yet: restart from scratch
                else:
                    start, params, opt_state = self._restore(
                        params, opt_state
                    )
