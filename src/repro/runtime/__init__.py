# Runtime substrate: fault-tolerant train runner (checkpoint/restart,
# failure injection), straggler mitigation, elastic re-meshing.
from .ft import TrainRunner, FailureInjector  # noqa: F401
from .stragglers import StragglerMonitor  # noqa: F401
