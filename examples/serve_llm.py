"""Serving driver on the layered front door: async submit/stream, quota
classes, deadlines, and chunk-boundary preemption (DESIGN.md §16).

The default path drives a toy autoregressive *decode* Program — each
request is a sequential fork/join chain, one token per epoch, the shape
continuous batching cares about — through the :class:`JobService` async
surface: interactive requests carry a priority and a deadline and may
preempt batch requests at chunk boundaries; completions stream back as
they finish, never blocking on a whole wave.

The model-based continuous-batching server (real transformer/SSM decode
through ``repro.serving.EpochServer``) is unchanged — run it with
``--legacy [--arch granite_3_8b]``.

Run:  PYTHONPATH=src python examples/serve_llm.py [--requests 16]
      PYTHONPATH=src python examples/serve_llm.py --legacy --arch mamba2_1_3b
"""
import argparse
import asyncio
import time

import jax.numpy as jnp
import numpy as np

from repro.core.program import InitialTask, Program, TaskType
from repro.service import JobService, QuotaClass


# ---------------------------------------------------------------- toy decode
# One "token" per chain link: decode(remaining, acc) forks its successor
# until the budget runs out, then the emitted value folds back up the join
# chain — a pure sequential dependency, exactly an LLM decode loop's shape.
def _decode(ctx):
    remaining = ctx.argi(0)
    acc = ctx.argi(1)
    leaf = remaining == 0
    ctx.emit(acc, where=leaf)
    nxt = (acc * 31 + 7) % 997
    ctx.fork("decode", argi=(remaining - 1, nxt), where=~leaf)
    ctx.join("collect", where=~leaf)


def _collect(ctx):
    cv = ctx.child_values(1)
    ctx.emit(cv[0, 0])


DECODE = Program(
    name="decode",
    tasks=(TaskType("decode", _decode), TaskType("collect", _collect)),
    n_arg_i=2,
    value_width=1,
    value_dtype=jnp.int32,
)


async def serve(args) -> None:
    svc = JobService(
        engine=args.engine,
        chunk=(args.chunk if args.engine == "device" else None),
        capacity=args.slots * 64,
        max_jobs=args.slots,
        classes=[
            QuotaClass("interactive", priority=10),
            QuotaClass("batch", priority=0),
        ],
    )
    rng = np.random.RandomState(0)
    futures = {}
    t0 = time.monotonic()
    for i in range(args.requests):
        interactive = i % 3 == 0
        tokens = int(rng.randint(4, 32))
        fut = svc.submit_async(
            DECODE,
            InitialTask(task="decode", argi=(tokens, int(rng.randint(997)))),
            quota=64,
            name=f"req{i}",
            klass="interactive" if interactive else "batch",
            deadline=(args.deadline if interactive else None),
        )
        futures[fut.job_id] = (fut, tokens)
    done = 0
    total_tokens = 0
    async for h in svc.stream_results():
        fut, tokens = futures[h.job_id]
        done += 1
        total_tokens += tokens
        print(
            f"  {h.job.name:>6s} [{h.klass:>11s}] {tokens:2d} tok "
            f"wait={h.queue_wait * 1e3:6.1f}ms run={h.run_time * 1e3:6.1f}ms"
            f"{'  (preempted x%d)' % h.preemptions if h.preemptions else ''}"
        )
    dt = time.monotonic() - t0
    adm = svc.admission
    print(
        f"{done} requests, {total_tokens} tokens in {dt:.2f}s "
        f"-> {total_tokens / dt:.0f} tok/s ({args.engine} engine)"
    )
    print(
        f"  deadline miss ratio: {adm.miss_ratio():.2f}  "
        f"preemptions: {dict(adm.preempted) or 0}"
    )


def legacy(args) -> None:
    import jax

    from repro import configs
    from repro.models.model import init_model
    from repro.serving import EpochServer, Request

    cfg = configs.get_reduced(args.arch)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    enc = None
    if cfg.encdec:
        enc = jnp.asarray(
            rng.normal(size=(1, cfg.encoder_len, cfg.d_model)), jnp.float32
        )
    server = EpochServer(
        cfg, params, n_slots=args.slots, max_len=128, enc_frames=enc
    )
    for i in range(args.requests):
        server.submit(
            Request(
                prompt=rng.randint(3, cfg.vocab, rng.randint(4, 20)).astype(
                    np.int32
                ),
                max_new_tokens=int(rng.randint(4, 16)),
            )
        )
    t0 = time.time()
    done = server.run_to_completion()
    dt = time.time() - t0
    tok = sum(len(r.output) for r in done)
    print(
        f"{cfg.name}: {len(done)} requests, {tok} tokens, "
        f"{server.epochs} epochs ({args.slots} slots) in {dt:.1f}s "
        f"-> {tok / dt:.1f} tok/s"
    )
    print(f"  epochs per token ~ {server.epochs / max(tok, 1):.2f} "
          f"(continuous batching keeps slots busy across ragged requests)")
    for r in done[:4]:
        print(f"  rid={r.rid:2d} prompt_len={len(r.prompt):2d} -> {r.output}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--engine", default="device",
                    choices=("host", "device"))
    ap.add_argument("--chunk", type=int, default=4,
                    help="K epochs per resident chunk (device engine)")
    ap.add_argument("--deadline", type=float, default=30.0,
                    help="interactive-class deadline in seconds (wall "
                         "clock, so leave headroom for jit warm-up)")
    ap.add_argument("--legacy", action="store_true",
                    help="run the model-based EpochServer path instead")
    ap.add_argument("--arch", default="granite_3_8b",
                    help="(--legacy) reduced model config to serve")
    args = ap.parse_args()
    if args.legacy:
        legacy(args)
    else:
        asyncio.run(serve(args))
