"""End-to-end serving driver: continuous batching on the TVM scheduler.

16 ragged requests stream through 4 slots of an epoch-synchronized server
(admission = prefix-sum fork, bulk decode epoch, emit on completion) — the
paper's machine applied to LLM serving.  Works for every arch family; try
--arch mamba2_1_3b (O(1)-state SSM decode) or whisper_large_v3 (enc-dec with
cached cross-KV).

Run:  PYTHONPATH=src python examples/serve_llm.py [--arch granite_3_8b]
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import init_model
from repro.serving import EpochServer, Request

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite_3_8b")
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--requests", type=int, default=16)
args = ap.parse_args()

cfg = configs.get_reduced(args.arch)
params, _ = init_model(cfg, jax.random.PRNGKey(0))
rng = np.random.RandomState(0)
enc = None
if cfg.encdec:
    import jax.numpy as jnp

    enc = jnp.asarray(
        rng.normal(size=(1, cfg.encoder_len, cfg.d_model)), jnp.float32
    )

server = EpochServer(
    cfg, params, n_slots=args.slots, max_len=128, enc_frames=enc
)
for i in range(args.requests):
    server.submit(
        Request(
            prompt=rng.randint(3, cfg.vocab, rng.randint(4, 20)).astype(
                np.int32
            ),
            max_new_tokens=int(rng.randint(4, 16)),
        )
    )
t0 = time.time()
done = server.run_to_completion()
dt = time.time() - t0
tok = sum(len(r.output) for r in done)
print(
    f"{cfg.name}: {len(done)} requests, {tok} tokens, {server.epochs} epochs"
    f" ({args.slots} slots) in {dt:.1f}s -> {tok/dt:.1f} tok/s"
)
print(f"  epochs per token ~ {server.epochs/max(tok,1):.2f} "
      f"(continuous batching keeps slots busy across ragged requests)")
for r in done[:4]:
    print(f"  rid={r.rid:2d} prompt_len={len(r.prompt):2d} -> {r.output}")
