"""Quickstart: the TREES epoch-synchronized runtime in three scenes.

  1. A task-parallel program (fib) on the host-loop and on-device engines,
     with the paper's T1 / T-inf / overhead accounting.
  2. The paper's running example: postorder tree traversal (Fig. 2-4).
  3. Work-together graph analytics: BFS vs the hand-coded worklist baseline.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.apps import bfs, fib, treewalk
from repro.apps.baselines import worklist
from repro.core import DeviceEngine, HostEngine, compare, run_oracle

# ---- 1. fib: fork/join/emit, host vs device engines ----------------------
n = 14
heap, values, host_stats = HostEngine(fib.PROGRAM, capacity=1 << 13).run(
    fib.initial(n)
)
print(f"fib({n}) = {int(values[0, 0])}  (expect {fib.fib_reference(n)})")
_, _, oracle_stats = run_oracle(fib.PROGRAM, fib.initial(n), capacity=1 << 13)
rep = compare(oracle_stats, host_stats)
print(
    f"  work T1={rep.t1_tasks} tasks, critical path T_inf={rep.t_inf_epochs} "
    f"epochs, parallelism={rep.parallelism:.1f}"
)
print(
    f"  host engine: {host_stats.dispatches} dispatches (V_inf), "
    f"lane utilization {rep.utilization:.2f} (V1 factor "
    f"{rep.v1_lane_factor:.2f})"
)
_, values_dev, dev_stats = DeviceEngine(
    fib.PROGRAM, capacity=1 << 13, stack_depth=256
).run(fib.initial(n))
print(
    f"  device engine (whole loop in one XLA program): same result "
    f"{int(values_dev[0, 0])}, dispatches={dev_stats.dispatches}"
)

# ---- 2. the paper's running example: postorder traversal -----------------
nn = 15
left, right = treewalk.random_tree(nn, seed=1)
prog = treewalk.make_program(nn, "post")
heap, _, st = HostEngine(prog, capacity=1 << 10).run(
    treewalk.initial(), heap_init=dict(left=left, right=right)
)
ve = np.asarray(heap["visit_epoch"])
ok = all(
    ve[p] > ve[c]
    for p in range(nn)
    for c in (left[p], right[p])
    if c >= 0
)
print(f"\npostorder traversal of {nn}-node tree: parent-after-children = {ok}"
      f" ({st.epochs} epochs)")

# ---- 3. BFS: TREES program vs hand-coded worklist -------------------------
ng = 128
adj_off, adj = bfs.random_graph(ng, avg_degree=4, seed=3)
prog = bfs.make_program(ng, len(adj))
heap, _, st = HostEngine(prog, capacity=1 << 14).run(
    bfs.initial(0), heap_init=bfs.heap_init(adj_off, adj, ng)
)
dist_trees = np.asarray(heap["dist"])
dist_wl, rounds = worklist.bfs_worklist(adj_off, adj, 0, ng)
print(
    f"\nBFS on {ng} nodes: TREES == worklist baseline: "
    f"{np.array_equal(dist_trees, np.asarray(dist_wl))} "
    f"(TREES {st.epochs} epochs / worklist {rounds} rounds)"
)
print("quickstart OK")
