"""Irregular-parallel graph analytics on the TREES runtime (paper §6.3).

BFS and SSSP as fork/join task programs with chunked edge expansion, versus
the hand-coded Lonestar-style worklist baselines; validates both against
sequential references and reports the work-together accounting.

Run:  PYTHONPATH=src python examples/graph_analytics.py [--nodes 256]
"""
import argparse
import time

import numpy as np

from repro.apps import bfs, sssp
from repro.apps.baselines import worklist
from repro.core import HostEngine

ap = argparse.ArgumentParser()
ap.add_argument("--nodes", type=int, default=256)
args = ap.parse_args()
n = args.nodes

adj_off, adj = bfs.random_graph(n, avg_degree=4, seed=0)
wgt = sssp.random_weights(len(adj), seed=1)
print(f"graph: {n} nodes, {len(adj)} edges")

# ---- BFS ------------------------------------------------------------------
t0 = time.time()
prog = bfs.make_program(n, len(adj))
heap, _, st = HostEngine(prog, capacity=1 << 16).run(
    bfs.initial(0), heap_init=bfs.heap_init(adj_off, adj, n)
)
t_trees = time.time() - t0
d_trees = np.asarray(heap["dist"])
t0 = time.time()
d_wl, rounds = worklist.bfs_worklist(adj_off, adj, 0, n)
t_wl = time.time() - t0
ref = bfs.bfs_reference(adj_off, adj, 0, n)
print(
    f"BFS   trees==ref: {np.array_equal(d_trees, ref)}  "
    f"worklist==ref: {np.array_equal(np.asarray(d_wl), ref)}  "
    f"epochs={st.epochs} tasks={st.tasks_executed} "
    f"(trees {t_trees:.2f}s / worklist {t_wl:.2f}s)"
)

# ---- SSSP -----------------------------------------------------------------
t0 = time.time()
progs = sssp.make_program(n, len(adj))
heap, _, st = HostEngine(progs, capacity=1 << 17).run(
    sssp.initial(0), heap_init=sssp.heap_init(adj_off, adj, wgt, n)
)
t_trees = time.time() - t0
s_trees = np.asarray(heap["dist"])
t0 = time.time()
s_wl, rounds = worklist.sssp_worklist(adj_off, adj, wgt, 0, n)
t_wl = time.time() - t0
refs = sssp.sssp_reference(adj_off, adj, wgt, 0, n)
print(
    f"SSSP  trees~=ref: {np.allclose(s_trees, refs, rtol=1e-5)}  "
    f"worklist~=ref: {np.allclose(np.asarray(s_wl), refs, rtol=1e-5)}  "
    f"epochs={st.epochs} tasks={st.tasks_executed} "
    f"(trees {t_trees:.2f}s / worklist {t_wl:.2f}s)"
)
