"""End-to-end training driver: a ~10M-param granite-family model trained a
few hundred steps on CPU with the full substrate — step-indexed data,
ZeRO-1 AdamW, atomic checkpoints, an injected node failure and automatic
restart (the loss curve continues exactly where it left off).

Run:  PYTHONPATH=src python examples/train_lm.py  [--steps 200]
Scale up: the same driver with --full and a production mesh runs the real
configs (see repro/launch/train.py and the multi-pod dry-run).
"""
import argparse
import tempfile

from repro.checkpointing import CheckpointManager
from repro.launch.train import build
from repro.runtime import FailureInjector, TrainRunner

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="granite_moe_1b_a400m")
args = ap.parse_args()

cfg, params, opt_state, step_fn, data, _ = build(
    args.arch, reduced=True, batch=8, seq=128, steps=args.steps, lr=3e-3
)
import numpy as np

n_params = sum(int(np.prod(v.shape)) for v in params.values())
print(f"training {cfg.name} (reduced, {n_params/1e6:.2f}M params) "
      f"for {args.steps} steps with a failure injected at step "
      f"{args.steps // 2}")

runner = TrainRunner(
    step_fn,
    data,
    CheckpointManager(tempfile.mkdtemp(prefix="repro_ckpt_"), keep=2,
                      async_save=True),
    ckpt_every=25,
    failure=FailureInjector(fail_at_step=args.steps // 2),
)
params, opt_state, hist = runner.run_with_restarts(
    params, opt_state, args.steps
)
for h in hist:
    print(f"  step {h['step']:5d}  loss {h['loss']:.4f}  lr {h['lr']:.2e}")
print(f"recovered from 1 injected failure; "
      f"{len(runner.straggler.events)} straggler events; done")
