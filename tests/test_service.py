"""Epoch-multiplexing job service tests (DESIGN.md §8).

The load-bearing property: co-scheduling N independent programs in one
shared TVM must be *observationally invisible* to each tenant — per-job
heaps, TV-value blocks, and work stats bit-identical to a solo
``HostEngine.run`` with ``capacity=quota`` — while the fleet pays strictly
fewer fused dispatches + scalar readbacks than the sum of the solo runs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import fib, get_fleet
from repro.core import HostEngine, Program, TaskType, InitialTask
from repro.service import (
    AdmissionError,
    EpochMultiplexer,
    Job,
    JobFailure,
    JobHandle,
    JobService,
    JobStatus,
    fuse_programs,
)


def _solo(case, quota, dispatch="masked"):
    eng = HostEngine(case.program, capacity=quota, dispatch=dispatch)
    return eng.run(case.initial, heap_init=dict(case.heap_init) or None)


def _assert_job_matches_solo(handle, solo_heap, solo_value, name):
    r = handle.result
    np.testing.assert_array_equal(
        np.asarray(r.value), np.asarray(solo_value), err_msg=f"{name}:value"
    )
    assert set(r.heap) == set(solo_heap)
    for k in solo_heap:
        np.testing.assert_array_equal(
            np.asarray(r.heap[k]), np.asarray(solo_heap[k]),
            err_msg=f"{name}:{k}",
        )


# ------------------------------------------- the multi-tenant equivalence
@pytest.mark.parametrize("dispatch", ["masked", "compacted", "gather"])
def test_mixed_fleet_bit_identical_and_cheaper(dispatch):
    """Acceptance: a mixed fleet of 3 registered apps through the service is
    bit-identical per job to solo runs, with fleet V_inf (dispatches +
    scalar transfers) strictly below the sum of the solo runs'."""
    fleet = get_fleet("mixed3")
    assert len(fleet) >= 3
    solo = {}
    solo_vinf = 0
    for case, quota in fleet:
        heap, value, stats = _solo(case, quota, dispatch)
        solo[case.name] = (heap, value, stats)
        solo_vinf += stats.dispatches + stats.scalar_transfers

    svc = JobService(
        capacity=sum(q for _, q in fleet), dispatch=dispatch
    )
    handles = [svc.submit_case(case, quota=q) for case, q in fleet]
    done = svc.drain()
    assert {h.job_id for h in done} == {h.job_id for h in handles}

    for h in handles:
        sh, sv, ss = solo[h.job.name]
        assert h.status is JobStatus.DONE
        _assert_job_matches_solo(h, sh, sv, h.job.name)
        # per-job work accounting matches the solo run exactly
        assert h.result.stats.epochs == ss.epochs
        assert h.result.stats.tasks_executed == ss.tasks_executed
        assert h.result.stats.total_forks == ss.total_forks
        assert h.result.stats.peak_tv_slots == ss.peak_tv_slots

    fs = svc.stats()
    assert fs.dispatches + fs.scalar_transfers < solo_vinf
    # fused global epochs = max over members, not the sum
    assert fs.epochs == max(s[2].epochs for s in solo.values())
    # cross-job frontier fusion is recorded as coalesced ranges
    assert fs.ranges_coalesced > 0


def test_fused_maps_match_solo():
    """A map-bearing tenant (mergesort bulk payloads) stays bit-identical
    when its map launches run against the fused, namespaced heap."""
    fleet = [(c, q) for c, q in get_fleet("mixed4")
             if c.name in ("mergesort", "fib")]
    solo = {c.name: _solo(c, q) for c, q in fleet}
    svc = JobService(capacity=sum(q for _, q in fleet))
    handles = [svc.submit_case(c, quota=q) for c, q in fleet]
    svc.drain()
    for h in handles:
        sh, sv, ss = solo[h.job.name]
        _assert_job_matches_solo(h, sh, sv, h.job.name)
        assert h.result.stats.epochs == ss.epochs
    # the sorted output really is sorted (guard against trivially-equal
    # garbage comparisons)
    ms = [h for h in handles if h.job.name == "mergesort"][0]
    n = ms.result.heap["inp"].shape[0]
    out = np.asarray(ms.result.heap["src"])[:n]
    np.testing.assert_array_equal(out, np.sort(np.asarray(ms.result.heap["inp"])))


@pytest.mark.parametrize(
    "policy,gang", [("round_robin", 1), ("round_robin", 2),
                    ("deepest_first", 2)]
)
def test_pop_policies_preserve_results(policy, gang):
    """Gang-limited pop policies change only the fusion schedule, never any
    job's results."""
    fleet = get_fleet("mixed3")
    solo = {c.name: _solo(c, q) for c, q in fleet}
    svc = JobService(
        capacity=sum(q for _, q in fleet), pop_policy=policy, gang=gang
    )
    handles = [svc.submit_case(c, quota=q) for c, q in fleet]
    svc.drain()
    for h in handles:
        sh, sv, _ = solo[h.job.name]
        _assert_job_matches_solo(h, sh, sv, f"{policy}:{h.job.name}")


def test_gang1_round_robin_is_fair_serialization():
    """gang=1 degenerates to interleaved solo execution: fleet dispatches
    equal the sum of per-job epochs (no fusion), and rotation gives every
    job progress (completion order follows job size)."""
    fleet = get_fleet("mixed3")
    solo_epochs = {c.name: _solo(c, q)[2].epochs for c, q in fleet}
    svc = JobService(
        capacity=sum(q for _, q in fleet), pop_policy="round_robin", gang=1
    )
    for c, q in fleet:
        svc.submit_case(c, quota=q)
    svc.drain()
    assert svc.stats().epochs == sum(solo_epochs.values())


# --------------------------------------------------- streaming / reuse
def test_streaming_admission_reuses_regions():
    """More jobs than regions: completed regions are reclaimed and queued
    jobs of the same program template are seeded mid-flight."""
    ns = (8, 9, 10, 11, 12)
    svc = JobService(capacity=1024, max_jobs=2)
    handles = [
        svc.submit(fib.PROGRAM, fib.initial(n), quota=512, name=f"fib{n}")
        for n in ns
    ]
    seen = []
    for h in svc.completions():  # streaming completion order
        seen.append(h.job.name)
    assert sorted(seen) == sorted(f"fib{n}" for n in ns)
    for h, n in zip(handles, ns):
        assert h.status is JobStatus.DONE
        assert int(np.asarray(h.result.value)[0, 0]) == fib.fib_reference(n)
    # 5 jobs through 2 regions: at least one region was reseeded in place
    # (fib8/fib9 finish first; fib10+ ride the same multiplexer)
    assert len(seen) == len(ns)


def test_result_drives_single_job():
    svc = JobService(capacity=512)
    h = svc.submit(fib.PROGRAM, fib.initial(9), quota=256)
    assert svc.poll(h) is JobStatus.QUEUED
    res = svc.result(h)
    assert svc.poll(h) is JobStatus.DONE
    assert int(np.asarray(res.value)[0, 0]) == fib.fib_reference(9)


# -------------------------------------------------- admission / failure
def test_quota_overflow_fails_only_that_job():
    """A job outgrowing its own region fails alone; its neighbour's result
    is untouched (bounded scatters: no cross-region corruption)."""
    svc = JobService(capacity=1024)
    bad = svc.submit(fib.PROGRAM, fib.initial(12), quota=8, name="bad")
    good = svc.submit(fib.PROGRAM, fib.initial(10), quota=512, name="good")
    svc.drain()
    assert bad.status is JobStatus.FAILED
    assert isinstance(bad.error, JobFailure)
    assert good.status is JobStatus.DONE
    assert int(np.asarray(good.result.value)[0, 0]) == fib.fib_reference(10)
    with pytest.raises(JobFailure):
        svc.result(bad)


def test_admission_rejects_bad_jobs():
    svc = JobService(capacity=1024)
    with pytest.raises(AdmissionError):  # quota above service capacity
        svc.submit(fib.PROGRAM, fib.initial(8), quota=4096)
    with pytest.raises(AdmissionError):  # quota below the minimum
        svc.submit(fib.PROGRAM, fib.initial(8), quota=1)
    with pytest.raises(AdmissionError):  # unknown seed task
        svc.submit(fib.PROGRAM, InitialTask(task="nope", argi=(1,)), quota=64)


def _f32_program():
    def _emit(ctx):
        ctx.emit(jnp.float32(1.5))

    return Program(
        name="f32emit", tasks=(TaskType("emit", _emit),),
        value_dtype=jnp.float32,
    )


def test_mixed_value_dtypes_split_into_waves():
    """Fleets must share one TV value dtype; incompatible jobs are not
    rejected — the service runs them in a later wave."""
    with pytest.raises(AdmissionError):
        fuse_programs([fib.PROGRAM, _f32_program()], [64, 64])
    svc = JobService(capacity=1024, max_jobs=4)
    a = svc.submit(fib.PROGRAM, fib.initial(8), quota=256, name="i32")
    b = svc.submit(_f32_program(), InitialTask(task="emit"), quota=64,
                   name="f32")
    svc.drain()
    assert a.status is JobStatus.DONE and b.status is JobStatus.DONE
    assert int(np.asarray(a.result.value)[0, 0]) == fib.fib_reference(8)
    assert float(np.asarray(b.result.value)[0, 0]) == 1.5
    # two waves ran: one per dtype
    assert svc.stats().epochs > 0


def _w1_shape_sensitive_program():
    """value_width=1 program whose result depends on the *row shape* of
    child_values — catches fused-width leakage into a tenant's view."""

    def _root(ctx):
        leaf = ctx.argi(0) < 0
        ctx.emit(ctx.argi(0), where=leaf)
        ctx.fork("root", argi=(-1,), where=~leaf)
        ctx.fork("root", argi=(-2,), where=~leaf)
        ctx.join("gather", where=~leaf)

    def _gather(ctx):
        cv = ctx.child_values(2)  # solo shape (2, 1)
        # flat index 1 is the *second child* only at width 1
        ctx.emit(cv.reshape(-1)[1])

    return Program(
        name="w1shape",
        tasks=(TaskType("root", _root), TaskType("gather", _gather)),
        n_arg_i=1,
    )


def _w2_program():
    def _emit2(ctx):
        ctx.emit(jnp.asarray([3, 4], jnp.int32))

    return Program(
        name="w2", tasks=(TaskType("emit2", _emit2),), value_width=2
    )


def test_mixed_value_width_tenant_sees_own_shape():
    """A width-1 tenant co-scheduled with a width-2 tenant must see its own
    (n, 1) child_values rows, not the fused (n, 2)."""
    w1, w2 = _w1_shape_sensitive_program(), _w2_program()
    solo = HostEngine(w1, capacity=16).run(InitialTask(task="root", argi=(0,)))
    svc = JobService(capacity=64, max_jobs=2)
    a = svc.submit(w1, InitialTask(task="root", argi=(0,)), quota=16)
    b = svc.submit(w2, InitialTask(task="emit2"), quota=8)
    svc.drain()
    np.testing.assert_array_equal(
        np.asarray(a.result.value), np.asarray(solo[1])
    )
    assert int(np.asarray(a.result.value)[0, 0]) == -2  # the second child
    np.testing.assert_array_equal(
        np.asarray(b.result.value)[0], np.asarray([3, 4], np.int32)
    )


def test_tenant_emit_wider_than_own_width_rejected():
    """A tenant emitting wider than its own value_width must fail exactly
    as it would solo, even when the fused width could hold it."""

    def _bad(ctx):
        ctx.emit(jnp.asarray([1, 2], jnp.int32))  # width 2 in a width-1 prog

    bad = Program(name="bad", tasks=(TaskType("bad", _bad),))
    svc = JobService(capacity=64, max_jobs=2)
    svc.submit(bad, InitialTask(task="bad"), quota=8)
    svc.submit(_w2_program(), InitialTask(task="emit2"), quota=8)
    with pytest.raises(ValueError, match="wider than"):
        svc.drain()


# ----------------------------------------------------------- fusion unit
def test_fuse_programs_namespacing():
    fleet = get_fleet("mixed3")
    programs = [c.program for c, _ in fleet]
    fused, slots = fuse_programs(programs, [q for _, q in fleet])
    assert len(fused.tasks) == sum(len(p.tasks) for p in programs)
    assert fused.n_arg_i == max(p.n_arg_i for p in programs)
    # tenant namespaces are disjoint and offsets index the fused table
    for slot, p in zip(slots, programs):
        for t in p.tasks:
            fid = fused.task_id(slot.prefix + t.name)
            assert fid == slot.task_offset + p.task_id(t.name)
        for hv in p.heap:
            assert any(f.name == slot.prefix + hv.name for f in fused.heap)
    # regions tile the capacity contiguously
    assert slots[0].base == 0
    for a, b in zip(slots, slots[1:]):
        assert b.base == a.end


def test_multiplexer_direct_single_job_matches_engine():
    """The multiplexer with J=1 is exactly a solo HostEngine."""
    heap, value, stats = _solo_fib9 = (
        HostEngine(fib.PROGRAM, capacity=256).run(fib.initial(9))
    )
    h = JobHandle(0, Job(fib.PROGRAM, fib.initial(9), quota=256))
    mux = EpochMultiplexer([h])
    mux.run()
    np.testing.assert_array_equal(
        np.asarray(h.result.value), np.asarray(value)
    )
    fs = mux.stats()
    assert fs.epochs == stats.epochs
    assert fs.dispatches == stats.dispatches
    assert fs.scalar_transfers == stats.scalar_transfers


# ------------------------------------------- structural program hashing
def _make_tree_prog(fanout=2):
    """Build a fresh Program object each call: same construction path =>
    same structure, but distinct function objects and closures.  The walk
    depth is an *initial arg* (argi(1)), so structurally equal jobs can
    still run for different lengths."""

    def _node(ctx):
        d, maxd = ctx.argi(0), ctx.argi(1)
        leaf = d >= maxd
        ctx.emit(d, where=leaf)
        for _ in range(fanout):
            ctx.fork("node", argi=(d + 1, maxd), where=~leaf)
        ctx.join("sum", where=~leaf)

    def _sum(ctx):
        cv = ctx.child_values(fanout)
        ctx.emit(cv[:, 0].sum())

    return Program(
        name=f"tree{fanout}",
        tasks=(TaskType("node", _node), TaskType("sum", _sum)),
        n_arg_i=2,
    )


def test_structural_hash_equality_and_sensitivity():
    import dataclasses

    a, b, c = _make_tree_prog(2), _make_tree_prog(2), _make_tree_prog(3)
    assert a.structural_hash() == b.structural_hash()
    # captured constants (the closure's fanout) are part of the structure
    assert a.structural_hash() != c.structural_hash()
    # the display name is cosmetic, not structural
    assert (
        a.structural_hash()
        == dataclasses.replace(a, name="renamed").structural_hash()
    )
    # the fused program namespaces tasks/heaps: structurally different
    fused, _ = fuse_programs([a, b], [32, 32])
    assert fused.structural_hash() != a.structural_hash()


def test_structurally_equal_tenant_reuses_region_without_new_wave():
    """ROADMAP item: a freed region is reseeded by any same-shape tenant —
    an independently built (structurally equal) program streams into the
    region freed by a shorter job while the wave is still in flight,
    instead of forcing a second wave/retrace."""
    p1, p2 = _make_tree_prog(), _make_tree_prog()
    assert p1 is not p2
    svc = JobService(capacity=512, max_jobs=2)
    a = svc.submit(p1, InitialTask(task="node", argi=(0, 2)), quota=256,
                   name="short")
    b = svc.submit(p1, InitialTask(task="node", argi=(0, 6)), quota=256,
                   name="long")
    c = svc.submit(p2, InitialTask(task="node", argi=(0, 3)), quota=256,
                   name="late")
    muxes = set()  # hold strong refs: a freed mux's id() could be reused
    for _ in svc.completions():
        muxes.add(svc._mux)
    for h in (a, b, c):
        assert h.status is JobStatus.DONE
    # one EpochMultiplexer served all three: c streamed into a's freed
    # region (p2 is a different object but structurally equal to p1)
    assert len(muxes) == 1
    solo = HostEngine(p2, capacity=256).run(InitialTask(task="node",
                                                        argi=(0, 3)))
    np.testing.assert_array_equal(
        np.asarray(c.result.value), np.asarray(solo[1])
    )


def test_structurally_different_tenant_waits_for_next_wave():
    p1, p2 = _make_tree_prog(2), _make_tree_prog(3)
    svc = JobService(capacity=512, max_jobs=2)
    svc.submit(p1, InitialTask(task="node", argi=(0, 2)), quota=256)
    svc.submit(p1, InitialTask(task="node", argi=(0, 6)), quota=256)
    svc.submit(p2, InitialTask(task="node", argi=(0, 2)), quota=256)
    muxes = set()  # hold strong refs: a freed mux's id() could be reused
    for _ in svc.completions():
        muxes.add(svc._mux)
    assert len(muxes) == 2  # incompatible template: a second wave ran


# ------------------------------------- segmented fork-scan integration
def test_mux_with_pallas_segmented_fork_offsets():
    """The arena allocator's plug point accepts the Pallas segmented scan
    (interpret mode on CPU) and produces bit-identical fleet results."""
    from repro.kernels import ops as kops

    def seg_offsets(counts, seg, n_segs):
        return kops.segmented_fork_offsets(counts, seg, n_segs,
                                           impl="interpret")

    ns = (8, 9)
    solo = {
        n: HostEngine(fib.PROGRAM, capacity=128).run(fib.initial(n))
        for n in ns
    }
    handles = [
        JobHandle(i, Job(fib.PROGRAM, fib.initial(n), quota=128,
                         name=f"fib{n}"))
        for i, n in enumerate(ns)
    ]
    mux = EpochMultiplexer(handles, seg_offsets_fn=seg_offsets)
    mux.run()
    for h, n in zip(handles, ns):
        np.testing.assert_array_equal(
            np.asarray(h.result.value), np.asarray(solo[n][1])
        )
        assert h.result.stats.epochs == solo[n][2].epochs
