"""Forced-regime tests for the self-tuning controllers (DESIGN.md §14).

The controllers are deliberately plain host objects — decisions are pure
functions of the observation window and the cost model — so every regime
the ISSUE names is testable without timing flakiness: an all-holes fleet
must steer dispatch to gather, a dense fleet must hold masked, a hot job
queue must shrink K, and a completion-free wave must widen it.  End-to-end
regime tests then drive real engines and assert the exported decision
counters, and the calibration cache is pinned one-shot.
"""
import numpy as np
import pytest

from repro.control import (
    ChunkController,
    CostModel,
    Decision,
    DispatchController,
    RollingWindow,
)
from repro.obs.metrics import MetricsRegistry


# ------------------------------------------------------------ rolling window
def test_rolling_window_mean_and_eviction():
    w = RollingWindow(3)
    assert w.mean() is None and w.last() is None and len(w) == 0
    for v in (1.0, 2.0, 3.0):
        w.add(v)
    assert w.mean() == pytest.approx(2.0)
    w.add(7.0)  # evicts the 1.0
    assert w.mean() == pytest.approx(4.0)
    assert w.last() == 7.0 and len(w) == 3


# --------------------------------------------------------------- cost model
def test_cost_model_prices_the_design_11_trade():
    m = CostModel()
    dense = m.epoch_costs(4096, fill=1.0)
    sparse = m.epoch_costs(4096, fill=0.01)
    # a full frontier never benefits from paying the pack pass
    assert dense["masked"] < dense["gather"] < dense["compacted"]
    # gather launches the rung over the live count; masked pays every lane
    # (the pack's extra dispatch+transfer only amortizes on wide spans:
    # at the default constants break-even is near P ~ 1k, DESIGN.md §14)
    assert sparse["gather"] < sparse["masked"]
    # monotone in span: wider frontiers cost more under every mode
    narrow = m.epoch_costs(1024, fill=0.01)
    assert all(sparse[k] >= narrow[k] for k in narrow)


def test_dispatch_controller_all_holes_fleet_goes_gather():
    ctl = DispatchController()
    for _ in range(4):  # nearly-empty frontier: 8 live lanes in 4096
        ctl.observe(8, 4096)
    d = ctl.choose(4096)
    assert d.mode == "gather"
    assert d.reason == "cost"
    assert d.hole_fraction == pytest.approx(1.0 - 8 / 4096)
    assert d.costs["gather"] < d.costs["masked"]


def test_dispatch_controller_dense_fleet_stays_masked():
    ctl = DispatchController()
    for _ in range(4):
        ctl.observe(4096, 4096)
    d = ctl.choose(4096)
    assert d.mode == "masked"
    assert d.costs["masked"] < d.costs["gather"]


def test_dispatch_controller_cold_start_is_masked():
    ctl = DispatchController()
    d = ctl.choose(1024)
    assert d.mode == "masked" and d.reason == "no-data" and d.fill is None


def test_dispatch_controller_hysteresis_resists_flapping():
    # park the controller on gather, then feed a fill right at the
    # break-even point: the marginal cost difference must not flip it
    ctl = DispatchController(hysteresis=10.0)  # huge band: never switch
    for _ in range(8):
        ctl.observe(8, 4096)
    assert ctl.choose(4096).mode == "gather"
    for _ in range(32):
        ctl.observe(4096, 4096)
    d = ctl.choose(4096)
    assert d.mode == "gather" and d.reason == "hysteresis"


def test_dispatch_controller_resident_never_picks_compacted():
    ctl = DispatchController()
    # fill chosen so compacted would win only if it were allowed: force
    # gather-favourable data and confirm the resident modes are the menu
    for _ in range(4):
        ctl.observe(2, 4096)
    d = ctl.choose_resident(4096)
    assert d.mode in ("masked", "gather")
    # and the per-epoch menu is restored afterwards
    assert ctl.modes == ("masked", "compacted", "gather")


def test_decision_counters_exported():
    reg = MetricsRegistry()
    ctl = DispatchController(registry=reg, driver="host", app="t")
    for _ in range(4):
        ctl.observe(8, 4096)
    ctl.choose(4096)
    assert reg.value("trees_controller_decisions_total",
                     driver="host", app="t", mode="gather") == 1
    assert reg.value("trees_controller_hole_fraction",
                     driver="host", app="t") == pytest.approx(1 - 8 / 4096)


# ----------------------------------------------------------- chunk controller
def test_chunk_controller_widens_while_no_completions():
    ctl = ChunkController(k_init=1, k_max=64)
    ks = [ctl.observe(completions=0) for _ in range(8)]
    assert ks[:6] == [2, 4, 8, 16, 32, 64]
    assert ctl.current() == 64  # capped
    assert ctl.widened == 6


def test_chunk_controller_hot_queue_shrinks():
    ctl = ChunkController(k_init=16, hot_wait_s=0.05)
    # completions flowing but the queue is hot: K halves
    k = ctl.observe(completions=2, queued=3, oldest_wait_s=1.0)
    assert k == 8 and ctl.shrunk == 1
    # still hot: halves again, floored at k_min
    for _ in range(8):
        k = ctl.observe(completions=0, queued=3, oldest_wait_s=1.0)
    assert k == 1
    # a cool queue with completions holds
    assert ctl.observe(completions=1, queued=0) == 1


def test_chunk_controller_cool_queue_below_threshold_does_not_shrink():
    ctl = ChunkController(k_init=8, hot_wait_s=0.05)
    k = ctl.observe(completions=1, queued=2, oldest_wait_s=0.001)
    assert k == 8 and ctl.shrunk == 0


def test_chunk_controller_registry_gauges():
    reg = MetricsRegistry()
    ctl = ChunkController(k_init=2, registry=reg, app="t")
    ctl.observe(completions=0)
    assert reg.value("trees_controller_chunk_k", app="t") == 4
    assert reg.value("trees_controller_chunk_adaptations_total",
                     app="t", action="widen") == 1


def test_chunk_controller_validates_bounds():
    with pytest.raises(ValueError):
        ChunkController(k_init=0)
    with pytest.raises(ValueError):
        ChunkController(k_init=8, k_max=4)


# ------------------------------------------------------------- calibration
def test_calibration_is_one_shot_per_process(tmp_path):
    import repro.control.controller as cc

    saved = dict(cc._CALIBRATION_CACHE)
    cc._CALIBRATION_CACHE.clear()
    try:
        p = str(tmp_path / "cal.json")
        m1 = CostModel.calibrated(capacity=256, repeats=1, path=p)
        assert m1.source.startswith("calibrated:")
        assert m1.dispatch_s > 0 and m1.lane_s > 0
        # second call must come from the process cache (same object)
        assert CostModel.calibrated(capacity=256, repeats=1) is m1
        # and the persisted file round-trips for a fresh process
        cc._CALIBRATION_CACHE.clear()
        import jax

        m2 = CostModel.load(p, backend=jax.default_backend())
        assert m2 is not None
        assert m2.dispatch_s == pytest.approx(m1.dispatch_s)
    finally:
        cc._CALIBRATION_CACHE.clear()
        cc._CALIBRATION_CACHE.update(saved)


# --------------------------------------------------- end-to-end forced regimes
def test_host_auto_sparse_fleet_decides_gather_end_to_end():
    """An all-holes fused fleet (two tiny tenants at opposite ends of a
    wide TV) must steer the host multiplexer's per-epoch decisions to
    gather once the window sees the holes — and stay bit-identical to the
    masked reference."""
    from repro.apps import get_case
    from repro.service import EpochMultiplexer, Job, JobHandle

    def handles():
        return [
            JobHandle(i, Job(c.program, c.initial,
                             heap_init=dict(c.heap_init),
                             quota=4096, name=f"{c.name}#{i}"))
            for i, c in enumerate((get_case("fib"), get_case("fib")))
        ]

    ref = handles()
    EpochMultiplexer(ref, dispatch="masked").run()

    ctl = DispatchController()
    got = handles()
    EpochMultiplexer(got, dispatch="auto", controller=ctl).run()
    assert sum(ctl.decisions.values()) > 0
    assert ctl.decisions["gather"] > 0, (
        f"sparse fused fleet should pick gather, got {ctl.decisions}"
    )
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(
            np.asarray(r.result.value), np.asarray(g.result.value)
        )
        assert r.result.stats.epochs == g.result.stats.epochs


def test_host_auto_solo_dense_stays_masked_end_to_end():
    """A solo HostEngine frontier is span-sized (no cross-region holes):
    the controller must keep paying the single masked launch."""
    from repro.apps import get_case
    from repro.core.engine import HostEngine

    case = get_case("fib")
    ctl = DispatchController()
    eng = HostEngine(case.program, capacity=case.capacity,
                     dispatch="auto", controller=ctl)
    eng.run(case.initial, heap_init=dict(case.heap_init))
    assert ctl.decisions["masked"] == sum(ctl.decisions.values())
