"""Multi-device distributed tests (pipeline parallelism, compressed
all-reduce, elastic resharding).  Each runs in a subprocess with forced host
devices so the main test process keeps its single-device jax config."""
import pathlib
import subprocess
import sys

import pytest

from conftest import multidevice_skip

_SKIP, _REASON = multidevice_skip(required=4)
pytestmark = pytest.mark.skipif(_SKIP, reason=_REASON)

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def _run(script: str, devices: int = 4) -> str:
    env = {
        "PYTHONPATH": SRC,
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PATH": "/usr/bin:/bin",
        "HOME": "/tmp",
    }
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=480, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_gpipe_matches_sequential():
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
mesh = jax.make_mesh((4,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
from repro.distributed import gpipe_apply
rng = np.random.RandomState(0)
ws = jnp.asarray(rng.normal(size=(4, 8, 8)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(6, 2, 8)), jnp.float32)
got = gpipe_apply(lambda w, h: jnp.tanh(h @ w), ws, x, mesh, axis="pod")
want = x
for i in range(4):
    want = jnp.tanh(want @ ws[i])
err = float(jnp.abs(got - want).max())
assert err < 1e-6, err
print("PIPE_OK", err)
"""
    )
    assert "PIPE_OK" in out


def test_compressed_allreduce_int8_and_bf16():
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
mesh = jax.make_mesh((4,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
from repro.distributed import compressed_grad_allreduce
from repro.distributed.compression import CompressionState
rng = np.random.RandomState(0)
g = {"w": jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)}
resid0 = {"w": jnp.zeros((4, 64), jnp.float32)}
def f(gs, rs):
    out, st = compressed_grad_allreduce(
        {"w": gs["w"][0]}, ("data",), "int8",
        CompressionState(residual={"w": rs["w"][0]}))
    return out, {"w": st.residual["w"][None]}
out, resid = jax.shard_map(f, mesh=mesh,
    in_specs=({"w": P("data")}, {"w": P("data")}),
    out_specs=({"w": P()}, {"w": P("data")}))(g, resid0)
want = g["w"].mean(0)
err = float(jnp.abs(out["w"] - want).max())
bound = float(jnp.abs(g["w"]).max() / 127) + 1e-6
assert err <= bound, (err, bound)
# error feedback residual: reapplying next step corrects the bias
assert float(jnp.abs(resid["w"]).max()) > 0
out2, _ = jax.shard_map(
    lambda gs, rs: compressed_grad_allreduce({"w": gs["w"][0]}, ("data",), "bf16", None),
    mesh=mesh, in_specs=({"w": P("data")}, {"w": P("data")}),
    out_specs=({"w": P()}, None))(g, resid0)
err2 = float(jnp.abs(out2["w"] - want).max())
assert err2 < 2e-2, err2
print("COMPRESS_OK", err, err2)
"""
    )
    assert "COMPRESS_OK" in out


def test_elastic_reshard_across_meshes():
    """Save under a (2,2) mesh, restore onto a (4,1) mesh — elastic."""
    out = _run(
        """
import tempfile, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpointing import CheckpointManager, restore_resharded
from repro.models.common import ShardingRules
from repro.launch.mesh import rules_for_mesh

mesh_a = jax.make_mesh((2, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
mesh_b = jax.make_mesh((4, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
axes = {"w": ("embed", "mlp"), "b": ("mlp",)}
rules_a = rules_for_mesh(mesh_a)
rules_b = rules_for_mesh(mesh_b)
w = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                   NamedSharding(mesh_a, P(None, "model")))
b = jax.device_put(np.arange(8, dtype=np.float32),
                   NamedSharding(mesh_a, P("model")))
d = tempfile.mkdtemp()
mgr = CheckpointManager(d)
mgr.save(5, {"w": w, "b": b})
step, params, _ = restore_resharded(mgr, axes, mesh_b, rules_b)
assert step == 5
np.testing.assert_array_equal(np.asarray(params["w"]), np.asarray(w))
np.testing.assert_array_equal(np.asarray(params["b"]), np.asarray(b))
assert params["w"].sharding.mesh.shape == {"data": 4, "model": 1}
print("ELASTIC_OK")
"""
    )
    assert "ELASTIC_OK" in out


def test_multidevice_train_step_with_mesh():
    """End-to-end sharded train step on a 2x2 mesh (TP+DP+ZeRO-1)."""
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch.mesh import rules_for_mesh, param_shardings
from repro.models.common import finalize, sharding_ctx
from repro.models.model import init_model, loss_fn
from repro.optim import AdamW
from repro.data import SyntheticLM, place_batch
from jax.sharding import NamedSharding

mesh = jax.make_mesh((2, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = finalize(configs.get_reduced("granite_3_8b"), 2)
rules = rules_for_mesh(mesh)
pspecs, axes = param_shardings(cfg, mesh, rules)
params, _ = init_model(cfg, jax.random.PRNGKey(0))
params = {k: jax.device_put(v, pspecs[k].sharding) for k, v in params.items()}
opt = AdamW(lr=1e-3)
opt_state = opt.init(params)
def step(p, s, b):
    with sharding_ctx(mesh, rules):
        (l, m), g = jax.value_and_grad(lambda p_: loss_fn(p_, cfg, b), has_aux=True)(p)
        return opt.update(p, g, s) + (l,)
data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4)
b = place_batch(data.batch_at(0), mesh)
p2, s2, om, l0 = jax.jit(step)(params, opt_state, b)
b = place_batch(data.batch_at(1), mesh)
p3, s3, om, l1 = jax.jit(step)(p2, s2, b)
assert np.isfinite(float(l0)) and np.isfinite(float(l1))
print("MESH_TRAIN_OK", float(l0), float(l1))
"""
    )
    assert "MESH_TRAIN_OK" in out
