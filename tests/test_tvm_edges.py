"""Commit-phase edge paths in ``core/tvm.py``: trailing-invalid reclamation,
TV-capacity overflow, and the fork_scan / type_rank kernels against jnp
prefix-sum references on non-block-multiple lengths (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeviceEngine,
    EngineError,
    HeapVar,
    HostEngine,
    InitialTask,
    Program,
    TaskType,
)
from repro.core import tvm

RNG = np.random.RandomState(7)


def _burst_program(n_kids: int):
    """Root forks ``n_kids`` leaves and joins; leaves emit and die."""

    def _root(ctx):
        for _ in range(n_kids):
            ctx.fork("leaf")
        ctx.join("gather")

    def _leaf(ctx):
        ctx.emit(1)

    def _gather(ctx):
        cv = ctx.child_values(n_kids)
        ctx.emit(cv[:, 0].sum())

    return Program(
        name="burst",
        tasks=(
            TaskType("root", _root),
            TaskType("leaf", _leaf),
            TaskType("gather", _gather),
        ),
        n_arg_i=1,
        value_width=1,
        value_dtype=jnp.int32,
    )


def _run_epoch(program, state, heap, start, count, cen):
    P = 16
    idx = start + jnp.arange(P, dtype=jnp.int32)
    in_range = jnp.arange(P, dtype=jnp.int32) < count
    cidx = jnp.clip(idx, 0, state.capacity - 1)
    active = in_range & (state.epoch[cidx] == cen)
    per_type, _ = tvm.trace_tasks(program, state, heap, idx, active)
    return tvm.commit_epoch(program, state, heap, idx, active, per_type,
                            jnp.asarray(cen, jnp.int32))


def test_trailing_invalid_reclamation_shrinks_next_free():
    """Paper §5.3: when the lanes at the top of the TV die, nextFreeCore
    must decrease so the slots are reused by later epochs."""
    prog = _burst_program(3)
    state = tvm.init_state(prog, 64, InitialTask(task="root"))
    heap = {}
    # epoch 1: root forks 3 leaves into slots 1..3, joins (stays valid)
    state, heap, summary, _ = _run_epoch(prog, state, heap, 0, 1, 1)
    assert int(summary.total_forks) == 3
    assert int(state.next_free) == 4
    # epoch 2: the 3 leaves emit and die -> only slot 0 stays valid, so the
    # trailing-invalid scan must pull next_free back from 4 to 1
    state, heap, summary, _ = _run_epoch(prog, state, heap, 1, 3, 2)
    assert int(summary.total_forks) == 0
    assert int(state.next_free) == 1
    assert int(state.epoch[0]) == 1  # joined root still eligible
    # epoch 1 again: gather sums the children (their values survive death)
    state, heap, summary, _ = _run_epoch(prog, state, heap, 0, 1, 1)
    assert int(state.value[0, 0]) == 3
    assert int(state.next_free) == 0  # everything dead: full reclamation


def test_reclamation_bounds_peak_tv_through_engine():
    """End to end: repeated fork bursts reuse reclaimed slots instead of
    accumulating, so peak TV stays near one burst's width."""

    def _driver(ctx):
        step = ctx.argi(0)
        for _ in range(4):
            ctx.fork("leaf", where=step < 8)
        ctx.join("next", argi=(step,), where=step < 8)

    def _leaf(ctx):
        ctx.emit(1)

    def _next(ctx):
        ctx.fork("driver", argi=(ctx.argi(0) + 1,))
        ctx.join("done", where=False)

    def _done(ctx):
        ctx.emit(0)

    prog = Program(
        name="bursts",
        tasks=(
            TaskType("driver", _driver), TaskType("leaf", _leaf),
            TaskType("next", _next), TaskType("done", _done),
        ),
        n_arg_i=1,
    )
    _, _, stats = HostEngine(prog, capacity=1 << 10).run(
        InitialTask(task="driver", argi=(0,))
    )
    # Without reclamation the 8 bursts' 40 forks would need 41 slots (every
    # child a fresh slot).  Reclamation is trailing-only (§5.3), so the dead
    # prefix drifts by one driver slot per generation — peak stays ~burst
    # width + generation count, far below the cumulative fork total.
    assert stats.total_forks == 40
    assert stats.peak_tv_slots < stats.total_forks
    assert stats.peak_tv_slots <= 13


def test_tv_overflow_raises_host():
    prog = _burst_program(8)
    with pytest.raises(EngineError, match="overflow"):
        HostEngine(prog, capacity=4).run(InitialTask(task="root"))


def test_tv_overflow_sets_summary_flag():
    prog = _burst_program(8)
    state = tvm.init_state(prog, 4, InitialTask(task="root"))
    _, _, summary, _ = _run_epoch(prog, state, {}, 0, 1, 1)
    assert bool(summary.overflow)


def test_tv_overflow_raises_device():
    from repro.apps import fib

    with pytest.raises(EngineError, match="exhausted"):
        DeviceEngine(fib.PROGRAM, capacity=16, stack_depth=64).run(
            fib.initial(12)
        )


# ---------------------------------------------------------------- kernels
@pytest.mark.parametrize("n", [1, 5, 127, 255, 1000, 1025])
def test_fork_scan_non_block_multiple_vs_cumsum(n):
    """Pallas fork_scan (interpret mode) vs the jnp.cumsum reference on
    lengths that do not divide the kernel block."""
    from repro.kernels.fork_compact import fork_scan

    x = RNG.randint(0, 5, n).astype(np.int32)
    offs, total = fork_scan(jnp.asarray(x), block=256, interpret=True)
    want = np.cumsum(x) - x
    np.testing.assert_array_equal(np.asarray(offs), want)
    assert int(total) == int(x.sum())


@pytest.mark.parametrize("n", [1, 9, 250, 257, 1023])
def test_type_rank_non_block_multiple_vs_cumsum(n):
    """Pallas type_rank (interpret mode) vs a per-type jnp.cumsum reference
    on non-block-multiple lengths."""
    from repro.kernels.fork_compact import type_rank

    T = 3
    t = RNG.randint(0, T, n).astype(np.int32)
    a = RNG.rand(n) < 0.6
    rank, counts = type_rank(
        jnp.asarray(t), jnp.asarray(a), T, block=256, interpret=True
    )
    rank, counts = np.asarray(rank), np.asarray(counts)
    for tt in range(T):
        m = (t == tt) & a
        excl = np.cumsum(m.astype(np.int64)) - m
        np.testing.assert_array_equal(rank[m], excl[m])
        assert counts[tt] == m.sum()
    assert (rank[~a] == -1).all()


def test_compact_types_is_a_bijection_onto_actives():
    """compact_types' permutation must cover exactly the active lanes."""
    from repro.apps import fib

    prog = fib.PROGRAM
    state = tvm.init_state(prog, 64, fib.initial(5))
    # manufacture a mixed-type population
    state = tvm.TVMState(
        task=state.task.at[1:5].set(jnp.asarray([1, 0, 1, 0])),
        argi=state.argi, argf=state.argf,
        epoch=state.epoch.at[1:5].set(1),
        value=state.value, child_base=state.child_base,
        child_count=state.child_count, next_free=jnp.asarray(5, jnp.int32),
    )
    idx = jnp.arange(8, dtype=jnp.int32)
    active = (idx < 5) & (state.epoch[idx] == 1)
    perm, counts = tvm.compact_types(prog, state, idx, active)
    perm, counts = np.asarray(perm), np.asarray(counts)
    n_active = int(np.asarray(active).sum())
    assert counts.sum() == n_active
    # the first n_active perm entries are a permutation of the active lanes
    got = sorted(perm[:n_active].tolist())
    want = sorted(np.nonzero(np.asarray(active))[0].tolist())
    assert got == want
    assert (perm[n_active:] == -1).all()
    # and same-type lanes are contiguous: counts[0] fib lanes first
    types = np.asarray(state.task)[perm[:n_active]]
    assert (np.diff(types) >= 0).all()
