"""Minimal deterministic stand-in for ``hypothesis`` (dev-only fallback).

The real dependency lives in ``requirements-dev.txt``; this stub exists so
the tier-1 suite *collects and runs everywhere*, including hermetic
containers where nothing can be pip-installed.  It implements just the
surface this repo's property tests use — ``given`` (positional + keyword
strategies), ``settings(max_examples=, deadline=)``, ``strategies.integers``
and ``strategies.lists`` — drawing a fixed number of pseudo-random examples
from a seeded PRNG.  No shrinking, no database: deterministic smoke coverage
rather than true property search.  ``tests/conftest.py`` installs it into
``sys.modules`` only when the real hypothesis is missing.
"""
from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int = 0, max_value: int = 1 << 16) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          **_kw) -> _Strategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.draw(rnd) for _ in range(n)]

    return _Strategy(draw)


def booleans() -> _Strategy:
    return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rnd: tuple(e.draw(rnd) for e in elements))


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rnd: rnd.choice(options))


def settings(max_examples: int = 10, deadline=None, **_kw):
    """Decorator recording max_examples on the (already-wrapped) test fn."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*pos_strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the test body over N deterministic draws of the strategies."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rnd = random.Random(0xC0FFEE)
            n = getattr(wrapper, "_stub_max_examples", 10)
            for _ in range(n):
                drawn = [s.draw(rnd) for s in pos_strategies]
                drawn_kw = {k: s.draw(rnd) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        # hide strategy-supplied parameters from pytest's fixture resolution:
        # positional strategies fill the leading params, keyword strategies
        # fill by name; whatever remains (e.g. real fixtures) stays visible
        params = list(inspect.signature(fn).parameters.values())
        remaining = [
            p for p in params[len(pos_strategies):]
            if p.name not in kw_strategies
        ]
        wrapper.__signature__ = inspect.Signature(remaining)
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.lists = lists
strategies.booleans = booleans
strategies.tuples = tuples
strategies.sampled_from = sampled_from

HealthCheck = types.SimpleNamespace(too_slow="too_slow", filter_too_much="filter_too_much")
