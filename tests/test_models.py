"""Per-architecture smoke tests: reduced configs, one forward/train/decode
step on CPU, asserting output shapes and no NaNs (full configs are exercised
only by the dry-run)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode_step, forward, init_cache, init_model, loss_fn
from repro.models.common import finalize

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.encdec:
        batch["enc_frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_len, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_loss(arch):
    cfg = configs.get_reduced(arch)
    params, axes = init_model(cfg, KEY)
    assert set(params) == set(axes)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    hidden, aux = forward(
        params, cfg, batch["tokens"], enc_frames=batch.get("enc_frames")
    )
    assert hidden.shape == (2, 16, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One SGD step must produce finite grads for every parameter."""
    cfg = configs.get_reduced(arch)
    params, _ = init_model(cfg, KEY)
    batch = _batch(cfg)

    def loss_of(p):
        return loss_fn(p, cfg, batch)[0]

    grads = jax.jit(jax.grad(loss_of))(params)
    for k, g in grads.items():
        assert np.isfinite(np.asarray(g, np.float32)).all(), (arch, k)
    # params actually move
    moved = any(
        float(jnp.abs(g).max()) > 0 for g in grads.values()
    )
    assert moved, arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = configs.get_reduced(arch)
    params, _ = init_model(cfg, KEY)
    B = 2
    cache = init_cache(cfg, B, max_len=32)
    if cfg.encdec:
        cache["enc_out"] = jax.random.normal(
            KEY, (B, cfg.encoder_len, cfg.d_model), cfg.compute_dtype
        )
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    logits, cache = step(params, tok, cache)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["lengths"][0]) == 1
    logits2, cache = step(params, tok, cache)
    assert int(cache["lengths"][0]) == 2
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_matches_prefill_causal():
    """Token-by-token decode must match the teacher-forced forward pass."""
    cfg = configs.get_reduced("granite_3_8b")
    params, _ = init_model(cfg, KEY)
    B, S = 1, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    hidden, _ = forward(params, cfg, tokens, remat=False)
    from repro.models.layers import logits_fn

    full_logits = logits_fn(params, cfg, hidden)  # (B, S, Vp)
    cache = init_cache(cfg, B, max_len=S + 1)
    outs = []
    for t in range(S):
        lg, cache = decode_step(params, cfg, tokens[:, t : t + 1], cache)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2,  # bf16 compute
    )


def test_head_padding_is_function_preserving():
    """Padding heads/vocab for TP divisibility must not change outputs."""
    cfg = configs.get_reduced("yi_34b")
    cfgp = finalize(cfg, model_axis_size=8)  # pads 4 heads -> 8
    assert cfgp.n_heads_padded == 8 and cfg.n_heads == 4
    params, _ = init_model(cfgp, KEY)
    B, S = 1, 8
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    hidden, _ = forward(params, cfgp, tokens, remat=False)
    # zero out everything the padded heads could have contributed: output
    # must be identical since padded heads are masked before wo
    p2 = dict(params)
    hd = cfgp.resolved_head_dim
    wo = np.array(params["layers/attn/wo"], np.float32)  # writable copy
    wo[:, cfg.n_heads * hd :, :] = 1e6  # poison padded-head rows
    p2["layers/attn/wo"] = jnp.asarray(wo, params["layers/attn/wo"].dtype)
    hidden2, _ = forward(p2, cfgp, tokens, remat=False)
    np.testing.assert_allclose(
        np.asarray(hidden, np.float32), np.asarray(hidden2, np.float32),
        rtol=1e-5, atol=1e-5,
    )


def test_moe_dispatch_capacity_and_balance():
    """MoE layer: dropped tokens fall back to residual; aux loss finite."""
    cfg = configs.get_reduced("granite_moe_1b_a400m")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5)
    )
    params, _ = init_model(cfg, KEY)
    batch = _batch(cfg, B=2, S=32)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["aux"]) > 0
