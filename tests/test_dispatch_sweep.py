"""Property-style bit-identity sweep across every dispatch mode and K.

One random mixed fleet per example, run six ways: the host multiplexer
under ``masked`` / ``compacted`` / ``gather`` dispatch, and the chunked
resident driver at K ∈ {1, 4, ∞} (sharing one wave template per example —
the chunk bound is a dynamic argument, so all three K choices re-enter one
compiled loop).  Every run must be bit-identical per job: same TV value
block, same heap, same solo-comparable epoch count.  Uses hypothesis when
installed, else the deterministic stub (``tests/_hypothesis_stub.py``).
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.apps import get_case
from repro.service import (
    DeviceMultiplexer,
    EpochMultiplexer,
    Job,
    JobHandle,
    WaveTemplate,
)

_POOL = ("fib", "treewalk")
_QUOTAS = (512, 1024)  # >= every pool member's peak TV residency


def _handles(fleet):
    return [
        JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                         quota=q, name=f"{c.name}#{i}"))
        for i, (c, q) in enumerate(fleet)
    ]


def _snapshot(handles):
    out = []
    for h in handles:
        assert h.status.value == "done", (h.job.name, h.error)
        out.append((
            np.asarray(h.result.value),
            {k: np.asarray(v) for k, v in sorted(h.result.heap.items())},
            h.result.stats.epochs,
            h.result.stats.tasks_executed,
        ))
    return out


def _assert_same(ref, got, label):
    assert len(ref) == len(got)
    for i, (rv, rh, re, rt) in enumerate(ref):
        gv, gh, ge, gt = got[i]
        np.testing.assert_array_equal(gv, rv, err_msg=f"{label}:job{i}:value")
        assert set(gh) == set(rh)
        for k in rh:
            np.testing.assert_array_equal(
                gh[k], rh[k], err_msg=f"{label}:job{i}:{k}"
            )
        assert ge == re, f"{label}:job{i}:epochs"
        assert gt == rt, f"{label}:job{i}:tasks"


@settings(max_examples=3, deadline=None)
@given(members=st.lists(
    st.tuples(st.sampled_from(_POOL), st.sampled_from(_QUOTAS)),
    min_size=2, max_size=3,
))
def test_all_dispatch_modes_and_chunks_bit_identical(members):
    fleet = [(get_case(name), q) for name, q in members]

    handles = _handles(fleet)
    EpochMultiplexer(handles, dispatch="masked").run()
    ref = _snapshot(handles)

    for dispatch in ("compacted", "gather"):
        handles = _handles(fleet)
        EpochMultiplexer(handles, dispatch=dispatch).run()
        _assert_same(ref, _snapshot(handles), f"host:{dispatch}")

    template = None
    for chunk in (1, 4, None):
        handles = _handles(fleet)
        mux = DeviceMultiplexer(handles, chunk=chunk, template=template)
        if template is None:
            template = WaveTemplate(
                key=None, program=mux.program, slots=mux.slots, loop=mux.loop
            )
        mux.run()
        _assert_same(ref, _snapshot(handles), f"device:K={chunk}")
