"""Property-style bit-identity sweep across every dispatch mode and K.

One random mixed fleet per example, run every way the runtime offers: the
host multiplexer under ``masked`` / ``compacted`` / ``gather`` dispatch,
then the chunked resident driver over the full configuration lattice
``megakernel ∈ {False, True} × dispatch ∈ {masked, gather} × K ∈ {1, 4,
∞}`` (one wave template per (megakernel, dispatch) cell — the chunk bound
is a dynamic argument, so all three K choices re-enter one compiled
loop; the megakernel cells run the chunk inside one persistent Pallas
kernel, interpret mode on CPU).  Every run must be bit-identical per
job: same TV value block, same heap, same solo-comparable epoch count.
Uses hypothesis when installed, else the deterministic stub
(``tests/_hypothesis_stub.py``).  A separate zero-retrace guard drives
identical consecutive megakernel waves through ``JobService`` and pins
``trace_count`` flat on the second wave.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.apps import get_case
from repro.service import (
    DeviceMultiplexer,
    EpochMultiplexer,
    Job,
    JobHandle,
    JobService,
    WaveTemplate,
)

_POOL = ("fib", "treewalk")
_QUOTAS = (512, 1024)  # >= every pool member's peak TV residency


def _handles(fleet):
    return [
        JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                         quota=q, name=f"{c.name}#{i}"))
        for i, (c, q) in enumerate(fleet)
    ]


def _snapshot(handles):
    out = []
    for h in handles:
        assert h.status.value == "done", (h.job.name, h.error)
        out.append((
            np.asarray(h.result.value),
            {k: np.asarray(v) for k, v in sorted(h.result.heap.items())},
            h.result.stats.epochs,
            h.result.stats.tasks_executed,
        ))
    return out


def _assert_same(ref, got, label):
    assert len(ref) == len(got)
    for i, (rv, rh, re, rt) in enumerate(ref):
        gv, gh, ge, gt = got[i]
        np.testing.assert_array_equal(gv, rv, err_msg=f"{label}:job{i}:value")
        assert set(gh) == set(rh)
        for k in rh:
            np.testing.assert_array_equal(
                gh[k], rh[k], err_msg=f"{label}:job{i}:{k}"
            )
        assert ge == re, f"{label}:job{i}:epochs"
        assert gt == rt, f"{label}:job{i}:tasks"


@settings(max_examples=3, deadline=None)
@given(members=st.lists(
    st.tuples(st.sampled_from(_POOL), st.sampled_from(_QUOTAS)),
    min_size=2, max_size=3,
))
def test_all_dispatch_modes_and_chunks_bit_identical(members):
    fleet = [(get_case(name), q) for name, q in members]

    handles = _handles(fleet)
    EpochMultiplexer(handles, dispatch="masked").run()
    ref = _snapshot(handles)

    for dispatch in ("compacted", "gather", "auto"):
        handles = _handles(fleet)
        EpochMultiplexer(handles, dispatch=dispatch).run()
        _assert_same(ref, _snapshot(handles), f"host:{dispatch}")

    for megakernel in (False, True):
        for dispatch in ("masked", "gather"):
            template = None
            for chunk in (1, 4, None):
                handles = _handles(fleet)
                mux = DeviceMultiplexer(
                    handles, dispatch=dispatch, chunk=chunk,
                    template=template, megakernel=megakernel,
                    megakernel_impl="interpret" if megakernel else "auto",
                )
                if template is None:
                    template = WaveTemplate(
                        key=None, program=mux.program, slots=mux.slots,
                        loop=mux.loop,
                    )
                mux.run()
                _assert_same(
                    ref, _snapshot(handles),
                    f"device:mega={megakernel}:{dispatch}:K={chunk}",
                )

    # the scale-out axis: the same fleet through P TVM shards (vmap
    # fallback on one device — bit-identical to the mesh path by
    # construction) must land on the same bits as every solo cell
    from repro.distributed import ShardedFleet

    for shards in (1, 2):
        handles = _handles(fleet)
        ShardedFleet(handles, shards=shards, chunk=4).run()
        _assert_same(ref, _snapshot(handles), f"sharded:P={shards}")

    # the self-tuning axis: dispatch="auto" + chunk="auto" through the
    # service front door must land on the same bits as every static cell
    svc = JobService(
        capacity=sum(q for _, q in fleet), max_jobs=len(fleet),
        engine="device", dispatch="auto", chunk="auto",
    )
    handles = [
        svc.submit(c.program, c.initial, heap_init=dict(c.heap_init),
                   quota=q, name=f"auto#{i}")
        for i, (c, q) in enumerate(fleet)
    ]
    svc.drain()
    _assert_same(ref, _snapshot(handles), "device:auto:K=auto")


def test_megakernel_waves_zero_retrace():
    """Identical consecutive megakernel waves reuse one compiled template:
    the second wave leaves ``JobService.trace_count`` unchanged (and the
    template cache reports the hit)."""
    from repro.apps import fib

    svc = JobService(capacity=512, max_jobs=2, engine="device", chunk=2,
                     megakernel=True, megakernel_impl="interpret")
    first = [svc.submit(fib.PROGRAM, fib.initial(n), quota=256)
             for n in (8, 9)]
    svc.drain()
    traced = svc.trace_count
    assert traced > 0
    assert svc.template_cache.misses == 1
    second = [svc.submit(fib.PROGRAM, fib.initial(n), quota=256)
              for n in (8, 9)]
    svc.drain()
    assert svc.trace_count == traced, (
        "identical consecutive megakernel waves must not retrace"
    )
    assert svc.template_cache.hits >= 1
    for h, n in zip(first + second, (8, 9, 8, 9)):
        assert int(np.asarray(h.result.value)[0, 0]) == fib.fib_reference(n)


def test_chunk_auto_zero_retrace_under_k_adaptation():
    """chunk="auto" adapts K between boundaries (the controller widens
    while completions don't surface), yet every K re-enters the same
    compiled chunk template: ``trace_count`` stays flat after the first
    wave — K only ever feeds the loop's *dynamic* epoch bound — and an
    identical consecutive auto wave stays flat too."""
    from repro.apps import fib

    svc = JobService(capacity=512, max_jobs=2, engine="device",
                     dispatch="auto", chunk="auto")
    first = [svc.submit(fib.PROGRAM, fib.initial(n), quota=256)
             for n in (8, 9)]
    svc.drain()
    assert svc.chunk_controller.widened > 0, (
        "a wave with no early completions must widen K"
    )
    traced = svc.trace_count
    assert traced > 0
    second = [svc.submit(fib.PROGRAM, fib.initial(n), quota=256)
              for n in (8, 9)]
    svc.drain()
    assert svc.trace_count == traced, (
        "K adaptation and an identical consecutive auto wave must not "
        "retrace"
    )
    assert svc.template_cache.hits >= 1
    for h, n in zip(first + second, (8, 9, 8, 9)):
        assert int(np.asarray(h.result.value)[0, 0]) == fib.fib_reference(n)


def test_megakernel_template_mismatch_rejected():
    """A cached chunk template bakes its dispatch + chunk driver into the
    traced loop: reusing it under a different configuration is refused."""
    import pytest

    fleet = [(get_case("fib"), 512), (get_case("treewalk"), 512)]
    mux = DeviceMultiplexer(_handles(fleet))
    template = WaveTemplate(
        key=None, program=mux.program, slots=mux.slots, loop=mux.loop
    )
    with pytest.raises(ValueError, match="dispatch"):
        DeviceMultiplexer(_handles(fleet), dispatch="gather",
                          template=template)
    with pytest.raises(ValueError, match="megakernel"):
        DeviceMultiplexer(_handles(fleet), megakernel=True,
                          template=template)
