"""Device-resident fleet execution tests (DESIGN.md §9).

The load-bearing property, extending the host-mux equivalence harness of
``test_service.py`` to the resident path: running an entire admitted wave to
completion inside one ``lax.while_loop`` (``DeviceMultiplexer``) must be
*observationally invisible* to each tenant — per-job heaps, TV-value blocks,
and solo-comparable work stats bit-identical to a solo ``HostEngine.run``
with ``capacity=quota`` — while the whole wave pays O(1) critical-path
overhead: exactly one dispatch and one scalar readback.
"""
import numpy as np
import pytest

from repro.apps import fib, get_fleet
from repro.core import HostEngine
from repro.service import (
    DeviceMultiplexer,
    Job,
    JobFailure,
    JobHandle,
    JobService,
    JobStatus,
)


def _solo(case, quota):
    eng = HostEngine(case.program, capacity=quota)
    return eng.run(case.initial, heap_init=dict(case.heap_init) or None)


def _handles(fleet):
    return [
        JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                         quota=q, name=c.name))
        for i, (c, q) in enumerate(fleet)
    ]


# ---------------------------------------------- the acceptance equivalence
@pytest.mark.parametrize("fleet_name", ["mixed3", "mixed4", "fib_fleet"])
def test_device_wave_bit_identical_with_o1_vinf(fleet_name):
    """Acceptance: every registry fleet through the resident wave driver is
    bit-identical per job to solo runs (heaps, TV value blocks, and the
    solo-comparable stats), with fleet dispatches + scalar_transfers == 2 —
    O(1) for the whole wave, independent of epoch count."""
    fleet = get_fleet(fleet_name)
    solo = {c.name: _solo(c, q) for c, q in fleet}

    handles = _handles(fleet)
    mux = DeviceMultiplexer(handles)
    done = mux.step()
    assert {h.job_id for h in done} == {h.job_id for h in handles}

    for h in handles:
        sh, sv, ss = solo[h.job.name]
        assert h.status is JobStatus.DONE
        np.testing.assert_array_equal(
            np.asarray(h.result.value), np.asarray(sv),
            err_msg=f"{h.job.name}:value",
        )
        assert set(h.result.heap) == set(sh)
        for k in sh:
            np.testing.assert_array_equal(
                np.asarray(h.result.heap[k]), np.asarray(sh[k]),
                err_msg=f"{h.job.name}:{k}",
            )
        # per-job work accounting matches the solo run exactly
        assert h.result.stats.epochs == ss.epochs
        assert h.result.stats.tasks_executed == ss.tasks_executed
        assert h.result.stats.total_forks == ss.total_forks
        assert h.result.stats.peak_tv_slots == ss.peak_tv_slots
        # the whole wave rode exactly one dispatch + one readback
        assert h.result.stats.shared_dispatches == 1
        assert h.result.stats.shared_transfers == 1

    fs = mux.stats()
    assert fs.dispatches == 1 and fs.scalar_transfers == 1
    # resident global epochs = max over members (every live region pops
    # every iteration, the fuse_all schedule); sum over *members*, not
    # names — homogeneous fleets repeat the same case
    member_epochs = [solo[c.name][2].epochs for c, _ in fleet]
    assert fs.epochs == max(member_epochs)
    assert fs.ranges_coalesced == sum(member_epochs) - fs.epochs


def test_device_wave_map_waste_is_measurable():
    """Resident map payloads launch at MapType.max_domain; the divergence
    from the live domains must surface in RunStats, not stay silent."""
    fleet = get_fleet("mixed4")  # mergesort schedules bulk map payloads
    mux = DeviceMultiplexer(_handles(fleet))
    mux.step()
    fs = mux.stats()
    assert fs.map_launches > 0
    assert fs.map_elements > 0
    assert fs.map_lanes_launched > fs.map_elements
    assert fs.map_lanes_wasted == fs.map_lanes_launched - fs.map_elements
    assert 0.0 < fs.map_utilization < 1.0
    # the host-loop driver sizes payloads to live-domain buckets: strictly
    # fewer wasted lanes for the same work
    case = [c for c, _ in fleet if c.name == "mergesort"][0]
    _, _, hs = HostEngine(case.program, capacity=512).run(
        case.initial, heap_init=dict(case.heap_init) or None
    )
    assert hs.map_elements > 0
    assert hs.map_lanes_launched >= hs.map_elements
    assert hs.map_lanes_wasted < fs.map_lanes_wasted


# --------------------------------------------------- failure isolation
def test_device_wave_overflow_fails_only_that_job():
    """A region overflowing inside the resident loop zeroes its own stack
    pointer and fails alone; its neighbour's result is untouched."""
    bad = JobHandle(0, Job(fib.PROGRAM, fib.initial(12), quota=8, name="bad"))
    good = JobHandle(
        1, Job(fib.PROGRAM, fib.initial(10), quota=512, name="good")
    )
    mux = DeviceMultiplexer([bad, good])
    mux.step()
    assert bad.status is JobStatus.FAILED
    assert isinstance(bad.error, JobFailure)
    assert good.status is JobStatus.DONE
    _, sv, ss = HostEngine(fib.PROGRAM, capacity=512).run(fib.initial(10))
    np.testing.assert_array_equal(
        np.asarray(good.result.value), np.asarray(sv)
    )
    assert good.result.stats.epochs == ss.epochs


def test_device_wave_is_closed_to_midflight_admission():
    """The O(1)-readback trade: the host never sees a freed region until
    the wave drains, so admit() must refuse mid-flight reuse."""
    mux = DeviceMultiplexer(
        [JobHandle(0, Job(fib.PROGRAM, fib.initial(8), quota=128))]
    )
    late = JobHandle(1, Job(fib.PROGRAM, fib.initial(8), quota=128))
    assert mux.admit(late) is False
    mux.step()
    assert mux.admit(late) is False  # still closed after completion
    assert mux.step() == []  # the wave runs once


def test_device_multiplexer_rejects_compacted():
    with pytest.raises(ValueError, match="masked"):
        DeviceMultiplexer(
            [JobHandle(0, Job(fib.PROGRAM, fib.initial(8), quota=64))],
            dispatch="compacted",
        )


# --------------------------------------------------- service integration
def test_service_device_engine_runs_waves():
    """JobService(engine='device'): each wave is one resident loop; fleet
    dispatches count the number of waves, not the number of epochs."""
    svc = JobService(capacity=1024, max_jobs=2, engine="device")
    ns = (8, 9, 10, 11, 12)
    handles = [
        svc.submit(fib.PROGRAM, fib.initial(n), quota=512, name=f"fib{n}")
        for n in ns
    ]
    done = svc.drain()
    assert {h.job_id for h in done} == {h.job_id for h in handles}
    for h, n in zip(handles, ns):
        assert h.status is JobStatus.DONE
        assert int(np.asarray(h.result.value)[0, 0]) == fib.fib_reference(n)
    fs = svc.stats()
    # 5 jobs, 2 regions per wave -> 3 waves -> 3 dispatches + 3 readbacks
    assert fs.dispatches == 3
    assert fs.scalar_transfers == 3


def test_service_device_engine_rejects_host_only_options():
    with pytest.raises(ValueError, match="masked"):
        JobService(engine="device", dispatch="compacted")
    with pytest.raises(ValueError, match="fuse_all"):
        JobService(engine="device", pop_policy="round_robin")
    with pytest.raises(ValueError, match="fuse_all"):
        JobService(engine="device", gang=2)
    with pytest.raises(ValueError, match="host"):
        JobService(engine="tpu")


def test_service_device_engine_result_single_job():
    svc = JobService(capacity=512, engine="device")
    h = svc.submit(fib.PROGRAM, fib.initial(9), quota=256)
    res = svc.result(h)
    assert int(np.asarray(res.value)[0, 0]) == fib.fib_reference(9)
    assert res.stats.shared_dispatches == 1
