"""Application-level tests: every paper workload vs its native reference."""
import numpy as np
import pytest

from repro.apps import bfs, fft, fib, matmul, mergesort, nqueens, sssp
from repro.apps.baselines import bitonic, worklist
from repro.core import HostEngine


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("n", [16, 96])
def test_bfs_matches_reference_and_worklist(n, seed):
    adj_off, adj = bfs.random_graph(n, avg_degree=4, seed=seed)
    ref = bfs.bfs_reference(adj_off, adj, 0, n)
    prog = bfs.make_program(n, len(adj))
    heap, _, _ = HostEngine(prog, capacity=1 << 14).run(
        bfs.initial(0), heap_init=bfs.heap_init(adj_off, adj, n)
    )
    np.testing.assert_array_equal(np.asarray(heap["dist"]), ref)
    wl, _ = worklist.bfs_worklist(adj_off, adj, 0, n)
    np.testing.assert_array_equal(np.asarray(wl), ref)


@pytest.mark.parametrize("n", [16, 64])
def test_sssp_matches_reference_and_worklist(n):
    adj_off, adj = bfs.random_graph(n, avg_degree=4, seed=7)
    wgt = sssp.random_weights(len(adj), seed=2)
    ref = sssp.sssp_reference(adj_off, adj, wgt, 0, n)
    prog = sssp.make_program(n, len(adj))
    heap, _, _ = HostEngine(prog, capacity=1 << 14).run(
        sssp.initial(0), heap_init=sssp.heap_init(adj_off, adj, wgt, n)
    )
    np.testing.assert_allclose(np.asarray(heap["dist"]), ref, rtol=1e-5)
    wl, _ = worklist.sssp_worklist(adj_off, adj, wgt, 0, n)
    np.testing.assert_allclose(np.asarray(wl), ref, rtol=1e-5)


@pytest.mark.parametrize("use_map", [True, False])
@pytest.mark.parametrize("n", [8, 32])
def test_mergesort(n, use_map):
    x = mergesort.random_input(n, seed=5)
    prog = mergesort.make_program(n, use_map=use_map)
    heap, _, stats = HostEngine(prog, capacity=1 << 12).run(
        mergesort.initial(n), heap_init=dict(inp=x)
    )
    np.testing.assert_array_equal(np.asarray(heap["src"])[:n], np.sort(x))
    if use_map:
        assert stats.map_launches > 0


@pytest.mark.parametrize("n", [16, 64, 256])
def test_bitonic_baseline(n):
    x = mergesort.random_input(n, seed=1)
    np.testing.assert_array_equal(
        np.asarray(bitonic.bitonic_sort(np.asarray(x))), np.sort(x)
    )


@pytest.mark.parametrize("n", [8, 32])
def test_fft(n):
    xr, xi = fft.random_input(n, seed=7)
    prog = fft.make_program(n)
    heap, _, _ = HostEngine(prog, capacity=1 << 12).run(
        fft.initial(n), heap_init=dict(xr=xr, xi=xi)
    )
    got = np.asarray(heap["re"])[:n] + 1j * np.asarray(heap["im"])[:n]
    np.testing.assert_allclose(got, fft.fft_reference(xr, xi), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [4, 5, 6, 7])
def test_nqueens(n):
    prog = nqueens.make_program(n)
    heap, _, _ = HostEngine(prog, capacity=1 << 13).run(nqueens.initial())
    assert int(np.asarray(heap["count"])[0]) == nqueens.SOLUTIONS[n]


@pytest.mark.parametrize("n,block", [(4, 4), (8, 4), (16, 8)])
def test_matmul(n, block):
    A, B = matmul.random_inputs(n, seed=9)
    prog = matmul.make_program(n, block=block)
    heap, _, _ = HostEngine(prog, capacity=1 << 12).run(
        matmul.initial(n), heap_init=dict(A=A.ravel(), B=B.ravel())
    )
    np.testing.assert_allclose(
        np.asarray(heap["C"]).reshape(n, n), A @ B, rtol=1e-4, atol=1e-4
    )


def test_fib_values():
    for n in (0, 1, 5, 16):
        _, v, _ = HostEngine(fib.PROGRAM, capacity=1 << 13).run(fib.initial(n))
        assert int(v[0, 0]) == fib.fib_reference(n)


def test_tsp_exact():
    from repro.apps import tsp

    n = 7
    dist = tsp.random_instance(n, seed=3)
    prog = tsp.make_program(n)
    heap, _, stats = HostEngine(prog, capacity=1 << 14).run(
        tsp.initial(), heap_init=tsp.heap_init(dist)
    )
    got = int(np.asarray(heap["best"])[0])
    assert got == tsp.tsp_reference(dist)
    # pruning means far fewer tasks than the full (n-1)! tree
    import math

    full_tree = sum(
        math.factorial(n - 1) // math.factorial(n - 1 - d)
        for d in range(1, n)
    )
    assert stats.tasks_executed < full_tree


def test_annealing_reaches_good_energy():
    from repro.apps import annealing

    nb = 8
    Q = annealing.random_qubo(nb, seed=5)
    prog = annealing.make_program(nb, n_steps=40, n_chains=16)
    heap, _, stats = HostEngine(prog, capacity=1 << 10).run(
        annealing.initial(), heap_init=dict(Q=Q.ravel())
    )
    got = int(np.asarray(heap["best"])[0])
    opt = annealing.brute_force_min(Q)
    assert got >= opt
    # 16 chains x 40 steps must land within 20% of the optimum (or exactly
    # 0 if the optimum is 0)
    assert got <= opt + max(2, int(abs(opt) * 0.2))
    # regular parallelism: ~n_steps epochs, not n_steps*chains
    assert stats.epochs <= 45
