"""Persistent epoch megakernel tests (DESIGN.md §12).

The megakernel (``kernels/epoch_megakernel.py``) fuses an entire resident
chunk — scheduler pop → pack → task step → fork commit, for up to K
epochs — into one ``pl.pallas_call``, replacing the XLA ``while_loop``
sandwich in ``EpochLoop.run_chunk``.  CPU CI exercises it through Pallas
interpret mode; the jnp oracle is ``kernels/ref.py::epoch_chunk_ref``.
Load-bearing properties:

  * ``epoch_chunk`` (interpret) matches the oracle on a synthetic carry
    with scalar, array, and zero-size leaves, honouring the dynamic limit;
  * ``DeviceEngine(megakernel=True)``/``DeviceMultiplexer(megakernel=
    True)`` are bit-identical to the PR-5 ``while_loop`` resident path —
    values, heap, and the ChunkSummary-derived stats match exactly — on
    every registry fleet, for K ∈ {1, 4, ∞}, masked and gather;
  * chunked megakernel waves still pay exactly ⌈E/K⌉ readbacks;
  * the span/map width ladders clamp their minimum rung for tiny
    capacities (single-region tiny fleets stop padding to 8 lanes).
"""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import fib, get_case, get_fleet
from repro.core import DeviceEngine, HostEngine
from repro.core.engine import _map_width_ladder, _span_width_ladder
from repro.kernels import epoch_chunk
from repro.kernels import ref as kref
from repro.service import DeviceMultiplexer, Job, JobHandle, JobStatus


def _handles(fleet):
    return [
        JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                         quota=q, name=f"{c.name}#{i}"))
        for i, (c, q) in enumerate(fleet)
    ]


# --------------------------------------------------------- kernel plumbing
def _toy_carry():
    return {
        "n": jnp.asarray(0, jnp.int32),                 # scalar leaf
        "acc": jnp.arange(5, dtype=jnp.float32),        # array leaf
        "empty": jnp.zeros((3, 0), jnp.float32),        # zero-size leaf
    }


def _toy_cond(c, lim):
    return c["n"] < lim


def _toy_body(c):
    return {
        "n": c["n"] + 1,
        "acc": c["acc"] * 2.0 + c["empty"].sum(),
        "empty": c["empty"],
    }


@pytest.mark.parametrize("limit", [0, 1, 7])
def test_epoch_chunk_interpret_matches_ref(limit):
    ref = epoch_chunk(_toy_cond, _toy_body, _toy_carry(), limit, impl="ref")
    got = epoch_chunk(_toy_cond, _toy_body, _toy_carry(), limit,
                      impl="interpret")
    assert int(got["n"]) == int(ref["n"]) == limit
    np.testing.assert_array_equal(np.asarray(got["acc"]),
                                  np.asarray(ref["acc"]))
    assert got["empty"].shape == (3, 0)


def test_epoch_chunk_dynamic_limit_no_retrace():
    """The chunk bound is a dynamic operand: different limits re-enter one
    compiled kernel (jit cache keyed on shapes only)."""
    import jax

    calls = []

    @jax.jit
    def run(carry, lim):
        calls.append(1)
        return epoch_chunk(_toy_cond, _toy_body, carry, lim,
                           impl="interpret")
    for lim in (1, 4, 6):
        out = run(_toy_carry(), jnp.asarray(lim, jnp.int32))
        assert int(out["n"]) == lim
    assert len(calls) == 1


def test_epoch_chunk_rejects_unknown_impl():
    with pytest.raises(ValueError, match="impl"):
        epoch_chunk(_toy_cond, _toy_body, _toy_carry(), 1, impl="vulkan")


def test_epoch_chunk_ref_is_while_loop():
    out = kref.epoch_chunk_ref(_toy_cond, _toy_body, _toy_carry(),
                               jnp.asarray(3, jnp.int32))
    assert int(out["n"]) == 3


# -------------------------------------------------------------- solo engine
@pytest.mark.parametrize("dispatch", ["masked", "gather"])
def test_solo_megakernel_bit_identical(dispatch):
    """DeviceEngine(megakernel=True) under interpret mode matches the
    while_loop resident engine exactly, stats included."""
    case = get_case("fib")
    base = DeviceEngine(case.program, capacity=case.capacity,
                        dispatch=dispatch)
    hb, vb, sb = base.run(case.initial,
                          heap_init=dict(case.heap_init) or None)
    mega = DeviceEngine(case.program, capacity=case.capacity,
                        dispatch=dispatch, megakernel=True,
                        megakernel_impl="interpret")
    hm, vm, sm = mega.run(case.initial,
                          heap_init=dict(case.heap_init) or None)
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(vb))
    for k in hb:
        np.testing.assert_array_equal(np.asarray(hm[k]), np.asarray(hb[k]),
                                      err_msg=k)
    assert _stats_dict(sm) == _stats_dict(sb)


def _stats_dict(s):
    d = dataclasses.asdict(s)
    d["tasks_by_type"] = dict(d["tasks_by_type"])
    d["lanes_by_type"] = dict(d["lanes_by_type"])
    return d


# ------------------------------------------------------------ fleet waves
@pytest.mark.parametrize("fleet_name", ["mixed3", "mixed4", "fib_fleet"])
@pytest.mark.parametrize("dispatch", ["masked", "gather"])
def test_fleet_megakernel_bit_identical(fleet_name, dispatch):
    """Acceptance: the megakernel chunk is bit-identical to the PR-5
    while_loop resident path on every registry fleet for K ∈ {1, 4, ∞}
    (masked and gather), with the ChunkSummary-derived fleet stats
    matching exactly."""
    fleet = get_fleet(fleet_name)
    for chunk in (1, 4, None):
        runs = {}
        for mega in (False, True):
            handles = _handles(fleet)
            mux = DeviceMultiplexer(
                handles, dispatch=dispatch, chunk=chunk, megakernel=mega,
                megakernel_impl="interpret" if mega else "auto",
            )
            mux.run()
            runs[mega] = (handles, mux.stats())
        (hb, sb), (hm, sm) = runs[False], runs[True]
        for b, m in zip(hb, hm):
            assert b.status is JobStatus.DONE and m.status is JobStatus.DONE
            np.testing.assert_array_equal(
                np.asarray(m.result.value), np.asarray(b.result.value),
                err_msg=f"{b.job.name}:K={chunk}",
            )
            for k in b.result.heap:
                np.testing.assert_array_equal(
                    np.asarray(m.result.heap[k]),
                    np.asarray(b.result.heap[k]),
                    err_msg=f"{b.job.name}:{k}:K={chunk}",
                )
            assert m.result.stats.epochs == b.result.stats.epochs
            assert (m.result.stats.tasks_executed
                    == b.result.stats.tasks_executed)
        assert _stats_dict(sm) == _stats_dict(sb), f"K={chunk}"


def test_megakernel_chunk_readback_cadence():
    """A megakernel wave of E epochs at chunk K pays exactly ⌈E/K⌉
    dispatches + readbacks, same as the while_loop driver."""
    fleet = [(get_case("fib"), 512), (get_case("treewalk"), 512)]
    for chunk in (1, 4, None):
        handles = _handles(fleet)
        mux = DeviceMultiplexer(
            handles, chunk=chunk, megakernel=True,
            megakernel_impl="interpret",
        )
        mux.run()
        s = mux.stats()
        expect = 1 if chunk is None else math.ceil(s.epochs / chunk)
        assert s.dispatches == expect
        assert s.scalar_transfers == expect


# --------------------------------------------------------- ladder edge case
def test_width_ladders_clamp_tiny_capacities():
    """Minimum-width rungs must stay live below the default minimum: a
    capacity at/below 8 halves the floor instead of degenerating to one
    full-width rung."""
    assert _span_width_ladder(4096) == (512, 1024, 2048, 4096)
    assert _span_width_ladder(8) == (4, 8)
    assert _span_width_ladder(4) == (2, 4)
    assert _span_width_ladder(1) == (1,)
    assert _map_width_ladder(16) == (8, 16)
    assert _map_width_ladder(8) == (4, 8)
    assert _map_width_ladder(4) == (2, 4)
    assert _map_width_ladder(1) == (1,)


def test_tiny_fleet_does_not_pad_to_minimum():
    """Single-region tiny fleet: with the clamped ladder the resident
    engine launches narrow rungs (holes accrue), instead of padding every
    epoch to the old 8-lane minimum."""
    eng = DeviceEngine(fib.PROGRAM, capacity=8)
    h, v, s = eng.run(fib.initial(3))
    assert int(np.asarray(v)[0, 0]) == fib.fib_reference(3)
    # rungs are (4, 8): epochs with span <= 4 launch 4 lanes, not 8
    assert s.hole_lanes_skipped > 0
    assert s.lanes_launched < 8 * s.epochs
    assert s.lanes_launched + s.hole_lanes_skipped == 8 * s.epochs
    # bit-identical to the host run regardless of rung choice
    hh, hv, _ = HostEngine(fib.PROGRAM, capacity=8).run(fib.initial(3))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(hv))
