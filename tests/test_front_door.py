"""Layered serving front door tests (DESIGN.md §16).

The load-bearing property: chunk-boundary preemption is *observationally
invisible* to the preempted job.  A job lifted into a
:class:`RegionCheckpoint` at a boundary and re-admitted later — into the
same wave, a different wave, a different engine — must finish with the
exact solo result and solo-comparable stats of an uninterrupted run.
Around that: admission ordering/rate/share policy, preemption planning
strictness, the async submit/stream surface, lifecycle-clock injection,
template-cache LRU accounting, and the virtual-clock loadgen gate.
"""
import asyncio

import numpy as np
import pytest

from repro.apps import fib
from repro.core import HostEngine
from repro.service import (
    AdmissionController,
    AdmissionError,
    DeviceMultiplexer,
    EpochMultiplexer,
    Job,
    JobHandle,
    JobService,
    JobStatus,
    QuotaClass,
    WaveTemplate,
    WaveTemplateCache,
)
from repro.distributed.fleet import ShardedFleet

QUOTA = 256
FIB_N = 9


def _solo():
    heap, value, stats = HostEngine(fib.PROGRAM, capacity=QUOTA).run(
        fib.initial(FIB_N)
    )
    return np.asarray(value), stats


def _handle(i, n=FIB_N, **kw):
    return JobHandle(i, Job(fib.PROGRAM, fib.initial(n), quota=QUOTA), **kw)


def _mux(engine, handles, dispatch="masked"):
    if engine == "host":
        return EpochMultiplexer(handles, dispatch=dispatch)
    if engine == "device":
        return DeviceMultiplexer(handles, dispatch=dispatch, chunk=2)
    return ShardedFleet(handles, shards=2, dispatch=dispatch, chunk=2)


# ------------------------------------------- preempt/resume bit-identity
@pytest.mark.parametrize("engine", ["host", "device", "sharded"])
@pytest.mark.parametrize("dispatch", ["masked", "gather"])
def test_preempt_resume_bit_identical(engine, dispatch):
    """Preempt mid-flight, re-admit into a *fresh* wave, compare the
    result and every solo-comparable stat against an uninterrupted solo
    HostEngine run."""
    if engine == "host" and dispatch == "gather":
        pytest.skip("gather is a resident-dispatch mode")
    solo_value, solo_stats = _solo()

    h = _handle(0)
    m1 = _mux(engine, [h], dispatch)
    for _ in range(3):
        m1.step()
    assert m1.preempt(h)
    assert h.status is JobStatus.PREEMPTED
    assert h.preemptions == 1
    assert h.checkpoint is not None
    # the checkpointed job re-queues into a *different* wave and resumes
    h2 = _handle(1)
    m2 = _mux(engine, [h2, h], dispatch)
    m2.run()
    assert h.status is JobStatus.DONE
    got = h.result.stats
    assert got.epochs == solo_stats.epochs
    assert got.tasks_executed == solo_stats.tasks_executed
    assert got.total_forks == solo_stats.total_forks
    assert got.peak_tv_slots == solo_stats.peak_tv_slots
    np.testing.assert_array_equal(np.asarray(h.result.value), solo_value)
    # the rider was untouched
    assert h2.status is JobStatus.DONE
    np.testing.assert_array_equal(np.asarray(h2.result.value), solo_value)


def test_preempt_resume_cross_engine():
    """Checkpoints are engine-agnostic: capture on the device driver,
    resume on the host driver (and vice versa), same solo bits."""
    solo_value, solo_stats = _solo()
    for first, second in (("device", "host"), ("host", "device")):
        h = _handle(0)
        m1 = _mux(first, [h])
        for _ in range(3):
            m1.step()
        assert m1.preempt(h)
        m2 = _mux(second, [_handle(1), h])
        m2.run()
        assert h.result.stats.solo_dict() == solo_stats_dict(solo_stats)
        np.testing.assert_array_equal(
            np.asarray(h.result.value), solo_value
        )


def solo_stats_dict(stats):
    return {
        "epochs": stats.epochs,
        "tasks_executed": stats.tasks_executed,
        "total_forks": stats.total_forks,
        "peak_tv_slots": stats.peak_tv_slots,
    }


def test_preempt_not_running_is_false():
    h = _handle(0)
    m = _mux(device := "device", [h])
    other = _handle(1)
    assert not m.preempt(other)  # never seated here
    m.run()
    assert not m.preempt(h)  # already finished


# ------------------------------------- service-level priority preemption
def test_service_priority_preempts_and_resumes():
    """A strictly-higher-priority submit evicts the running batch job at
    a chunk boundary; both finish, the victim with solo-identical bits,
    and the interactive job finishes first."""
    solo_value, solo_stats = _solo()
    svc = JobService(
        capacity=QUOTA, max_jobs=1, engine="device", chunk=2,
        classes=[QuotaClass("batch"),
                 QuotaClass("interactive", priority=10)],
    )
    lo = svc.submit(fib.PROGRAM, fib.initial(FIB_N), quota=QUOTA,
                    klass="batch")
    svc._pump()
    svc._pump()
    hi = svc.submit(fib.PROGRAM, fib.initial(7), quota=QUOTA,
                    klass="interactive", deadline=60.0)
    done = svc.drain()
    assert done[0] is hi
    assert lo.preemptions >= 1
    assert lo.status is JobStatus.DONE
    assert lo.result.stats.solo_dict() == solo_stats_dict(solo_stats)
    np.testing.assert_array_equal(np.asarray(lo.result.value), solo_value)
    assert svc.admission.preempted == {"batch": lo.preemptions}


def test_service_preempt_readmit_zero_retrace():
    """A preempt + re-admit cycle of known wave shapes reuses the cached
    compiled templates — trace_count must not move."""
    svc = JobService(
        capacity=QUOTA, max_jobs=1, engine="device", chunk=2,
        classes=[QuotaClass("batch"),
                 QuotaClass("interactive", priority=10)],
    )
    lo = svc.submit(fib.PROGRAM, fib.initial(FIB_N), quota=QUOTA,
                    klass="batch")
    svc._pump()
    svc._pump()
    before = svc.trace_count
    hi = svc.submit(fib.PROGRAM, fib.initial(7), quota=QUOTA,
                    klass="interactive")
    svc.drain()
    assert lo.preemptions >= 1
    assert svc.trace_count == before
    assert hi.status is JobStatus.DONE and lo.status is JobStatus.DONE


def test_equal_priority_never_preempts():
    """Strict-priority rule: equal priority can never evict (prevents
    requeue ping-pong)."""
    svc = JobService(
        capacity=QUOTA, max_jobs=1, engine="device", chunk=2,
    )
    a = svc.submit(fib.PROGRAM, fib.initial(FIB_N), quota=QUOTA)
    svc._pump()
    b = svc.submit(fib.PROGRAM, fib.initial(FIB_N), quota=QUOTA)
    done = svc.drain()
    assert a.preemptions == 0 and b.preemptions == 0
    assert done[0] is a  # FIFO preserved


# ------------------------------------------------------ admission policy
def _jh(i, quota=64, **kw):
    return JobHandle(
        i, Job(fib.PROGRAM, fib.initial(5), quota=quota), **kw
    )


def test_admission_order_priority_then_edf_then_fifo():
    adm = AdmissionController(
        classes=[QuotaClass("hi", priority=5)], clock=lambda: 0.0
    )
    a = _jh(0)                                  # default, no deadline
    b = _jh(1, deadline=10.0)                   # default, EDF first
    c = _jh(2, klass="hi")                      # class priority wins
    d = _jh(3, priority=9)                      # explicit beats class
    assert adm.order([a, b, c, d]) == [d, c, b, a]


def test_admission_default_degenerates_to_fifo():
    """No priorities/deadlines/limits: take_wave == the old greedy FIFO
    first-fit."""
    adm = AdmissionController(clock=lambda: 0.0)
    hs = [_jh(i, quota=64) for i in range(5)]
    wave, left = adm.take_wave(hs, capacity=128, max_jobs=8)
    assert [h.job_id for h in wave] == [0, 1]
    assert [h.job_id for h in left] == [2, 3, 4]


def test_admission_class_share_caps_wave_fraction():
    adm = AdmissionController(
        classes=[QuotaClass("greedy", share=0.5)], clock=lambda: 0.0
    )
    hs = [_jh(i, quota=64, klass="greedy") for i in range(4)]
    hs.append(_jh(4, quota=64))
    wave, left = adm.take_wave(hs, capacity=256, max_jobs=8)
    # greedy may hold at most 128 of 256 slots: two jobs
    assert [h.job_id for h in wave] == [0, 1, 4]
    assert [h.job_id for h in left] == [2, 3]


def test_admission_rate_limit_token_bucket():
    t = [0.0]
    adm = AdmissionController(
        classes=[QuotaClass("limited", rate=1.0, burst=1.0)],
        clock=lambda: t[0],
    )
    a, b = _jh(0, klass="limited"), _jh(1, klass="limited")
    assert adm.allow(a)
    assert not adm.allow(b)       # bucket drained
    assert adm.has_token(b) is False
    t[0] = 1.5                    # refill at 1 token/s
    assert adm.has_token(b)
    assert adm.allow(b)


def test_admission_unknown_class_raises():
    svc = JobService(capacity=256)
    with pytest.raises(AdmissionError):
        svc.submit(fib.PROGRAM, fib.initial(5), quota=64, klass="nope")


def test_plan_preemptions_strictly_lower_priority_only():
    adm = AdmissionController(
        classes=[QuotaClass("hi", priority=5),
                 QuotaClass("pinned", priority=0, preemptible=False)],
        clock=lambda: 0.0,
    )
    run_lo = _jh(0)
    run_pinned = _jh(1, klass="pinned")
    run_hi = _jh(2, klass="hi")
    for h in (run_lo, run_pinned, run_hi):
        h.mark_running()
    want = _jh(3, klass="hi")
    victims = adm.plan_preemptions([run_lo, run_pinned, run_hi], [want])
    # only the preemptible strictly-lower-priority job yields
    assert victims == [run_lo]
    # an equal-priority waiter gets nothing
    assert adm.plan_preemptions([run_hi], [_jh(4, klass="hi")]) == []


def test_deadline_scoreboard_and_slack():
    t = [0.0]
    adm = AdmissionController(clock=lambda: t[0])
    h = _jh(0, deadline=5.0, clock=lambda: t[0])
    assert adm.deadline_slack([h]) == 5.0
    t[0] = 2.0
    assert adm.deadline_slack([h]) == 3.0
    h.mark_running()
    t[0] = 4.0
    h.mark_finished()
    assert adm.note_finished(h) is True
    assert adm.miss_ratio() == 0.0
    h2 = _jh(1, deadline=1.0, clock=lambda: t[0])
    h2.mark_running()
    t[0] = 9.0
    h2.mark_finished()
    assert adm.note_finished(h2) is False
    assert adm.miss_ratio() == 0.5


# ------------------------------------------------------- lifecycle clock
def test_handle_clock_injectable_and_monotonic():
    """Lifecycle stamps come from the handle's injected clock — virtual
    time in tests/loadgen, time.monotonic by default — and are monotone
    through the full lifecycle including preemption."""
    t = [10.0]
    h = _jh(0, clock=lambda: t[0])
    assert h.submitted_at == 10.0
    t[0] = 11.0
    h.mark_running()
    assert h.started_at == 11.0
    t[0] = 9.0  # a broken clock would violate monotonicity
    t[0] = 12.0
    h.mark_finished()
    assert h.finished_at == 12.0
    assert h.submitted_at <= h.started_at <= h.finished_at
    assert h.queue_wait == 1.0
    assert h.run_time == 1.0


def test_service_clock_threads_to_handles():
    t = [0.0]
    svc = JobService(capacity=256, clock=lambda: t[0])
    t[0] = 3.0
    h = svc.submit(fib.PROGRAM, fib.initial(5), quota=64, deadline=2.0)
    assert h.submitted_at == 3.0
    assert h.deadline == 5.0  # relative deadline, absolute stamp
    assert svc.admission.clock() == 3.0


# ------------------------------------------------- template cache LRU
class _FakeLoop:
    def __init__(self, traces):
        self.trace_count = traces


def _tpl(key, traces=1):
    return WaveTemplate(
        key=(key,), program=None, slots=(), loop=_FakeLoop(traces)
    )


def test_wave_template_cache_lru_evicts_oldest_first():
    cache = WaveTemplateCache(max_entries=16)
    for i in range(17):
        cache.store(_tpl(i))
    assert cache.evictions == 1
    assert cache.peek((0,)) is None          # oldest evicted
    assert cache.peek((1,)) is not None
    # touching an entry protects it from the next eviction
    cache.lookup((1,))
    cache.store(_tpl(99))
    assert cache.evictions == 2
    assert cache.peek((1,)) is not None      # recently used: survives
    assert cache.peek((2,)) is None          # next-oldest went instead


def test_wave_template_cache_eviction_keeps_trace_count_monotone():
    cache = WaveTemplateCache(max_entries=16)
    seen = []
    for i in range(40):
        cache.store(_tpl(i, traces=2))
        seen.append(cache.trace_count)
    assert cache.evictions == 40 - 16
    assert seen == sorted(seen)
    assert cache.trace_count == 40 * 2       # evicted traces still count
    assert len(cache) == 16


def test_service_exports_eviction_metric():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    svc = JobService(capacity=256, engine="device", chunk=2, metrics=reg,
                     template_cache=WaveTemplateCache(max_entries=16))
    svc.submit(fib.PROGRAM, fib.initial(5), quota=64)
    svc.drain()
    assert reg.value("trees_wave_template_evictions") == 0


# ----------------------------------------------------------- async API
def test_submit_async_gather_and_stream():
    solo_value, _ = _solo()

    async def main():
        svc = JobService(capacity=2 * QUOTA, max_jobs=2)
        f1 = svc.submit_async(fib.PROGRAM, fib.initial(FIB_N), quota=QUOTA)
        f2 = svc.submit_async(fib.PROGRAM, fib.initial(FIB_N), quota=QUOTA)
        r1, r2 = await asyncio.gather(f1.result(), f2.result())
        np.testing.assert_array_equal(np.asarray(r1.value), solo_value)
        np.testing.assert_array_equal(np.asarray(r2.value), solo_value)
        assert f1.done() and f2.done()
        # stream_results drains later submissions as they finish
        svc.submit(fib.PROGRAM, fib.initial(6), quota=64)
        svc.submit(fib.PROGRAM, fib.initial(5), quota=64)
        seen = [h async for h in svc.stream_results()]
        assert len(seen) == 2
        assert all(h.status is JobStatus.DONE for h in seen)

    asyncio.run(main())


def test_async_failure_raises_through_future():
    from repro.service import JobFailure

    async def main():
        svc = JobService(capacity=64, max_jobs=1)
        # fib(12) needs ~465 slots: overflows a 64-slot region
        fut = svc.submit_async(fib.PROGRAM, fib.initial(12), quota=64)
        with pytest.raises(JobFailure):
            await fut

    asyncio.run(main())


# ------------------------------------------------ controllers (§16 knobs)
def test_chunk_controller_deadline_slack_shrinks_k():
    from repro.control.controller import ChunkController

    ctl = ChunkController(k_init=8, tight_slack_s=0.1)
    assert ctl.observe(completions=1, queued=0) == 8       # hold
    assert ctl.observe(1, 0, deadline_slack=0.05) == 4     # tight: shrink
    assert ctl.observe(1, 0, deadline_slack=10.0) == 4     # loose: hold
    ctl2 = ChunkController(k_init=1)
    assert ctl2.observe(0, 0, deadline_slack=0.01) == 1    # floor holds


def test_placement_controller_policy_mix():
    from repro.control.controller import PlacementController

    ctl = PlacementController(window=8)
    # homogeneous, balanced -> round_robin
    for _ in range(4):
        ctl.observe_job(1)
    assert ctl.choose() == "round_robin"
    # diverse types, balanced -> sticky (affinity wins)
    for k in range(8):
        ctl.observe_job(k)
    assert ctl.choose() == "sticky"
    # imbalanced -> least_loaded overrides everything
    ctl.observe_imbalance(util_spread=0.5, queue_spread=0)
    assert ctl.choose() == "least_loaded"
    assert set(ctl.decisions) == {"round_robin", "sticky", "least_loaded"}


def test_sharded_fleet_auto_placement_runs():
    h = _handle(0)
    fl = ShardedFleet([h], shards=2, chunk=2, placement="auto")
    fl.admit(_handle(1))
    done = fl.run()
    assert len(done) == 2
    assert all(x.status is JobStatus.DONE for x in done)
    assert sum(fl._pctl.decisions.values()) == 2


# -------------------------------------------------------------- loadgen
def test_loadgen_priority_beats_fifo_and_is_deterministic(tmp_path):
    import json
    import subprocess
    import sys
    import os

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = tmp_path / "lg.json"
    cmd = [
        sys.executable, os.path.join(repo, "benchmarks", "loadgen.py"),
        "--jobs", "24", "--json", str(out),
    ]
    subprocess.run(cmd, check=True, env=env, cwd=str(tmp_path))
    doc = json.loads(out.read_text())
    rows = {r["name"]: r for r in doc["rows"]}
    sys.path.insert(0, os.path.join(repo, "benchmarks"))
    try:
        from check import parse_derived, run_latency_check
    finally:
        sys.path.pop(0)
    fifo = parse_derived(rows["loadgen_fifo"]["derived"])
    prio = parse_derived(rows["loadgen_priority"]["derived"])
    assert int(fifo["misses_interactive"]) > 0
    assert (
        int(prio["misses_interactive"]) < int(fifo["misses_interactive"])
    )
    # the gate agrees, self-contained and vs itself as baseline
    assert run_latency_check(str(out)) == 0
    assert run_latency_check(str(out), str(out)) == 0
