"""Telemetry subsystem tests (DESIGN.md §13).

Covers the three obs layers plus the bench regression gate:

* ``obs/trace.py`` — spans land as valid Chrome-trace-event JSON
  (perfetto-loadable), host drivers emit per-epoch phases, resident
  drivers emit per-chunk spans whose readback count is the ⌈E/K⌉ cadence
  the design promises, and the disabled path changes nothing;
* ``obs/metrics.py`` / ``obs/export.py`` — labeled registry semantics,
  the StatsCollector adapter's per-epoch utilization/hole-fraction
  pairing, per-tenant latency histograms from ``JobService`` lifecycle
  events, JSONL + Prometheus text round-trips;
* ``obs/log.py`` — the shared ``repro`` logger hierarchy and key=value
  formatting;
* ``benchmarks/check.py`` — exact on deterministic counters, fuzzy on
  wall-clock, error on incomparable artifacts.
"""
import importlib.util
import json
import logging
import math
import pathlib

import numpy as np
import pytest

from repro.apps import fib
from repro.core import HostEngine, RunStats, RunStatsCollector
from repro.obs import (
    NULL_TRACER,
    MetricsCollector,
    MetricsError,
    MetricsRegistry,
    SpanTracer,
    export_run_stats,
    get_logger,
    iter_samples,
    iter_spans,
    kv,
    load_trace,
    read_jsonl,
    to_prometheus,
    validate_chrome_trace,
    write_jsonl,
)
from repro.service import JobService


# ---------------------------------------------------------------- trace.py
def test_span_tracer_writes_valid_chrome_trace(tmp_path):
    tr = SpanTracer()
    tr.thread(1, "host-epochs")
    with tr.span("epoch", "host", tid=1, cen=3) as args:
        with tr.span("dispatch", "host", tid=1, launched=8):
            pass
        args.update(util=0.5)
    tr.instant("admit", "service", tid=1, job="t0")
    tr.counter("queue_depth", tid=1, queued=2)
    path = tmp_path / "trace.json"
    tr.write(str(path))

    events = load_trace(str(path))
    spans = list(iter_spans(events, "epoch"))
    assert len(spans) == 1
    assert spans[0]["args"] == {"cen": 3, "util": 0.5}
    inner = list(iter_spans(events, "dispatch", "host"))
    assert len(inner) == 1
    assert inner[0]["dur"] >= 0
    # the late-arg update pattern: values attached after child spans ran
    assert spans[0]["ts"] <= inner[0]["ts"]


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError, match="phase"):
        validate_chrome_trace([{"name": "x"}])
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(
            [{"ph": "X", "name": "x", "ts": 0, "dur": "?", "pid": 1,
              "tid": 0}]
        )


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("epoch", foo=1) as args:
        args.update(bar=2)  # throwaway dict, must not raise
    NULL_TRACER.instant("x")
    NULL_TRACER.counter("x", v=1)
    with NULL_TRACER.annotation("x"):
        pass
    assert NULL_TRACER.events_named("epoch") == []


def test_host_engine_emits_per_epoch_spans():
    tr = SpanTracer()
    eng = HostEngine(fib.PROGRAM, capacity=256, dispatch="gather", tracer=tr)
    _, _, stats = eng.run(fib.initial(8))

    epochs = list(iter_spans(tr.events, "epoch", "host"))
    assert len(epochs) == stats.epochs
    # gather dispatch: one pack + one dispatch + one readback per epoch
    assert len(list(iter_spans(tr.events, "pack", "host"))) == stats.epochs
    assert (
        len(list(iter_spans(tr.events, "dispatch", "host"))) == stats.epochs
    )
    assert (
        len(list(iter_spans(tr.events, "readback", "host"))) == stats.epochs
    )
    for e in epochs:
        assert e["args"]["mode"] == "gather"
        assert 0.0 <= e["args"]["util"] <= 1.0
    validate_chrome_trace(tr.to_dict())


def test_tracing_off_is_bit_identical():
    ref_eng = HostEngine(fib.PROGRAM, capacity=256)
    _, ref_vals, ref_stats = ref_eng.run(fib.initial(8))
    tr = SpanTracer()
    traced_eng = HostEngine(fib.PROGRAM, capacity=256, tracer=tr)
    _, vals, stats = traced_eng.run(fib.initial(8))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ref_vals))
    assert stats == ref_stats
    assert len(list(iter_spans(tr.events, "epoch"))) == stats.epochs


# --------------------------------------- acceptance: resident chunk cadence
def test_device_service_trace_readbacks_and_tenant_latency():
    """The ISSUE's acceptance criterion: a ``JobService(engine="device",
    chunk=K)`` run with tracing on yields a perfetto-loadable trace whose
    readback-span count is ⌈E/K⌉, plus per-tenant queue-wait and run-time
    histograms for every completed job."""
    K = 3
    reg = MetricsRegistry()
    tr = SpanTracer()
    svc = JobService(
        capacity=512, max_jobs=2, engine="device", chunk=K,
        metrics=reg, tracer=tr,
    )
    svc.submit(fib.PROGRAM, fib.initial(8), quota=256, name="tenant-a")
    svc.submit(fib.PROGRAM, fib.initial(9), quota=256, name="tenant-b")
    handles = svc.drain()
    assert all(h.status.value == "done" for h in handles)

    E = svc.stats().epochs
    assert E > K  # the cadence claim is vacuous on a single chunk
    readbacks = list(iter_spans(tr.events, "readback", "resident"))
    assert len(readbacks) == math.ceil(E / K)
    chunks = list(iter_spans(tr.events, "chunk", "resident"))
    assert len(chunks) == math.ceil(E / K)
    # chunk spans reconstruct per-chunk deltas from the ChunkSummary
    assert sum(c["args"]["epochs"] for c in chunks) == E
    assert all(c["args"]["k"] == K for c in chunks)
    assert (
        sum(c["args"]["tasks"] for c in chunks)
        == svc.stats().tasks_executed
    )
    validate_chrome_trace(tr.to_dict())

    # per-tenant latency split: one queue-wait + one run-time observation
    # per completed job, and a terminal-status counter
    qw = reg.get("trees_job_queue_wait_seconds")
    rt = reg.get("trees_job_run_seconds")
    for tenant in ("tenant-a", "tenant-b"):
        assert qw.labels(tenant=tenant).count == 1
        assert rt.labels(tenant=tenant).count == 1
        assert qw.labels(tenant=tenant).sum >= 0.0
        assert rt.labels(tenant=tenant).sum > 0.0
        assert reg.value(
            "trees_jobs_finished_total", tenant=tenant, status="done"
        ) == 1

    # the template cache counters mirrored into the registry
    assert reg.value(
        "trees_wave_template_lookups_total", outcome="miss"
    ) == 1
    assert reg.value("trees_wave_template_traces") == svc.trace_count

    # driver-labeled run counters fed through the StatsCollector adapter
    assert reg.value(
        "trees_epochs_total", driver="device", dispatch="masked",
        app="service",
    ) == E


# -------------------------------------------------------------- metrics.py
def test_registry_declaration_semantics():
    r = MetricsRegistry()
    c1 = r.counter("x_total", "a counter", ("driver",))
    c2 = r.counter("x_total", "a counter", ("driver",))
    assert c1 is c2  # idempotent re-declare shares the family
    with pytest.raises(MetricsError, match="already registered"):
        r.gauge("x_total", "now a gauge", ("driver",))
    with pytest.raises(MetricsError, match="do not match"):
        c1.labels(nope="x")
    c1.labels(driver="host").inc(2)
    assert r.value("x_total", driver="host") == 2
    with pytest.raises(MetricsError, match=">= 0"):
        c1.labels(driver="host").inc(-1)


def test_histogram_buckets_and_quantile():
    r = MetricsRegistry()
    h = r.histogram("lat_seconds", "", (), buckets=(0.1, 1.0)).labels()
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count == 3
    assert h.counts == [1, 1, 1]
    assert h.quantile(0.5) == 1.0
    assert h.quantile(1.0) == math.inf
    with pytest.raises(MetricsError, match="histogram"):
        r.value("lat_seconds")


def test_metrics_collector_pairs_holes_with_lanes():
    """The hole-fraction fold: drivers report ``holes_skipped`` just
    before the matching ``lanes`` call, so the adapter emits exactly one
    utilization + one hole-fraction observation per epoch."""
    r = MetricsRegistry()
    eng = HostEngine(
        fib.PROGRAM, capacity=256, dispatch="gather",
        stats_factory=lambda: MetricsCollector(
            RunStatsCollector(), r, driver="host", dispatch="gather",
            app="fib",
        ),
    )
    _, _, stats = eng.run(fib.initial(8))
    lab = dict(driver="host", dispatch="gather", app="fib")
    util = r.get("trees_lane_utilization").labels(**lab)
    frac = r.get("trees_hole_fraction").labels(**lab)
    assert util.count == stats.epochs
    assert frac.count == stats.epochs
    assert r.value("trees_tasks_total", **lab) == stats.tasks_executed
    assert r.value("trees_lanes_total", **lab) == stats.lanes_launched
    assert (
        r.value("trees_hole_lanes_total", **lab) == stats.hole_lanes_skipped
    )
    assert r.value("trees_peak_tv_slots", **lab) == stats.peak_tv_slots


# --------------------------------------------------------------- export.py
def test_export_jsonl_and_prometheus(tmp_path):
    r = MetricsRegistry()
    r.counter("trees_epochs_total", "epochs", ("driver",)).labels(
        driver="host"
    ).inc(23)
    r.histogram("trees_lat_seconds", "lat", (), buckets=(1.0,)).labels(
    ).observe(0.5)

    path = tmp_path / "metrics.jsonl"
    n = write_jsonl(r, str(path))
    samples = read_jsonl(str(path))
    assert len(samples) == n == len(list(iter_samples(r)))
    by_name = {s["name"]: s for s in samples}
    assert by_name["trees_epochs_total"]["value"] == 23
    assert by_name["trees_epochs_total"]["labels"] == {"driver": "host"}
    assert by_name["trees_lat_seconds"]["count"] == 1

    text = to_prometheus(r)
    assert "# TYPE trees_epochs_total counter" in text
    assert 'trees_epochs_total{driver="host"} 23' in text
    assert 'trees_lat_seconds_bucket{le="1"} 1' in text
    assert 'trees_lat_seconds_bucket{le="+Inf"} 1' in text
    assert "trees_lat_seconds_count 1" in text
    assert text.endswith("\n")


def test_export_run_stats_shares_vocabulary():
    r = MetricsRegistry()
    stats = RunStats(epochs=3, tasks_executed=7, lanes_launched=10)
    export_run_stats(r, stats, driver="host", app="fib")
    assert r.value("trees_run_epochs", driver="host", app="fib") == 3
    assert r.value("trees_run_tasks_executed", driver="host", app="fib") == 7
    # derived fields ride along under the same keys as RunStats.as_dict()
    assert r.value(
        "trees_run_utilization", driver="host", app="fib"
    ) == stats.utilization


# ------------------------------------------------------------------ log.py
def test_logger_hierarchy_and_kv(capsys):
    log = get_logger("runtime")
    assert log.name == "repro.runtime"
    assert get_logger("runtime") is log
    line = kv(step=3, elapsed_s=0.25, name="a b")
    assert "step=3" in line and "elapsed_s=0.25" in line
    assert "name='a b'" in line  # values with spaces are quoted

    import repro.obs.log as obslog

    rec = logging.LogRecord(
        "repro.runtime", logging.WARNING, __file__, 1,
        "straggler %s", (kv(step=3),), None,
    )
    out = obslog.KeyValueFormatter().format(rec)
    assert "WARNING" in out
    assert "repro.runtime" in out
    assert "straggler step=3" in out


# ----------------------------------------------------- benchmarks/check.py
def _load_check():
    path = (
        pathlib.Path(__file__).resolve().parents[1]
        / "benchmarks" / "check.py"
    )
    spec = importlib.util.spec_from_file_location("bench_check", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _artifact(tmp_path, name, rows):
    doc = {
        "schema": "trees-bench-v2", "dispatch": "masked", "smoke": True,
        "megakernel": False, "groups": ["fib"], "rows": rows,
    }
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_check_exact_counters_fuzzy_time(tmp_path):
    check = _load_check()
    base_rows = [{
        "name": "fib8", "us_per_call": 100.0, "compile_us": 5.0,
        "derived": "tasks=55;epochs=9;us_per_task=1.8;util=0.62",
        "stats": {"epochs": 9, "tasks_executed": 55},
    }]
    base = _artifact(tmp_path, "base.json", base_rows)

    # big speedup + identical counters: passes (fuzzy one-sided on time)
    fresh_rows = json.loads(json.dumps(base_rows))
    fresh_rows[0]["us_per_call"] = 1.0
    fresh_rows[0]["derived"] = "tasks=55;epochs=9;us_per_task=0.1;util=0.99"
    fresh = _artifact(tmp_path, "fresh.json", fresh_rows)
    assert check.run_check(fresh, base) == 0
    # ... unless --strict, which flags implausible speedups too
    assert check.run_check(fresh, base, strict=True) == 1

    # slowdown beyond the factor fails
    slow_rows = json.loads(json.dumps(base_rows))
    slow_rows[0]["us_per_call"] = 100.0 * 25 * 2
    slow = _artifact(tmp_path, "slow.json", slow_rows)
    assert check.run_check(slow, base) == 1
    assert check.run_check(slow, base, ignore_time=True) == 0

    # a drifted deterministic counter fails exactly, however fast the row
    drift_rows = json.loads(json.dumps(base_rows))
    drift_rows[0]["derived"] = "tasks=56;epochs=9;us_per_task=1.8;util=0.62"
    drift = _artifact(tmp_path, "drift.json", drift_rows)
    assert check.run_check(drift, base) == 1
    # structured stats drift fails too
    sdrift_rows = json.loads(json.dumps(base_rows))
    sdrift_rows[0]["stats"]["tasks_executed"] = 56
    sdrift = _artifact(tmp_path, "sdrift.json", sdrift_rows)
    assert check.run_check(sdrift, base) == 1


def test_check_rejects_incomparable_artifacts(tmp_path):
    check = _load_check()
    a = _artifact(tmp_path, "a.json", [
        {"name": "x", "us_per_call": 1.0, "derived": ""}
    ])
    b = _artifact(tmp_path, "b.json", [
        {"name": "y", "us_per_call": 1.0, "derived": ""}
    ])
    assert check.run_check(a, b) == 2  # empty intersection
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "other", "rows": []}))
    assert check.run_check(a, str(bad)) == 2
