"""Sharded fleet execution across a device mesh (DESIGN.md §15).

The contract under test: a :class:`~repro.distributed.ShardedFleet` is P
independent device waves advancing together — ONE fused launch + ONE
stacked readback per collective chunk — and per-job results stay
bit-identical to a solo ``HostEngine.run`` at every P, every placement
policy, and every migration history.  Work counters are *conserved*:
sharding (and chunk-boundary rebalancing) moves jobs between shards but
the summed per-shard ``tasks_executed``/``total_forks`` equal the solo
totals exactly.  The shard_map mesh path (real devices, exercised in a
subprocess with 8 forced host devices) and the single-device vmap
fallback produce the same bits.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.apps import fib, get_fleet
from repro.core import HostEngine
from repro.distributed import ShardedFleet
from repro.service import Job, JobHandle, JobService

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def _solo(case, quota):
    eng = HostEngine(case.program, capacity=quota)
    return eng.run(case.initial, heap_init=dict(case.heap_init) or None)


def _handles(fleet, tag=""):
    return [
        JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                         quota=q, name=c.name + tag))
        for i, (c, q) in enumerate(fleet)
    ]


def _assert_solo_identical(handle, solo):
    sh, sv, ss = solo
    r = handle.result
    assert r is not None, (handle.job.name, handle.error)
    np.testing.assert_array_equal(np.asarray(r.value), np.asarray(sv))
    assert set(r.heap) == set(sh)
    for k in sh:
        np.testing.assert_array_equal(
            np.asarray(r.heap[k]), np.asarray(sh[k]), err_msg=k
        )
    assert r.stats.epochs == ss.epochs
    assert r.stats.tasks_executed == ss.tasks_executed
    assert r.stats.total_forks == ss.total_forks
    assert r.stats.peak_tv_slots == ss.peak_tv_slots


@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_fleet_bit_identical_to_solo_across_p(shards):
    """Every job's value block, heap, and solo-comparable stats match the
    solo run exactly, whatever P (vmap fallback on one device)."""
    fleet = get_fleet("mixed3")
    solo = {c.name: _solo(c, q) for c, q in fleet}

    anchors = _handles(fleet)
    fl = ShardedFleet(anchors, shards=shards, chunk=4)
    extra = _handles(fleet, "_b") + _handles(fleet, "_c")
    for h in extra:
        assert fl.admit(h)
    done = fl.run()
    assert len(done) == len(anchors) + len(extra)
    for h in done:
        base = h.job.name.replace("_b", "").replace("_c", "")
        _assert_solo_identical(h, solo[base])


@pytest.mark.parametrize("shards", [2, 4])
def test_work_conservation_across_shards(shards):
    """Summed per-shard tasks/forks equal the solo totals exactly, and the
    fleet's collective V_inf is one dispatch + one readback per step —
    not per shard."""
    fleet = get_fleet("mixed3")
    reps = 3
    solo_tasks = solo_forks = 0
    for c, q in fleet:
        _, _, s = _solo(c, q)
        solo_tasks += reps * s.tasks_executed
        solo_forks += reps * s.total_forks

    anchors = _handles(fleet)
    fl = ShardedFleet(anchors, shards=shards, chunk=4,
                      placement="least_loaded")
    for tag in ("_b", "_c")[: reps - 1]:
        for h in _handles(fleet, tag):
            assert fl.admit(h)
    fl.run()

    per_shard = fl.shard_stats()
    assert len(per_shard) == shards
    assert sum(s.tasks_executed for s in per_shard) == solo_tasks
    assert sum(s.total_forks for s in per_shard) == solo_forks
    total = fl.stats()
    assert total.tasks_executed == solo_tasks
    assert total.total_forks == solo_forks
    # collective accounting: the whole point of the fleet step
    assert total.dispatches == fl.collective_steps
    assert total.scalar_transfers == fl.collective_steps


def test_rebalance_migrates_queued_jobs_off_hot_shards():
    """Sticky placement pins every fib job to one shard; with rebalancing
    on, boundary migration drains the hot shard's queue through other
    shards' free regions — and the results stay solo-identical."""
    quota = 256
    case_jobs = [
        Job(fib.PROGRAM, fib.initial(9), quota=quota, name=f"fib#{i}")
        for i in range(8)
    ]
    _, sv, ss = HostEngine(fib.PROGRAM, capacity=quota).run(fib.initial(9))

    def build(rebalance):
        handles = [JobHandle(i, j) for i, j in enumerate(case_jobs[:1])]
        fl = ShardedFleet(handles, shards=4, chunk=2, placement="sticky",
                          rebalance=rebalance)
        for i, j in enumerate(case_jobs[1:], start=1):
            assert fl.admit(JobHandle(i, j))
        return fl

    fl = build(rebalance=True)
    done = fl.run()
    assert len(done) == 8
    assert fl.migrations > 0, (
        "sticky placement queued every job on one shard; rebalancing "
        "must have moved some to idle shards"
    )
    for h in done:
        np.testing.assert_array_equal(
            np.asarray(h.result.value), np.asarray(sv)
        )
        assert h.result.stats.tasks_executed == ss.tasks_executed

    pinned = build(rebalance=False)
    pinned.run()
    assert pinned.migrations == 0
    # affinity respected: only the sticky shard ever executed anything
    worked = [p for p, s in enumerate(pinned.shard_stats())
              if s.tasks_executed > 0]
    assert len(worked) == 1


def test_placement_policies():
    """round_robin cycles shards; sticky maps equal-structure jobs to one
    shard; least_loaded prefers empty shards; incompatible jobs are
    refused (left for the service queue)."""
    fleet = get_fleet("mixed3")
    anchors = _handles(fleet)
    fl = ShardedFleet(anchors, shards=3, chunk=4, placement="round_robin")
    # anchors placed round-robin: one wave's worth spread over 3 shards
    assert sum(len(q) for q in fl._pending) == len(anchors)
    assert [len(q) for q in fl._pending] == [1, 1, 1]

    sticky = ShardedFleet(_handles(fleet), shards=3, chunk=4,
                          placement="sticky")
    a = _handles(fleet, "_a")
    b = _handles(fleet, "_b")
    for h in a + b:
        assert sticky.admit(h)
    # same structure + quota -> same shard, always
    for ha, hb in zip(a, b):
        pa = [p for p, q in enumerate(sticky._pending) if ha in q]
        pb = [p for p, q in enumerate(sticky._pending) if hb in q]
        assert pa == pb

    # a job whose program structure matches no slot is refused
    alien = Job(
        get_fleet("fib_fleet")[0][0].program,
        get_fleet("fib_fleet")[0][0].initial,
        quota=1 << 20, name="too-big",
    )
    assert not fl.admit(JobHandle(99, alien))


def test_zero_retrace_under_migration_and_p_switch():
    """A sharded service reuses ONE compiled chunk template across waves,
    across migrations, and across shard counts: trace_count is flat after
    the first wave — the template key is deliberately not a function of
    P, and migration reseeds through the existing reseed path."""
    fleet = get_fleet("mixed3")

    def submit_all(svc, reps):
        for r in range(reps):
            for c, q in fleet:
                svc.submit_case(c, quota=q, name=f"{c.name}#{r}")

    svc = JobService(
        capacity=sum(q for _, q in fleet), engine="sharded", shards=2,
        chunk=4, max_jobs=len(fleet), placement="sticky",
    )
    submit_all(svc, 3)  # sticky + heterogenous -> migrations happen
    svc.drain()
    traced = svc.trace_count
    assert traced > 0
    assert svc._mux.migrations >= 0  # fleet drove to completion

    submit_all(svc, 2)  # identical consecutive wave shape
    svc.drain()
    assert svc.trace_count == traced, (
        "an identical consecutive sharded wave must not retrace"
    )

    # same template cache serves a different P: the chunk template is
    # NOT rebuilt (cache hit — same fused program, slots, and loop), the
    # only new tracing is the fleet wrapper for the new batch shape
    # (vmap/shard_map re-enters the cached body once per P), and
    # consecutive waves at the new P are again zero-retrace
    svc4 = JobService(
        capacity=sum(q for _, q in fleet), engine="sharded", shards=4,
        chunk=4, max_jobs=len(fleet),
        template_cache=svc.template_cache,
    )
    submit_all(svc4, 2)
    svc4.drain()
    assert svc4.template_cache.hits >= 1, (
        "switching shard counts must reuse the cached chunk template"
    )
    assert svc4.template_cache.misses == 1  # only the very first wave built
    traced4 = svc4.trace_count
    submit_all(svc4, 2)
    svc4.drain()
    assert svc4.trace_count == traced4, (
        "an identical consecutive wave at the new P must not retrace"
    )


def test_sharded_service_streams_and_matches_solo():
    """The service front door: engine='sharded' drains a many-rep queue
    through placement + streaming admission, results solo-identical."""
    fleet = get_fleet("mixed3")
    solo = {c.name: _solo(c, q) for c, q in fleet}
    svc = JobService(
        capacity=sum(q for _, q in fleet), engine="sharded", shards=4,
        chunk=4, max_jobs=len(fleet), placement="least_loaded",
    )
    hs = []
    for r in range(4):
        for c, q in fleet:
            hs.append(svc.submit_case(c, quota=q, name=f"{c.name}#{r}"))
    done = svc.drain()
    assert len(done) == len(hs)
    for h in hs:
        _assert_solo_identical(h, solo[h.job.name.split("#")[0]])


def test_sharded_engine_validation():
    with pytest.raises(ValueError, match="shards"):
        JobService(engine="device", shards=2)
    with pytest.raises(ValueError, match="placement"):
        JobService(engine="sharded", shards=2, placement="random")
    with pytest.raises(ValueError, match="shards"):
        JobService(engine="sharded", shards=0)


def test_fleet_mesh_fallback_and_shard_map_path():
    """make_fleet_mesh degrades to None (vmap fallback) when the host has
    too few devices; the real shard_map path runs in a subprocess with 8
    forced host devices and must be bit-identical to solo."""
    from repro.launch.mesh import make_fleet_mesh

    assert make_fleet_mesh(1) is None  # P=1: never worth a mesh
    import jax

    if len(jax.devices()) < 64:
        assert make_fleet_mesh(64) is None  # degraded, not an error
    with pytest.raises(ValueError):
        make_fleet_mesh(0)

    script = """
import numpy as np
import jax
assert jax.device_count() == 8, jax.device_count()
from repro.apps import get_fleet
from repro.core import HostEngine
from repro.distributed import ShardedFleet
from repro.service import Job, JobHandle

fleet = get_fleet("mixed3")
solo = {}
for c, q in fleet:
    eng = HostEngine(c.program, capacity=q)
    solo[c.name] = eng.run(c.initial, heap_init=dict(c.heap_init) or None)

handles = [
    JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                     quota=q, name=c.name))
    for i, (c, q) in enumerate(fleet)
]
fl = ShardedFleet(handles, shards=8, chunk=4)
assert fl.mesh is not None, "8 devices must yield a real fleet mesh"
for tag in ("_b", "_c"):
    for i, (c, q) in enumerate(fleet):
        assert fl.admit(JobHandle(100 + i, Job(
            c.program, c.initial, heap_init=dict(c.heap_init),
            quota=q, name=c.name + tag)))
done = fl.run()
assert len(done) == 9, len(done)
for h in done:
    base = h.job.name.replace("_b", "").replace("_c", "")
    sh, sv, ss = solo[base]
    np.testing.assert_array_equal(np.asarray(h.result.value),
                                  np.asarray(sv))
    for k in sh:
        np.testing.assert_array_equal(np.asarray(h.result.heap[k]),
                                      np.asarray(sh[k]))
    assert h.result.stats.tasks_executed == ss.tasks_executed
    assert h.result.stats.epochs == ss.epochs
st = fl.stats()
assert st.dispatches == fl.collective_steps
print("SHARD_MAP_OK", fl.collective_steps)
"""
    env = {
        "PYTHONPATH": SRC,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PATH": "/usr/bin:/bin",
        "HOME": "/tmp",
    }
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARD_MAP_OK" in proc.stdout
