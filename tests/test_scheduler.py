"""Scheduler-layer tests: EpochScheduler stack discipline + coalescing,
dispatch policies, masked vs compacted equivalence across every registered
app case, and the pluggable stats collectors."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import all_cases, fib, get_case
from repro.core import (
    COMPACTED,
    DeviceEngine,
    EpochScheduler,
    HeapVar,
    HostEngine,
    InitialTask,
    MapType,
    MASKED,
    NullStats,
    Program,
    RunStatsCollector,
    TaskType,
    launch_bucket,
    resolve_policy,
)


# ------------------------------------------------------------- scheduler
def test_scheduler_lifo_order():
    s = EpochScheduler(coalesce=False)
    s.reset()
    s.push_join(1, 0, 1)
    s.push_forked(2, 1, 3)
    d = s.pop()
    assert (d.cen, d.start, d.count) == (2, 1, 3)  # forked first (LIFO)
    d = s.pop()
    assert (d.cen, d.start, d.count) == (1, 0, 1)
    d = s.pop()  # the reset seed range
    assert (d.cen, d.start, d.count) == (1, 0, 1)
    assert not s


def test_scheduler_default_coalesces_join_with_seed():
    """With coalescing on, a re-armed join range at the same CEN as another
    stacked range drains in a single pop."""
    s = EpochScheduler()
    s.reset()
    s.push_join(1, 0, 1)
    s.push_forked(2, 1, 3)
    assert s.pop().cen == 2
    d = s.pop()
    assert (d.cen, d.start, d.count, d.n_ranges) == (1, 0, 1, 2)
    assert not s


def test_scheduler_coalesces_same_cen_ranges():
    """All ranges at the current epoch number merge into one dispatch —
    phase 1+3 overhead paid once for the whole system (§3 work-together a)."""
    s = EpochScheduler(coalesce=True)
    s.push_forked(3, 0, 4)
    s.push_forked(3, 10, 6)
    s.push_forked(3, 4, 2)
    d = s.pop()
    assert d.cen == 3
    assert (d.start, d.count) == (0, 16)  # covering span of all three
    assert d.n_ranges == 3
    assert not s


def test_scheduler_coalescing_stops_at_other_cen():
    s = EpochScheduler(coalesce=True)
    s.push_forked(2, 0, 4)
    s.push_forked(3, 4, 4)
    d = s.pop()
    assert (d.cen, d.n_ranges) == (3, 1)
    d = s.pop()
    assert (d.cen, d.n_ranges) == (2, 1)


def test_scheduler_no_coalesce_flag():
    s = EpochScheduler(coalesce=False)
    s.push_forked(3, 0, 4)
    s.push_forked(3, 8, 2)
    assert s.pop().n_ranges == 1
    assert s.pop().n_ranges == 1


def test_push_forked_ignores_empty_range():
    s = EpochScheduler()
    s.push_forked(2, 5, 0)
    assert not s


def test_pop_on_empty_scheduler_raises_clear_error():
    s = EpochScheduler()
    with pytest.raises(RuntimeError, match="scheduler empty"):
        s.pop()
    s.push_forked(2, 0, 1)
    s.pop()
    with pytest.raises(RuntimeError, match="already drained"):
        s.pop()


# -------------------------------------------------------------- policies
def test_launch_bucket_sizing():
    assert launch_bucket(0) == 8
    assert launch_bucket(1) == 8
    assert launch_bucket(9) == 16
    assert launch_bucket(1, minimum=1) == 1
    assert launch_bucket(3, minimum=1) == 4


def test_policy_resolution():
    assert resolve_policy("masked") is MASKED
    assert resolve_policy(COMPACTED) is COMPACTED
    assert MASKED.epoch_bucket(5) == 8
    assert COMPACTED.type_bucket(5) == 8
    assert COMPACTED.type_bucket(3) == 4  # lane-exact minimum of 1
    assert COMPACTED.type_bucket(0) == 0  # idle type: no launch at all
    with pytest.raises(ValueError):
        resolve_policy("bogus")


def test_device_engine_rejects_compacted():
    with pytest.raises(ValueError):
        DeviceEngine(fib.PROGRAM, dispatch="compacted")


def test_mux_pop_policy_resolution_and_selection():
    from repro.core import FUSE_ALL, MuxPopPolicy, resolve_mux_policy

    # an explicit gang bound overrides a pre-built instance's
    assert resolve_mux_policy(FUSE_ALL, 2).gang == 2
    assert resolve_mux_policy(FUSE_ALL).gang == 0
    assert resolve_mux_policy("round_robin", 3) == MuxPopPolicy("round_robin", 3)
    with pytest.raises(ValueError):
        resolve_mux_policy("bogus")

    ready, depths = [0, 1, 2, 3], [5, 1, 9, 2]
    assert MuxPopPolicy("fuse_all").select(ready, depths, 0) == ready
    rr = MuxPopPolicy("round_robin", 2)
    assert rr.select(ready, depths, 0) == [0, 1]
    assert rr.select(ready, depths, 1) == [1, 2]
    assert rr.select(ready, depths, 5) == [1, 2]  # rotor wraps
    df = MuxPopPolicy("deepest_first", 2)
    assert df.select(ready, depths, 0) == [2, 0]  # depths 9, 5 first


# -------------------------------- masked vs compacted: every app, identical
@pytest.mark.parametrize("name", sorted(all_cases()))
def test_compacted_matches_masked_everywhere(name):
    """The §5.4 compaction stage may only change lane layout, never results:
    heaps and the full TV value array must be bit-identical."""
    case = get_case(name)
    hm, vm, sm = case.run(dispatch="masked")
    hc, vc, sc = case.run(dispatch="compacted")
    for k in hm:
        np.testing.assert_array_equal(
            np.asarray(hm[k]), np.asarray(hc[k]), err_msg=f"{name}:{k}"
        )
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(vc))
    assert sc.epochs == sm.epochs
    assert sc.tasks_executed == sm.tasks_executed
    # dense per-type slices must not waste more lanes than full-width vmaps
    assert sc.utilization >= sm.utilization


def test_compacted_reports_per_type_occupancy():
    _, _, stats = get_case("fib").run(dispatch="compacted")
    occ = stats.occupancy_by_type
    assert set(occ) == {"fib", "fibsum"}
    for v in occ.values():
        assert 0.0 < v <= 1.0
    # compaction pays one extra dispatch + transfer per epoch (§5.4 trade)
    _, _, masked = get_case("fib").run(dispatch="masked")
    assert stats.dispatches == 2 * masked.dispatches
    assert stats.scalar_transfers == 2 * masked.scalar_transfers


def test_compacted_with_pallas_interpret_kernels():
    """The compaction stage accepts the Pallas type_rank kernel (interpret
    mode on CPU) and produces the same schedule as the jnp reference."""
    from repro.kernels import ops as kops

    def rank_interpret(types, active, n_types):
        return kops.type_rank(types, active, n_types, impl="interpret")

    _, v_ref, s_ref = HostEngine(
        fib.PROGRAM, capacity=1 << 10, dispatch="compacted"
    ).run(fib.initial(9))
    _, v_pal, s_pal = HostEngine(
        fib.PROGRAM, capacity=1 << 10, dispatch="compacted",
        rank_fn=rank_interpret,
    ).run(fib.initial(9))
    assert int(v_ref[0, 0]) == int(v_pal[0, 0]) == fib.fib_reference(9)
    assert s_ref.epochs == s_pal.epochs
    assert s_ref.lanes_launched == s_pal.lanes_launched


# ----------------------------------------------------------------- stats
def test_ranges_coalesced_accounting():
    """RunStatsCollector credits every extra same-CEN range merged into a
    pop — the work-together fusion count — while NullStats ignores it."""
    col = RunStatsCollector()
    col.epoch(cen=3, n_ranges=3)  # 2 extra ranges merged
    col.epoch(cen=2, n_ranges=1)  # plain pop
    col.epoch(cen=1, n_ranges=4)
    stats = col.result()
    assert stats.epochs == 3
    assert stats.ranges_coalesced == (3 - 1) + (1 - 1) + (4 - 1)

    null = NullStats()
    null.epoch(cen=3, n_ranges=5)
    assert null.result().ranges_coalesced == 0


def test_coalescing_scheduler_feeds_ranges_into_stats():
    """Drive a coalescing scheduler's pops straight into the collector:
    the merged-range count must match what the scheduler actually fused."""
    s = EpochScheduler(coalesce=True)
    s.push_forked(2, 0, 2)
    s.push_forked(3, 4, 2)
    s.push_forked(3, 8, 2)
    s.push_forked(3, 2, 2)
    col = RunStatsCollector()
    while s:
        d = s.pop()
        col.epoch(d.cen, d.n_ranges)
    stats = col.result()
    assert stats.epochs == 2         # three CEN-3 ranges fused into one pop
    assert stats.ranges_coalesced == 2


def test_occupancy_by_type_accounting():
    """occupancy_by_type is per-type active/launched from the lanes() hook;
    types never reported stay absent rather than defaulting to 0/0."""
    col = RunStatsCollector()
    col.lanes(5, 8, {"a": (3, 4), "b": (2, 4)})
    col.lanes(3, 4, {"a": (3, 4)})
    stats = col.result()
    assert stats.tasks_executed == 8 and stats.lanes_launched == 12
    assert stats.tasks_by_type == {"a": 6, "b": 2}
    assert stats.lanes_by_type == {"a": 8, "b": 4}
    occ = stats.occupancy_by_type
    assert occ == {"a": 6 / 8, "b": 2 / 4}
    assert "c" not in occ


def test_engine_occupancy_consistent_with_totals():
    """Under the compacted dispatch the per-type lane ledger must tile the
    global counters exactly: sums over types equal tasks/lanes launched."""
    _, _, stats = get_case("fib").run(dispatch="compacted")
    assert sum(stats.tasks_by_type.values()) == stats.tasks_executed
    assert sum(stats.lanes_by_type.values()) == stats.lanes_launched
    for t, occ in stats.occupancy_by_type.items():
        assert occ == stats.tasks_by_type[t] / stats.lanes_by_type[t]
        assert 0.0 < occ <= 1.0


def test_null_stats_counts_only_control_terms():
    _, _, stats = HostEngine(
        fib.PROGRAM, capacity=1 << 10, collect_stats=False
    ).run(fib.initial(8))
    assert stats.epochs > 0 and stats.dispatches > 0
    assert stats.tasks_executed == 0 and stats.lanes_launched == 0


def test_stats_factory_plugs_in():
    seen = []

    def factory():
        col = RunStatsCollector()
        seen.append(col)
        return col

    eng = HostEngine(fib.PROGRAM, capacity=1 << 10, stats_factory=factory)
    _, _, stats = eng.run(fib.initial(8))
    assert len(seen) == 1
    assert stats is seen[0].result()
    assert stats.tasks_executed > 0


# ------------------------------------------------- map launch edge cases
def _zero_domain_map_program():
    """A task that schedules a map whose element domain is empty."""

    def _root(ctx):
        ctx.map("noop", argi=(0,))
        ctx.emit(1)

    def _noop(mctx):
        mctx.write("out", mctx.eid, 1, op="add")

    return Program(
        name="zero_dom",
        tasks=(TaskType("root", _root),),
        maps=(MapType("noop", _noop, domain=lambda ai: ai[..., 0], max_domain=8),),
        n_arg_i=1,
        heap=(HeapVar("out", (8,), jnp.int32),),
    )


def test_map_launch_skipped_when_domain_all_zero():
    """A scheduled map whose lanes all have empty domains must not dispatch
    a wasted payload (the dom[where].max()-on-zero sizing bug)."""
    prog = _zero_domain_map_program()
    heap, values, stats = HostEngine(prog, capacity=64).run(
        InitialTask(task="root", argi=(0,))
    )
    assert int(values[0, 0]) == 1
    assert stats.map_launches == 0
    assert np.asarray(heap["out"]).sum() == 0


# ------------------------------------------- batched device stacks (§9)
def test_batched_device_stacks_seed_and_pop():
    from repro.core import batched_device_pop, batched_device_stacks

    j, r, sp = batched_device_stacks(
        3, 4, cens=[1, 1, 1], starts=[0, 10, 20], counts=[1, 2, 3]
    )
    assert j.shape == (3, 4) and r.shape == (3, 4, 2)
    cen, start, count, live, sp2 = batched_device_pop(j, r, sp)
    np.testing.assert_array_equal(np.asarray(live), [True, True, True])
    np.testing.assert_array_equal(np.asarray(cen), [1, 1, 1])
    np.testing.assert_array_equal(np.asarray(start), [0, 10, 20])
    np.testing.assert_array_equal(np.asarray(count), [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(sp2), [0, 0, 0])
    # popping drained stacks reports dead regions with inert zero ranges
    cen, start, count, live, sp3 = batched_device_pop(j, r, sp2)
    np.testing.assert_array_equal(np.asarray(live), [False] * 3)
    np.testing.assert_array_equal(np.asarray(cen), [0, 0, 0])
    np.testing.assert_array_equal(np.asarray(count), [0, 0, 0])
    np.testing.assert_array_equal(np.asarray(sp3), [0, 0, 0])


def test_batched_device_push_is_per_region_conditional():
    from repro.core import (
        batched_device_pop,
        batched_device_push,
        batched_device_stacks,
    )

    j, r, sp = batched_device_stacks(2, 4)
    j, r, sp, of = batched_device_push(
        j, r, sp,
        jnp.asarray([5, 6]), jnp.asarray([7, 8]), jnp.asarray([2, 3]),
        jnp.asarray([True, False]), 4,
    )
    assert not bool(np.asarray(of).any())
    np.testing.assert_array_equal(np.asarray(sp), [2, 1])
    cen, start, count, live, _ = batched_device_pop(j, r, sp)
    # region 0 sees its new entry; region 1 still sees its seed
    np.testing.assert_array_equal(np.asarray(cen), [5, 1])
    np.testing.assert_array_equal(np.asarray(start), [7, 0])
    np.testing.assert_array_equal(np.asarray(count), [2, 1])


def test_batched_device_push_flags_overflow_per_region():
    from repro.core import batched_device_push, batched_device_stacks

    j, r, sp = batched_device_stacks(2, 1)  # depth 1: the seed fills it
    ones = jnp.asarray([1, 1])
    j, r, sp, of = batched_device_push(
        j, r, sp, ones, ones, ones, jnp.asarray([True, False]), 1
    )
    np.testing.assert_array_equal(np.asarray(of), [True, False])


def test_legacy_single_region_wrappers_match_batched():
    from repro.core.scheduler import device_push, device_stacks

    j, r = device_stacks(8, cen=2, start=3, count=4)
    assert j.shape == (8,) and r.shape == (8, 2)
    assert int(j[0]) == 2 and list(np.asarray(r[0])) == [3, 4]
    j2, r2, sp2 = device_push(
        j, r, jnp.asarray(1), jnp.asarray(9), jnp.asarray(5),
        jnp.asarray(6), jnp.asarray(True), 8,
    )
    assert int(sp2) == 2
    assert int(j2[1]) == 9 and list(np.asarray(r2[1])) == [5, 6]
