"""Engine behaviour tests: host vs device vs sequential-oracle equivalence,
paper-example semantics (Fig. 3), and hypothesis property tests on random
fork/join DAG programs."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps import fib, treewalk
from repro.core import (
    DeviceEngine,
    EngineError,
    HostEngine,
    Program,
    TaskType,
    InitialTask,
    HeapVar,
    compare,
    run_oracle,
)


@pytest.mark.parametrize("dispatch", ["masked", "compacted"])
@pytest.mark.parametrize("n,expect", [(0, 0), (1, 1), (2, 1), (10, 55), (14, 377)])
def test_fib_host(n, expect, dispatch):
    heap, values, stats = HostEngine(
        fib.PROGRAM, capacity=1 << 12, dispatch=dispatch
    ).run(fib.initial(n))
    assert int(values[0, 0]) == expect
    # critical path = one epoch per level down + one per join level up
    assert stats.epochs == (2 * n - 1 if n >= 2 else 1)


@pytest.mark.parametrize("n", [2, 8, 12])
def test_fib_device_matches_host(n):
    _, vh, sh = HostEngine(fib.PROGRAM, capacity=1 << 12).run(fib.initial(n))
    _, vd, sd = DeviceEngine(
        fib.PROGRAM, capacity=1 << 12, stack_depth=256
    ).run(fib.initial(n))
    assert int(vh[0, 0]) == int(vd[0, 0]) == fib.fib_reference(n)
    assert sh.epochs == sd.epochs


def test_fib_oracle_equivalence():
    heap_o, v_o, so = run_oracle(fib.PROGRAM, fib.initial(9), capacity=1 << 12)
    heap_e, v_e, se = HostEngine(fib.PROGRAM, capacity=1 << 12).run(
        fib.initial(9)
    )
    assert int(v_o[0, 0]) == int(v_e[0, 0])
    assert so.epochs == se.epochs
    assert so.tasks_executed == se.tasks_executed
    rep = compare(so, se)
    assert rep.t1_tasks == so.tasks_executed
    assert rep.v1_lane_factor >= 1.0
    assert rep.utilization <= 1.0


def test_overflow_raises():
    with pytest.raises(EngineError):
        HostEngine(fib.PROGRAM, capacity=16).run(fib.initial(12))


def test_treewalk_postorder_property():
    n = 21
    left, right = treewalk.random_tree(n, seed=11)
    prog = treewalk.make_program(n, "post")
    heap, _, _ = HostEngine(prog, capacity=1 << 10).run(
        treewalk.initial(), heap_init=dict(left=left, right=right)
    )
    ve = np.asarray(heap["visit_epoch"])
    for p in range(n):
        for c in (left[p], right[p]):
            if c >= 0:
                assert ve[p] > ve[c], "parent must be visited after children"


def test_treewalk_preorder_property():
    n = 17
    left, right = treewalk.random_tree(n, seed=4)
    prog = treewalk.make_program(n, "pre")
    heap, _, _ = HostEngine(prog, capacity=1 << 10).run(
        treewalk.initial(), heap_init=dict(left=left, right=right)
    )
    ve = np.asarray(heap["visit_epoch"])
    for p in range(n):
        for c in (left[p], right[p]):
            if c >= 0:
                assert ve[p] < ve[c], "parent must be visited before children"


# ---------------------------------------------------------------------------
# Property test: random fork/join DAG programs must match the oracle exactly.
# Each task carries (depth, salt); it pseudo-randomly forks 0..3 children,
# optionally joins to sum their values, and add-scatters into a heap cell.
# This exercises fork allocation contiguity, join LIFO order, emit routing,
# reclamation, and heap commit semantics all at once.
# ---------------------------------------------------------------------------
def _make_random_dag_program(max_depth: int, fanout_mod: int) -> Program:
    def _node(ctx):
        depth, salt = ctx.argi(0), ctx.argi(1)
        h = (salt * 31421 + depth * 6927 + 17) & 0x7FFF
        n_kids = jnp.where(depth >= max_depth, 0, h % fanout_mod)
        ctx.write("touch", (h % 16), 1, op="add")
        for k in range(fanout_mod - 1):
            ctx.fork(
                "node",
                argi=(depth + 1, h + 31 * k + 7),
                where=k < n_kids,
            )
        has_kids = n_kids > 0
        ctx.emit(depth + (h % 5), where=~has_kids)
        ctx.join("gather", argi=(depth, salt), where=has_kids)

    def _gather(ctx):
        cv = ctx.child_values(fanout_mod - 1)
        ctx.emit(cv[:, 0].sum() + 1)

    return Program(
        name="random_dag",
        tasks=(TaskType("node", _node), TaskType("gather", _gather)),
        n_arg_i=2,
        value_width=1,
        value_dtype=jnp.int32,
        heap=(HeapVar("touch", (16,), jnp.int32),),
    )


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**15 - 1),
    max_depth=st.integers(1, 4),
    fanout_mod=st.integers(2, 4),
)
def test_random_dag_engine_matches_oracle(seed, max_depth, fanout_mod):
    prog = _make_random_dag_program(max_depth, fanout_mod)
    init = InitialTask(task="node", argi=(0, seed))
    heap_o, v_o, so = run_oracle(prog, init, capacity=1 << 12)
    heap_e, v_e, se = HostEngine(prog, capacity=1 << 12).run(init)
    np.testing.assert_array_equal(np.asarray(heap_e["touch"]), heap_o["touch"])
    assert int(v_e[0, 0]) == int(v_o[0, 0])
    assert se.epochs == so.epochs
    assert se.tasks_executed == so.tasks_executed


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**15 - 1))
def test_random_dag_compacted_matches_oracle(seed):
    """Type-compacted dispatch on heterogeneous (node+gather) epochs must
    stay bit-identical to the sequential oracle."""
    prog = _make_random_dag_program(3, 3)
    init = InitialTask(task="node", argi=(0, seed))
    heap_o, v_o, so = run_oracle(prog, init, capacity=1 << 12)
    heap_c, v_c, sc = HostEngine(
        prog, capacity=1 << 12, dispatch="compacted"
    ).run(init)
    np.testing.assert_array_equal(np.asarray(heap_c["touch"]), heap_o["touch"])
    assert int(v_c[0, 0]) == int(v_o[0, 0])
    assert sc.epochs == so.epochs
    assert sc.tasks_executed == so.tasks_executed


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**15 - 1))
def test_random_dag_device_matches_host(seed):
    prog = _make_random_dag_program(3, 3)
    init = InitialTask(task="node", argi=(0, seed))
    heap_h, v_h, sh = HostEngine(prog, capacity=1 << 10).run(init)
    heap_d, v_d, sd = DeviceEngine(
        prog, capacity=1 << 10, stack_depth=256
    ).run(init)
    np.testing.assert_array_equal(
        np.asarray(heap_h["touch"]), np.asarray(heap_d["touch"])
    )
    assert int(v_h[0, 0]) == int(v_d[0, 0])
    assert sh.epochs == sd.epochs


def test_engine_with_pallas_fork_offsets():
    """The engine's fork-allocation plug point accepts the Pallas kernel
    (interpret mode on CPU) and produces identical schedules."""
    from repro.kernels import ops as kops

    def pallas_offsets(counts):
        return kops.fork_offsets(counts, impl="interpret")

    _, v_ref, s_ref = HostEngine(fib.PROGRAM, capacity=1 << 10).run(
        fib.initial(10)
    )
    _, v_pal, s_pal = HostEngine(
        fib.PROGRAM, capacity=1 << 10, fork_offsets_fn=pallas_offsets
    ).run(fib.initial(10))
    assert int(v_ref[0, 0]) == int(v_pal[0, 0]) == fib.fib_reference(10)
    assert s_ref.epochs == s_pal.epochs
    assert s_ref.tasks_executed == s_pal.tasks_executed
