"""Engine behaviour tests: host vs device vs sequential-oracle equivalence,
paper-example semantics (Fig. 3), and hypothesis property tests on random
fork/join DAG programs."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.apps import fib, treewalk
from repro.core import (
    DeviceEngine,
    EngineError,
    HostEngine,
    MapType,
    Program,
    TaskType,
    InitialTask,
    HeapVar,
    compare,
    run_oracle,
)


@pytest.mark.parametrize("dispatch", ["masked", "compacted"])
@pytest.mark.parametrize("n,expect", [(0, 0), (1, 1), (2, 1), (10, 55), (14, 377)])
def test_fib_host(n, expect, dispatch):
    heap, values, stats = HostEngine(
        fib.PROGRAM, capacity=1 << 12, dispatch=dispatch
    ).run(fib.initial(n))
    assert int(values[0, 0]) == expect
    # critical path = one epoch per level down + one per join level up
    assert stats.epochs == (2 * n - 1 if n >= 2 else 1)


@pytest.mark.parametrize("n", [2, 8, 12])
def test_fib_device_matches_host(n):
    _, vh, sh = HostEngine(fib.PROGRAM, capacity=1 << 12).run(fib.initial(n))
    _, vd, sd = DeviceEngine(
        fib.PROGRAM, capacity=1 << 12, stack_depth=256
    ).run(fib.initial(n))
    assert int(vh[0, 0]) == int(vd[0, 0]) == fib.fib_reference(n)
    assert sh.epochs == sd.epochs


def test_fib_oracle_equivalence():
    heap_o, v_o, so = run_oracle(fib.PROGRAM, fib.initial(9), capacity=1 << 12)
    heap_e, v_e, se = HostEngine(fib.PROGRAM, capacity=1 << 12).run(
        fib.initial(9)
    )
    assert int(v_o[0, 0]) == int(v_e[0, 0])
    assert so.epochs == se.epochs
    assert so.tasks_executed == se.tasks_executed
    rep = compare(so, se)
    assert rep.t1_tasks == so.tasks_executed
    assert rep.v1_lane_factor >= 1.0
    assert rep.utilization <= 1.0


def test_overflow_raises():
    with pytest.raises(EngineError):
        HostEngine(fib.PROGRAM, capacity=16).run(fib.initial(12))


def test_treewalk_postorder_property():
    n = 21
    left, right = treewalk.random_tree(n, seed=11)
    prog = treewalk.make_program(n, "post")
    heap, _, _ = HostEngine(prog, capacity=1 << 10).run(
        treewalk.initial(), heap_init=dict(left=left, right=right)
    )
    ve = np.asarray(heap["visit_epoch"])
    for p in range(n):
        for c in (left[p], right[p]):
            if c >= 0:
                assert ve[p] > ve[c], "parent must be visited after children"


def test_treewalk_preorder_property():
    n = 17
    left, right = treewalk.random_tree(n, seed=4)
    prog = treewalk.make_program(n, "pre")
    heap, _, _ = HostEngine(prog, capacity=1 << 10).run(
        treewalk.initial(), heap_init=dict(left=left, right=right)
    )
    ve = np.asarray(heap["visit_epoch"])
    for p in range(n):
        for c in (left[p], right[p]):
            if c >= 0:
                assert ve[p] < ve[c], "parent must be visited before children"


# ---------------------------------------------------------------------------
# Property test: random fork/join DAG programs must match the oracle exactly.
# Each task carries (depth, salt); it pseudo-randomly forks 0..3 children,
# optionally joins to sum their values, and add-scatters into a heap cell.
# This exercises fork allocation contiguity, join LIFO order, emit routing,
# reclamation, and heap commit semantics all at once.
# ---------------------------------------------------------------------------
def _make_random_dag_program(max_depth: int, fanout_mod: int) -> Program:
    def _node(ctx):
        depth, salt = ctx.argi(0), ctx.argi(1)
        h = (salt * 31421 + depth * 6927 + 17) & 0x7FFF
        n_kids = jnp.where(depth >= max_depth, 0, h % fanout_mod)
        ctx.write("touch", (h % 16), 1, op="add")
        for k in range(fanout_mod - 1):
            ctx.fork(
                "node",
                argi=(depth + 1, h + 31 * k + 7),
                where=k < n_kids,
            )
        has_kids = n_kids > 0
        ctx.emit(depth + (h % 5), where=~has_kids)
        ctx.join("gather", argi=(depth, salt), where=has_kids)

    def _gather(ctx):
        cv = ctx.child_values(fanout_mod - 1)
        ctx.emit(cv[:, 0].sum() + 1)

    return Program(
        name="random_dag",
        tasks=(TaskType("node", _node), TaskType("gather", _gather)),
        n_arg_i=2,
        value_width=1,
        value_dtype=jnp.int32,
        heap=(HeapVar("touch", (16,), jnp.int32),),
    )


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**15 - 1),
    max_depth=st.integers(1, 4),
    fanout_mod=st.integers(2, 4),
)
def test_random_dag_engine_matches_oracle(seed, max_depth, fanout_mod):
    prog = _make_random_dag_program(max_depth, fanout_mod)
    init = InitialTask(task="node", argi=(0, seed))
    heap_o, v_o, so = run_oracle(prog, init, capacity=1 << 12)
    heap_e, v_e, se = HostEngine(prog, capacity=1 << 12).run(init)
    np.testing.assert_array_equal(np.asarray(heap_e["touch"]), heap_o["touch"])
    assert int(v_e[0, 0]) == int(v_o[0, 0])
    assert se.epochs == so.epochs
    assert se.tasks_executed == so.tasks_executed


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**15 - 1))
def test_random_dag_compacted_matches_oracle(seed):
    """Type-compacted dispatch on heterogeneous (node+gather) epochs must
    stay bit-identical to the sequential oracle."""
    prog = _make_random_dag_program(3, 3)
    init = InitialTask(task="node", argi=(0, seed))
    heap_o, v_o, so = run_oracle(prog, init, capacity=1 << 12)
    heap_c, v_c, sc = HostEngine(
        prog, capacity=1 << 12, dispatch="compacted"
    ).run(init)
    np.testing.assert_array_equal(np.asarray(heap_c["touch"]), heap_o["touch"])
    assert int(v_c[0, 0]) == int(v_o[0, 0])
    assert sc.epochs == so.epochs
    assert sc.tasks_executed == so.tasks_executed


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**15 - 1))
def test_random_dag_device_matches_host(seed):
    prog = _make_random_dag_program(3, 3)
    init = InitialTask(task="node", argi=(0, seed))
    heap_h, v_h, sh = HostEngine(prog, capacity=1 << 10).run(init)
    heap_d, v_d, sd = DeviceEngine(
        prog, capacity=1 << 10, stack_depth=256
    ).run(init)
    np.testing.assert_array_equal(
        np.asarray(heap_h["touch"]), np.asarray(heap_d["touch"])
    )
    assert int(v_h[0, 0]) == int(v_d[0, 0])
    assert sh.epochs == sd.epochs


def test_engine_with_pallas_fork_offsets():
    """The engine's fork-allocation plug point accepts the Pallas kernel
    (interpret mode on CPU) and produces identical schedules."""
    from repro.kernels import ops as kops

    def pallas_offsets(counts):
        return kops.fork_offsets(counts, impl="interpret")

    _, v_ref, s_ref = HostEngine(fib.PROGRAM, capacity=1 << 10).run(
        fib.initial(10)
    )
    _, v_pal, s_pal = HostEngine(
        fib.PROGRAM, capacity=1 << 10, fork_offsets_fn=pallas_offsets
    ).run(fib.initial(10))
    assert int(v_ref[0, 0]) == int(v_pal[0, 0]) == fib.fib_reference(10)
    assert s_ref.epochs == s_pal.epochs
    assert s_ref.tasks_executed == s_pal.tasks_executed


# ------------------------------------------ exact resident accumulators
def test_hilo_pairs_count_past_int32_exactly():
    """The resident accumulators' hi/lo split-radix pairs count exactly
    past 2^31 (where a plain i32 lane would wrap), in both the scalar [2]
    and the per-region [J, 2] layouts."""
    import jax

    from repro.core.engine import _hilo_add, _hilo_value

    n = jnp.asarray(1 << 30, jnp.int32)

    def step(acc, _):
        return _hilo_add(acc, n), None

    acc, _ = jax.lax.scan(step, jnp.zeros((2,), jnp.int32), None, length=8)
    assert int(_hilo_value(acc)) == 8 << 30  # 2^33: far past i32

    nv = jnp.asarray([1 << 30, 7, 0], jnp.int32)

    def stepv(acc, _):
        return _hilo_add(acc, nv), None

    accv, _ = jax.lax.scan(
        stepv, jnp.zeros((3, 2), jnp.int32), None, length=6
    )
    np.testing.assert_array_equal(
        _hilo_value(accv), np.asarray([6 << 30, 42, 0], np.int64)
    )


def _make_mapper_program(D: int):
    """Synthetic high-volume map program: every epoch schedules one map
    over a D-element domain (bumping a heap counter per element) and forks
    the next tick — a per-epoch map-lane firehose for the accumulator
    tests."""
    def _tick(ctx):
        k = ctx.argi(0)
        more = k > 0
        ctx.map("bump", argi=(k,))
        ctx.fork("tick", argi=(k - 1,), where=more)
        ctx.emit(k, where=~more)

    def _bump(mctx):
        mctx.write("acc", mctx.eid, 1, op="add")

    return Program(
        name=f"mapper{D}",
        tasks=(TaskType("tick", _tick),),
        n_arg_i=1,
        value_width=1,
        value_dtype=jnp.int32,
        maps=(MapType(
            "bump", _bump,
            domain=lambda argi: argi[..., 0] * 0 + D,
            max_domain=D,
        ),),
        heap=(HeapVar("acc", (D,), jnp.int32),),
    )


def test_resident_map_accumulators_exact_on_high_volume_fleet():
    """A high-volume map fleet (one D-wide map launch per region per
    epoch) through the resident driver: the hi/lo accumulators report the
    exact element volumes a host-loop run counts, and the heap results are
    bit-identical."""
    from repro.service import DeviceMultiplexer, EpochMultiplexer, Job, \
        JobHandle

    D = 96
    prog = _make_mapper_program(D)
    steps = (37, 23)

    def handles():
        return [
            JobHandle(i, Job(prog, InitialTask(task="tick", argi=(s,)),
                             quota=64, name=f"m{s}"))
            for i, s in enumerate(steps)
        ]

    host = EpochMultiplexer(handles())
    host.run()
    hs = host.stats()
    dev_handles = handles()
    dev = DeviceMultiplexer(dev_handles)
    dev.run()
    ds = dev.stats()

    expected_elements = sum(s + 1 for s in steps) * D
    assert hs.map_elements == expected_elements
    assert ds.map_elements == expected_elements
    assert ds.map_launches == hs.map_launches
    assert ds.map_lanes_launched >= ds.map_elements
    for h in dev_handles:
        assert h.status.value == "done"
        acc = np.asarray(h.result.heap["acc"])
        np.testing.assert_array_equal(
            acc, np.full(D, int(h.job.name[1:]) + 1)
        )
        # per-region task/fork totals decode exactly from the hi/lo pairs
        s = int(h.job.name[1:])
        assert h.result.stats.tasks_executed == s + 1
        assert h.result.stats.total_forks == s
