"""Per-kernel validation: Pallas interpret-mode vs pure-jnp oracle, swept
across shapes and dtypes, plus hypothesis property tests for the scan."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import mha_flash
from repro.kernels.fork_compact import fork_scan
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.RandomState(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


# ------------------------------------------------------------- fork_scan
@pytest.mark.parametrize("n", [1, 8, 127, 1024, 4097])
@pytest.mark.parametrize("block", [256, 1024])
def test_fork_scan_shapes(n, block):
    x = RNG.randint(0, 7, n).astype(np.int32)
    offs, tot = fork_scan(jnp.asarray(x), block=block, interpret=True)
    ro, rt = ref.fork_scan_ref(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(offs), np.asarray(ro))
    assert int(tot) == int(rt)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 9), min_size=1, max_size=300))
def test_fork_scan_property(xs):
    x = jnp.asarray(np.asarray(xs, np.int32))
    offs, tot = fork_scan(x, block=256, interpret=True)
    # offsets are the exclusive prefix sum: contiguous child allocation
    np.testing.assert_array_equal(
        np.asarray(offs), np.cumsum([0] + xs[:-1])
    )
    assert int(tot) == sum(xs)


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,D",
    [
        (1, 2, 2, 32, 32, 32),    # MHA square
        (2, 8, 2, 64, 64, 64),    # GQA 4:1
        (1, 4, 1, 40, 72, 32),    # MQA, ragged lengths (padding paths)
        (1, 2, 2, 160, 160, 128), # multi-block q and kv
    ],
)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    qo = Skv - Sq if causal else 0
    got = mha_flash(
        q, k, v, causal=causal, q_offset=qo, block_q=32, block_k=32,
        interpret=True,
    )
    want = ref.mha_ref(q, k, v, causal=causal, q_offset=qo)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


# ------------------------------------------------------ decode attention
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D", [(2, 8, 2, 96, 32), (4, 4, 4, 300, 64), (1, 16, 2, 33, 128)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, Hq, Hkv, S, D, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)), dtype)
    kc = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), dtype)
    vc = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), dtype)
    lens = jnp.asarray(RNG.randint(1, S + 1, B), jnp.int32)
    got = decode_attention(q, kc, vc, lens, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


def test_decode_ragged_lengths_ignore_tail():
    """Garbage beyond `lengths` must not affect the output."""
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 32
    q = jnp.asarray(RNG.normal(size=(B, Hq, D)), jnp.float32)
    kc = RNG.normal(size=(B, Hkv, S, D)).astype(np.float32)
    vc = RNG.normal(size=(B, Hkv, S, D)).astype(np.float32)
    lens = jnp.asarray([17, 40], jnp.int32)
    out1 = decode_attention(q, jnp.asarray(kc), jnp.asarray(vc), lens, interpret=True)
    kc[0, :, 17:] = 1e6  # poison the invalid tail
    vc[0, :, 17:] = -1e6
    out2 = decode_attention(q, jnp.asarray(kc), jnp.asarray(vc), lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# --------------------------------------------------------------- SSD scan
@pytest.mark.parametrize(
    "S,H,P,N,chunk", [(32, 2, 8, 8, 8), (96, 3, 16, 16, 32), (65, 1, 32, 8, 16)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(S, H, P, N, chunk, dtype):
    x = jnp.asarray(RNG.normal(size=(S, H, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (S, H)), dtype)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, H), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(S, N)), dtype)
    C = jnp.asarray(RNG.normal(size=(S, N)), dtype)
    y, hf = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, hr = ref.ssd_scan_ref(x, dt, A, B, C)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(
        rtol=5e-5, atol=5e-5
    )
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol
    )
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), **tol)


def test_ssd_scan_chunk_invariance():
    """Chunk size is an implementation detail: results must not change."""
    S, H, P, N = 64, 2, 16, 8
    x = jnp.asarray(RNG.normal(size=(S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, H), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(S, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(S, N)), jnp.float32)
    y8, h8 = ssd_scan(x, dt, A, B, C, chunk=8, interpret=True)
    y32, h32 = ssd_scan(x, dt, A, B, C, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h32), rtol=1e-4, atol=1e-5)


def test_ssd_carries_initial_state():
    """Splitting a sequence and carrying h must equal one long scan."""
    S, H, P, N = 48, 2, 8, 8
    x = jnp.asarray(RNG.normal(size=(S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, H), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(S, N)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(S, N)), jnp.float32)
    y_full, h_full = ssd_scan(x, dt, A, B, C, chunk=16, interpret=True)
    y1, h1 = ssd_scan(x[:24], dt[:24], A, B[:24], C[:24], chunk=16, interpret=True)
    y2, h2 = ssd_scan(
        x[24:], dt[24:], A, B[24:], C[24:], h0=h1, chunk=16, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2])), np.asarray(y_full),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------ ops dispatch
def test_ops_ref_dispatch_on_cpu():
    q = jnp.asarray(RNG.normal(size=(1, 2, 16, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 16, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 16, 32)), jnp.float32)
    a = ops.attention(q, k, v, impl="auto")  # ref on CPU
    b = ops.attention(q, k, v, impl="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- type_rank
@pytest.mark.parametrize("n,T,blk", [(50, 3, 256), (1024, 2, 256), (3000, 5, 1024)])
def test_type_rank_matches_oracle(n, T, blk):
    from repro.kernels.fork_compact import type_rank
    from repro.kernels.ref import type_rank_ref

    t = jnp.asarray(RNG.randint(0, T, n), jnp.int32)
    a = jnp.asarray(RNG.rand(n) < 0.7)
    r, c = type_rank(t, a, T, block=blk, interpret=True)
    rr, cc = type_rank_ref(t, a, T)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cc))


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.tuples(st.integers(0, 3), st.booleans()), min_size=1,
             max_size=200)
)
def test_type_rank_compaction_property(lanes):
    """dest = starts[type] + rank must be a bijection onto [0, n_active):
    the paper's same-type-contiguity invariant (§5.4)."""
    from repro.kernels.fork_compact import type_rank

    t = jnp.asarray([x[0] for x in lanes], jnp.int32)
    a = jnp.asarray([x[1] for x in lanes])
    r, c = type_rank(t, a, 4, block=256, interpret=True)
    cnp, rnp, anp, tnp = map(np.asarray, (c, r, a, t))
    starts = np.concatenate([[0], np.cumsum(cnp)[:-1]])
    if anp.any():
        dest = starts[tnp[anp]] + rnp[anp]
        assert sorted(dest.tolist()) == list(range(int(anp.sum())))
    assert (rnp[~anp] == -1).all()


# -------------------------------------------------- segmented_fork_scan
@pytest.mark.parametrize(
    "n,n_segs,blk", [(64, 3, 32), (300, 5, 128), (1024, 2, 1024), (4097, 4, 1024)]
)
def test_segmented_fork_scan_matches_oracle(n, n_segs, blk):
    """A/B: the Pallas segmented scan (interpret mode) vs the jnp reference
    the JobArena commit uses by default — including out-of-range segment
    ids (unowned TV lanes), which must contribute nothing."""
    from repro.kernels.fork_compact import segmented_fork_scan

    counts = RNG.randint(0, 4, n).astype(np.int32)
    seg = RNG.randint(0, n_segs + 1, n).astype(np.int32)  # n_segs = unowned
    oi, ti = segmented_fork_scan(
        jnp.asarray(counts), jnp.asarray(seg), n_segs, block=blk,
        interpret=True,
    )
    orf, trf = ref.segmented_fork_scan_ref(
        jnp.asarray(counts), jnp.asarray(seg), n_segs
    )
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(orf))
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(trf))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3)),
                min_size=1, max_size=200))
def test_segmented_fork_scan_property(lanes):
    """Within every segment, offsets are that segment's exclusive cumsum —
    the per-region contiguous child allocation invariant."""
    counts = np.asarray([c for c, _ in lanes], np.int32)
    seg = np.asarray([s for _, s in lanes], np.int32)
    offs, totals = ref.segmented_fork_scan_ref(
        jnp.asarray(counts), jnp.asarray(seg), 4
    )
    offs, totals = np.asarray(offs), np.asarray(totals)
    for s in range(4):
        m = seg == s
        expect = np.cumsum(counts[m]) - counts[m]
        np.testing.assert_array_equal(offs[m], expect)
        assert totals[s] == counts[m].sum()


def test_segmented_fork_offsets_ops_dispatch():
    """ops wrapper: ref on CPU, interpret mode explicitly."""
    counts = jnp.asarray([1, 2, 0, 3], jnp.int32)
    seg = jnp.asarray([0, 1, 0, 1], jnp.int32)
    for impl in ("ref", "interpret"):
        offs, totals = ops.segmented_fork_offsets(counts, seg, 2, impl=impl)
        np.testing.assert_array_equal(np.asarray(offs), [0, 0, 1, 2])
        np.testing.assert_array_equal(np.asarray(totals), [1, 5])
