"""Property sweep over the stats invariants (ISSUE 7 satellite).

Across every driver × dispatch mode × chunk size K the runtime offers,
three accounting invariants must hold:

* ``tasks_executed`` is a property of the *program*, not the driver —
  identical everywhere (the work term T_1 in the paper's accounting);
* on resident paths, the span-ladder tiling is exact:
  ``lanes_launched + hole_lanes_skipped == epochs × capacity`` (every
  full-span lane is either launched or accounted as skipped — DESIGN.md
  §11's dense-frontier claim as an equation);
* the derived ratios ``utilization`` / ``map_utilization`` stay in
  [0, 1] (they feed the RATIO_BUCKETS histograms in ``obs/metrics.py``,
  whose top bucket is 1.0).

Uses hypothesis when installed, else the deterministic stub
(``tests/_hypothesis_stub.py``) — same idiom as
``tests/test_dispatch_sweep.py``.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.apps import fib, get_case
from repro.core import DeviceEngine, HostEngine
from repro.service import DeviceMultiplexer, EpochMultiplexer, Job, \
    JobHandle, WaveTemplate

_POOL = ("fib", "treewalk")
_QUOTAS = (512, 1024)


def _handles(fleet):
    return [
        JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                         quota=q, name=f"{c.name}#{i}"))
        for i, (c, q) in enumerate(fleet)
    ]


def _check_ratios(s, label):
    assert 0.0 <= s.utilization <= 1.0, f"{label}: util={s.utilization}"
    assert 0.0 <= s.map_utilization <= 1.0, (
        f"{label}: map_util={s.map_utilization}"
    )


def _check_resident_tiling(s, capacity, label):
    assert s.lanes_launched + s.hole_lanes_skipped == s.epochs * capacity, (
        f"{label}: launched {s.lanes_launched} + skipped "
        f"{s.hole_lanes_skipped} != {s.epochs} epochs x {capacity} lanes"
    )


@settings(max_examples=3, deadline=None)
@given(members=st.lists(
    st.tuples(st.sampled_from(_POOL), st.sampled_from(_QUOTAS)),
    min_size=2, max_size=3,
))
def test_stats_invariants_across_drivers_dispatch_and_k(members):
    fleet = [(get_case(name), q) for name, q in members]
    tasks_ref = None

    # host multiplexer under every dispatch policy
    for dispatch in ("masked", "compacted", "gather"):
        handles = _handles(fleet)
        mux = EpochMultiplexer(handles, dispatch=dispatch)
        mux.run()
        s = mux.stats()
        if tasks_ref is None:
            tasks_ref = s.tasks_executed
        assert s.tasks_executed == tasks_ref, f"host:{dispatch}"
        _check_ratios(s, f"host:{dispatch}")

    # resident driver across dispatch x K (template reused across K — the
    # chunk bound is a dynamic argument of one compiled loop)
    for dispatch in ("masked", "gather"):
        template = None
        for chunk in (1, 4, None):
            handles = _handles(fleet)
            mux = DeviceMultiplexer(
                handles, dispatch=dispatch, chunk=chunk, template=template,
            )
            if template is None:
                template = WaveTemplate(
                    key=None, program=mux.program, slots=mux.slots,
                    loop=mux.loop,
                )
            mux.run()
            s = mux.stats()
            label = f"device:{dispatch}:K={chunk}"
            assert s.tasks_executed == tasks_ref, label
            _check_ratios(s, label)
            _check_resident_tiling(s, mux.capacity, label)


def test_solo_driver_invariants():
    """The solo engines obey the same equations (deterministic twin of
    the sweep, pinned so a failure names the exact configuration)."""
    cap = 256
    tasks_ref = None
    host_stats = {}
    for dispatch in ("masked", "compacted", "gather"):
        _, _, s = HostEngine(
            fib.PROGRAM, capacity=cap, dispatch=dispatch
        ).run(fib.initial(9))
        host_stats[dispatch] = s
        if tasks_ref is None:
            tasks_ref = s.tasks_executed
        assert s.tasks_executed == tasks_ref, f"host:{dispatch}"
        _check_ratios(s, f"host:{dispatch}")
    # host gather: launched + skipped tiles exactly the lane volume the
    # masked driver paid (its full-span baseline is masked's launches,
    # which are themselves span-bucketed — not epochs x capacity)
    sg, sm = host_stats["gather"], host_stats["masked"]
    assert sg.lanes_launched + sg.hole_lanes_skipped == sm.lanes_launched

    _, _, ds = DeviceEngine(
        fib.PROGRAM, capacity=cap, stack_depth=256
    ).run(fib.initial(9))
    assert ds.tasks_executed == tasks_ref
    _check_ratios(ds, "device:solo")
    _check_resident_tiling(ds, cap, "device:solo")
    np.testing.assert_allclose(
        ds.utilization, ds.tasks_executed / max(1, ds.lanes_launched)
    )
