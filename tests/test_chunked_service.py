"""Chunked-resident wave tests (DESIGN.md §10).

The K-epoch chunk knob makes host-mux (K=1) and fully-resident (K=None)
two endpoints of one driver: the resident ``lax.while_loop`` re-enters
every K epochs, the host reads back one compact ``ChunkSummary`` per
chunk, and between chunks it streams completions and reseeds freed
regions.  The load-bearing properties:

  * per-job results stay bit-identical to solo ``HostEngine.run`` at
    *every* K, and the wave pays exactly ⌈epochs/K⌉ dispatches+readbacks;
  * chunk boundaries restore the host-mux-only features to the resident
    path (streaming completions, mid-flight admission) without perturbing
    the per-job schedules;
  * trailing-drain edges (K larger than the remaining epochs, steps after
    the wave drained) are clean no-ops on the stats ledger;
  * structurally identical consecutive waves reuse one compiled chunk
    template with zero new traces (the compile-count regression guard).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.apps import fib, get_case, get_fleet
from repro.core import DeviceEngine, HostEngine
from repro.service import (
    DeviceMultiplexer,
    Job,
    JobFailure,
    JobHandle,
    JobService,
    JobStatus,
    WaveTemplate,
)


def _handles(fleet):
    return [
        JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                         quota=q, name=c.name))
        for i, (c, q) in enumerate(fleet)
    ]


@pytest.fixture(scope="module")
def solo_results():
    """Cache solo HostEngine runs per (case, quota) across this module."""
    cache = {}

    def get(case, quota):
        key = (case.name, quota)
        if key not in cache:
            eng = HostEngine(case.program, capacity=quota)
            cache[key] = eng.run(
                case.initial, heap_init=dict(case.heap_init) or None
            )
        return cache[key]

    return get


@pytest.fixture(scope="module")
def fleet_templates():
    """Share one compiled chunk template per fleet across every K (the
    template is K-independent — the bound is a dynamic argument — so this
    is exactly the production reuse path, exercised for free)."""
    return {}


def _make_mux(fleet_name, chunk, templates):
    handles = _handles(get_fleet(fleet_name))
    tpl = templates.get(fleet_name)
    mux = DeviceMultiplexer(handles, chunk=chunk, template=tpl)
    if tpl is None:
        templates[fleet_name] = WaveTemplate(
            key=fleet_name, program=mux.program, slots=mux.slots,
            loop=mux.loop,
        )
    return handles, mux


# ------------------------------------------------ the acceptance equivalence
@pytest.mark.parametrize("chunk", [1, 4, None])
@pytest.mark.parametrize("fleet_name", ["mixed3", "mixed4", "fib_fleet"])
def test_chunked_wave_bit_identical_with_ceil_vinf(
    fleet_name, chunk, solo_results, fleet_templates
):
    """Acceptance: every registry fleet through the chunked wave driver is
    bit-identical per job to solo runs at K ∈ {1, 4, ∞}, and the wave pays
    exactly ⌈epochs/K⌉ dispatches + scalar readbacks."""
    fleet = get_fleet(fleet_name)
    solo = {c.name: solo_results(c, q) for c, q in fleet}
    handles, mux = _make_mux(fleet_name, chunk, fleet_templates)
    done = mux.run()
    assert {h.job_id for h in done} == {h.job_id for h in handles}

    for h in handles:
        sh, sv, ss = solo[h.job.name]
        assert h.status is JobStatus.DONE
        np.testing.assert_array_equal(
            np.asarray(h.result.value), np.asarray(sv),
            err_msg=f"{h.job.name}:value@K={chunk}",
        )
        assert set(h.result.heap) == set(sh)
        for k in sh:
            np.testing.assert_array_equal(
                np.asarray(h.result.heap[k]), np.asarray(sh[k]),
                err_msg=f"{h.job.name}:{k}@K={chunk}",
            )
        assert h.result.stats.epochs == ss.epochs
        assert h.result.stats.tasks_executed == ss.tasks_executed
        assert h.result.stats.total_forks == ss.total_forks
        assert h.result.stats.peak_tv_slots == ss.peak_tv_slots

    fs = mux.stats()
    member_epochs = [solo[c.name][2].epochs for c, _ in fleet]
    E = max(member_epochs)  # fuse_all: every live region pops each epoch
    expected = 1 if chunk is None else math.ceil(E / chunk)
    assert fs.epochs == E
    assert fs.dispatches == expected
    assert fs.scalar_transfers == expected
    assert fs.ranges_coalesced == sum(member_epochs) - E


def test_k3_matches_k_none(solo_results, fleet_templates):
    """An odd K that does not divide the epoch count (the satellite's K=3)
    still drains cleanly: same results, ⌈E/3⌉ readbacks."""
    fleet = get_fleet("fib_fleet")
    solo = {c.name: solo_results(c, q) for c, q in fleet}
    handles, mux = _make_mux("fib_fleet", 3, fleet_templates)
    mux.run()
    E = max(solo[c.name][2].epochs for c, _ in fleet)
    fs = mux.stats()
    assert fs.scalar_transfers == math.ceil(E / 3)
    for h in handles:
        _, sv, _ = solo[h.job.name]
        np.testing.assert_array_equal(
            np.asarray(h.result.value), np.asarray(sv)
        )


# ------------------------------------------------- chunk-boundary features
def test_streaming_completion_surfaces_before_wave_drains(fleet_templates):
    """With a finite K, a short job's handle resolves at a chunk boundary
    while a long neighbour is still mid-wave — the feature the blind O(1)
    wave gave up."""
    short = JobHandle(0, Job(fib.PROGRAM, fib.initial(4), quota=64,
                             name="short"))
    long_ = JobHandle(1, Job(fib.PROGRAM, fib.initial(12), quota=512,
                             name="long"))
    mux = DeviceMultiplexer([short, long_], chunk=2)
    boundaries = 0
    while not short.done:
        mux.step()
        boundaries += 1
    assert short.status is JobStatus.DONE
    assert long_.status is JobStatus.RUNNING  # wave not drained yet
    _, sv, ss = HostEngine(fib.PROGRAM, capacity=64).run(fib.initial(4))
    np.testing.assert_array_equal(np.asarray(short.result.value),
                                  np.asarray(sv))
    assert boundaries == math.ceil((2 * 4 - 1) / 2)  # its own epochs / K
    mux.run()
    assert long_.status is JobStatus.DONE


def test_job_admitted_mid_wave_completes_bit_identically():
    """A structurally-equal job admitted into a freed region between chunks
    completes bit-identically to its solo run; the carried-over neighbour
    is unperturbed."""
    first = JobHandle(0, Job(fib.PROGRAM, fib.initial(4), quota=64,
                             name="first"))
    long_ = JobHandle(1, Job(fib.PROGRAM, fib.initial(12), quota=512,
                             name="long"))
    mux = DeviceMultiplexer([first, long_], chunk=2)
    while not first.done:
        mux.step()
    late = JobHandle(2, Job(fib.PROGRAM, fib.initial(6), quota=64,
                            name="late"))
    assert mux.admit(late) is True
    assert late.status is JobStatus.RUNNING
    mux.run()
    for h, n, q in ((late, 6, 64), (long_, 12, 512)):
        assert h.status is JobStatus.DONE
        _, sv, ss = HostEngine(fib.PROGRAM, capacity=q).run(fib.initial(n))
        np.testing.assert_array_equal(
            np.asarray(h.result.value), np.asarray(sv), err_msg=h.job.name
        )
        assert h.result.stats.epochs == ss.epochs
        assert h.result.stats.peak_tv_slots == ss.peak_tv_slots


def test_fully_resident_wave_stays_closed_to_admission():
    """K=None keeps the PR-3 contract: no chunk boundaries, no admission."""
    mux = DeviceMultiplexer(
        [JobHandle(0, Job(fib.PROGRAM, fib.initial(8), quota=128))],
        chunk=None,
    )
    late = JobHandle(1, Job(fib.PROGRAM, fib.initial(8), quota=128))
    assert mux.admit(late) is False
    mux.step()
    assert mux.admit(late) is False


def test_mid_chunk_overflow_isolates_one_region():
    """A region overflowing *inside* a chunk zeroes its own stack pointer
    and fails at the next boundary; its neighbour finishes bit-identically."""
    bad = JobHandle(0, Job(fib.PROGRAM, fib.initial(12), quota=8,
                           name="bad"))
    good = JobHandle(1, Job(fib.PROGRAM, fib.initial(10), quota=512,
                            name="good"))
    mux = DeviceMultiplexer([bad, good], chunk=2)
    mux.run()
    assert bad.status is JobStatus.FAILED
    assert isinstance(bad.error, JobFailure)
    assert good.status is JobStatus.DONE
    _, sv, ss = HostEngine(fib.PROGRAM, capacity=512).run(fib.initial(10))
    np.testing.assert_array_equal(np.asarray(good.result.value),
                                  np.asarray(sv))
    assert good.result.stats.epochs == ss.epochs


# ----------------------------------------------------- trailing-drain edges
def test_chunk_larger_than_remaining_epochs_is_clean():
    """K > the wave's total epochs degenerates to the fully resident wave:
    one chunk, identical stats, no phantom epochs from the unused budget."""
    def run(chunk):
        h = JobHandle(0, Job(fib.PROGRAM, fib.initial(10), quota=512))
        mux = DeviceMultiplexer([h], chunk=chunk)
        mux.run()
        return h, mux.stats()

    h_inf, s_inf = run(None)
    h_big, s_big = run(1000)  # far beyond the 19 epochs actually needed
    assert dataclasses.asdict(s_big) == dataclasses.asdict(s_inf)
    np.testing.assert_array_equal(
        np.asarray(h_big.result.value), np.asarray(h_inf.result.value)
    )
    # a K that overshoots only the *last* chunk is equally clean
    h_k10, s_k10 = run(10)  # chunks of 10 + 9
    assert s_k10.scalar_transfers == 2
    for f in ("epochs", "tasks_executed", "total_forks", "map_launches",
              "map_elements", "map_lanes_launched", "lanes_launched"):
        assert getattr(s_k10, f) == getattr(s_inf, f), f


def test_empty_wave_steps_do_not_perturb_stats():
    """Steps after the wave drained are no-ops: no dispatches, no epochs,
    no map-lane counters — the stats ledger is untouched."""
    h = JobHandle(0, Job(fib.PROGRAM, fib.initial(8), quota=128))
    mux = DeviceMultiplexer([h], chunk=4)
    mux.run()
    snap = dataclasses.asdict(mux.stats())
    assert mux.step() == []
    assert mux.step() == []
    assert dataclasses.asdict(mux.stats()) == snap


# ------------------------------------------------ compile-count regression
def test_identical_consecutive_waves_reuse_template_zero_traces():
    """The wave-template cache: two identical consecutive waves through
    JobService(engine='device') hit the cache and retrace *nothing* — the
    trace-counter hook on the step/loop builders stays flat."""
    svc = JobService(capacity=512, max_jobs=2, engine="device", chunk=3)
    ns = (8, 9)
    wave_a = [svc.submit(fib.PROGRAM, fib.initial(n), quota=256) for n in ns]
    svc.drain()
    traces_after_a = svc.trace_count
    assert traces_after_a > 0
    assert svc.template_cache.misses == 1
    assert svc.template_cache.hits == 0

    wave_b = [svc.submit(fib.PROGRAM, fib.initial(n), quota=256) for n in ns]
    svc.drain()
    assert svc.trace_count == traces_after_a  # zero new traces
    assert svc.template_cache.hits == 1
    for h, n in zip(wave_a + wave_b, ns + ns):
        assert h.status is JobStatus.DONE
        assert int(np.asarray(h.result.value)[0, 0]) == fib.fib_reference(n)


def test_permuted_wave_reuses_template_zero_traces():
    """Cache-key canonicalization: a wave that is a *permutation* of an
    earlier wave's members (mixed programs and quotas) reuses the cached
    template with zero new traces — the key and the seating order both
    canonicalize on (structural hash, quota), so member submission order
    no longer splinters the cache."""
    fibc, treec = get_case("fib"), get_case("treewalk")
    solo = {}
    for c, q in ((fibc, 512), (treec, 256)):
        eng = HostEngine(c.program, capacity=q)
        solo[c.name] = eng.run(
            c.initial, heap_init=dict(c.heap_init) or None
        )

    svc = JobService(capacity=768, max_jobs=2, engine="device", chunk=3)
    wave_a = [
        svc.submit_case(fibc, quota=512),
        svc.submit_case(treec, quota=256),
    ]
    svc.drain()
    traces_after_a = svc.trace_count
    assert traces_after_a > 0
    assert svc.template_cache.misses == 1

    # resubmit the same members permuted: must hit the same template
    wave_b = [
        svc.submit_case(treec, quota=256),
        svc.submit_case(fibc, quota=512),
    ]
    svc.drain()
    assert svc.trace_count == traces_after_a  # zero new traces
    assert svc.template_cache.hits == 1
    assert svc.template_cache.misses == 1

    for h in wave_a + wave_b:
        sh, sv, ss = solo[h.job.name]
        assert h.status is JobStatus.DONE
        np.testing.assert_array_equal(
            np.asarray(h.result.value), np.asarray(sv), err_msg=h.job.name
        )
        for k in sh:
            np.testing.assert_array_equal(
                np.asarray(h.result.heap[k]), np.asarray(sh[k]),
                err_msg=f"{h.job.name}:{k}",
            )
        assert h.result.stats.epochs == ss.epochs


def test_wave_template_key_is_order_insensitive():
    """The key itself canonicalizes member order, carrying quotas through
    the permutation — and still distinguishes genuinely different quota
    layouts."""
    from repro.service import wave_template_key

    fibc, treec = get_case("fib"), get_case("treewalk")
    a = Job(fibc.program, fibc.initial, quota=512, name="fib")
    b = Job(treec.program, treec.initial,
            heap_init=dict(treec.heap_init), quota=256, name="treewalk")
    k_ab = wave_template_key([a, b], 768, 1 << 10, 3)
    k_ba = wave_template_key([b, a], 768, 1 << 10, 3)
    assert k_ab == k_ba
    # different quota for the same member is a different wave shape
    a2 = Job(fibc.program, fibc.initial, quota=256, name="fib")
    assert wave_template_key([a2, b], 768, 1 << 10, 3) != k_ab


def test_service_streams_admission_through_chunked_waves():
    """JobService(engine='device', chunk=K): a queued third job streams
    into the freed region of the live wave — one wave shape ever compiled,
    all results exact."""
    svc = JobService(capacity=1024, max_jobs=2, engine="device", chunk=2)
    ns = (4, 12, 6)
    handles = [
        svc.submit(fib.PROGRAM, fib.initial(n), quota=512, name=f"fib{n}")
        for n in ns
    ]
    svc.drain()
    for h, n in zip(handles, ns):
        assert h.status is JobStatus.DONE
        assert int(np.asarray(h.result.value)[0, 0]) == fib.fib_reference(n)
    # the third job was admitted mid-wave: no second wave was ever fused
    assert svc.template_cache.misses == 1
    assert svc.template_cache.hits == 0


# -------------------------------------------- live-span bucketed task steps
def test_resident_task_launches_bucket_to_live_span(fleet_templates):
    """DESIGN.md §11: the resident epoch step launches at the smallest
    span-ladder width covering the popped ranges, not full TV width — the
    skipped hole lanes are accounted, and launched + skipped tiles
    epochs x capacity exactly."""
    handles, mux = _make_mux("mixed3", None, fleet_templates)
    mux.run()
    assert all(h.status is JobStatus.DONE for h in handles)
    fs = mux.stats()
    assert fs.hole_lanes_skipped > 0
    assert fs.lanes_launched + fs.hole_lanes_skipped == (
        fs.epochs * mux.capacity
    )
    assert fs.utilization == fs.tasks_executed / fs.lanes_launched


def test_solo_device_engine_skips_hole_lanes():
    """The solo resident engine rides the same ladder: a small popped
    range in a large TV stops paying full-capacity launches."""
    cap = 1 << 12
    _, _, ds = DeviceEngine(
        fib.PROGRAM, capacity=cap, stack_depth=512
    ).run(fib.initial(12))
    _, _, hs = HostEngine(fib.PROGRAM, capacity=cap).run(fib.initial(12))
    assert ds.tasks_executed == hs.tasks_executed
    assert ds.hole_lanes_skipped > 0
    assert ds.lanes_launched + ds.hole_lanes_skipped == ds.epochs * cap
    assert ds.lanes_launched < ds.epochs * cap


# --------------------------------------------- bucketed resident map sizing
def test_resident_map_payloads_bucket_below_max_domain():
    """Resident map payloads launch at a traced power-of-2 bucket of the
    live domains instead of always MapType.max_domain — results stay
    bit-identical and the measured lane waste shrinks."""
    case = get_case("mergesort")
    max_domain = max(m.max_domain for m in case.program.maps)
    hh, hv, hs = HostEngine(case.program, capacity=case.capacity).run(
        case.initial, heap_init=dict(case.heap_init) or None
    )
    dh, dv, ds = DeviceEngine(case.program, capacity=case.capacity).run(
        case.initial, heap_init=dict(case.heap_init) or None
    )
    np.testing.assert_array_equal(np.asarray(dh["src"]), np.asarray(hh["src"]))
    assert ds.map_launches > 0
    assert ds.map_elements == hs.map_elements  # same useful work
    # strictly below the old always-max_domain sizing
    assert ds.map_lanes_launched < ds.map_launches * case.capacity * max_domain
    assert ds.map_lanes_launched >= ds.map_elements
