"""Substrate tests: data determinism, atomic checkpointing, fault-tolerant
exact resume, straggler monitor, serving engine, optimizer."""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpointing import CheckpointManager
from repro.data import PackedDataset, SyntheticLM
from repro.models.model import init_model
from repro.optim import AdamW, cosine_schedule
from repro.runtime import FailureInjector, TrainRunner
from repro.launch.train import build, make_train_step
from repro.serving import EpochServer, Request


# ------------------------------------------------------------------- data
def test_data_step_indexed_determinism():
    d = SyntheticLM(vocab=100, seq_len=32, global_batch=4, seed=7)
    a, b = d.batch_at(13), d.batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(14)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token-shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_packed_dataset_masks_document_boundaries():
    d = PackedDataset(vocab=50, seq_len=128, global_batch=2, mean_doc_len=20)
    b = d.batch_at(0)
    eos_pos = b["tokens"] == d.eos
    # labels at eos positions are masked (never predict across docs)
    assert (b["labels"][eos_pos] == -1).all()
    assert (b["labels"] >= -1).all()


# ------------------------------------------------------------ checkpoints
def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for s in (10, 20, 30):
        mgr.save(s, tree)
    assert mgr.all_steps() == [20, 30]  # keep-2 gc
    step, restored, _ = mgr.restore_like(tree)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(
        np.asarray(restored["b"]["c"]), np.asarray(tree["b"]["c"])
    )


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    tree = {"w": jnp.zeros((128, 128))}
    mgr.save(1, tree)
    mgr.wait()
    # no tmp dirs left behind, manifest complete
    leftovers = list(pathlib.Path(tmp_path).glob("*.tmp-*"))
    assert leftovers == []
    d = pathlib.Path(tmp_path) / "step_00000001"
    m = json.loads((d / "manifest.json").read_text())
    assert m["step"] == 1 and m["keys"] == ["w"]


# -------------------------------------------------------- fault tolerance
def _tiny_setup(tmp_path, ckpt_every=5):
    cfg, params, opt_state, step_fn, data, _ = build(
        "granite_3_8b", reduced=True, batch=2, seq=32, steps=20, lr=1e-3
    )
    mgr = CheckpointManager(tmp_path, keep=3)
    return cfg, params, opt_state, step_fn, data, mgr


def test_exact_resume_after_failure(tmp_path):
    """Kill at step 13, restart from ckpt 10 -> identical final state.

    (train_step donates its inputs, so each run builds fresh initial state —
    same seed, identical init, exactly like a restarted worker.)"""
    cfg, p0, s0, step_fn, data, _ = _tiny_setup(tmp_path / "x")

    mgr_a = CheckpointManager(tmp_path / "a", keep=5)
    run_a = TrainRunner(step_fn, data, mgr_a, ckpt_every=5)
    pa, sa, hist_a = run_a.run(p0, s0, 20)

    _, p1, s1, _, _, _ = build(
        "granite_3_8b", reduced=True, batch=2, seq=32, steps=20, lr=1e-3
    )
    mgr_b = CheckpointManager(tmp_path / "b", keep=5)
    run_b = TrainRunner(
        step_fn, data, mgr_b, ckpt_every=5,
        failure=FailureInjector(fail_at_step=13),
    )
    pb, sb, hist_b = run_b.run_with_restarts(p1, s1, 20)

    for k in pa:
        np.testing.assert_array_equal(
            np.asarray(pa[k]), np.asarray(pb[k]), err_msg=k
        )
    np.testing.assert_array_equal(
        np.asarray(sa.step), np.asarray(sb.step)
    )


def test_straggler_monitor_flags_outliers():
    from repro.runtime.stragglers import StragglerMonitor
    import time as _t

    mon = StragglerMonitor(threshold=5.0, ema_decay=0.5)
    for s in range(5):
        mon.start_step()
        _t.sleep(0.01)
        mon.end_step(s)
    mon.start_step()
    _t.sleep(0.2)
    ev = mon.end_step(5)
    assert ev is not None and ev.step == 5
    assert len(mon.events) == 1


# ---------------------------------------------------------------- serving
def test_epoch_server_matches_single_request_decode():
    cfg = dataclasses.replace(
        configs.get_reduced("granite_3_8b"), compute_dtype=jnp.float32
    )
    params, _ = init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    prompts = [
        rng.randint(3, cfg.vocab, size=n).astype(np.int32)
        for n in (5, 9, 3, 12)
    ]
    srv = EpochServer(cfg, params, n_slots=3, max_len=64)
    for p in prompts:
        srv.submit(Request(prompt=p, max_new_tokens=5))
    done = srv.run_to_completion()
    assert len(done) == len(prompts)

    from repro.models.model import decode_step, prefill

    for r in done:
        lg, cache = prefill(
            params, cfg, jnp.asarray(prompts[r.rid][None]), max_len=64
        )
        want = [int(jnp.argmax(lg, -1)[0])]
        for _ in range(4):
            lg, cache = decode_step(
                params, cfg, jnp.asarray([[want[-1]]], jnp.int32), cache
            )
            want.append(int(jnp.argmax(lg, -1)[0]))
        assert r.output == want, r.rid


def test_epoch_server_slot_reuse_and_bulk_epochs():
    cfg = configs.get_reduced("mamba2_1_3b")
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    srv = EpochServer(cfg, params, n_slots=2, max_len=64)
    rng = np.random.RandomState(1)
    for _ in range(6):
        srv.submit(
            Request(
                prompt=rng.randint(3, cfg.vocab, 6).astype(np.int32),
                max_new_tokens=4,
            )
        )
    done = srv.run_to_completion()
    assert len(done) == 6
    # work-together: 6 requests x 4 tokens in far fewer than 24 epochs
    assert srv.epochs <= 14


# -------------------------------------------------------------- optimizer
def test_adamw_reduces_loss_and_schedules():
    sched = cosine_schedule(1e-2, warmup_steps=5, total_steps=50)
    assert float(sched(0)) == 0.0
    assert float(sched(5)) == pytest.approx(1e-2, rel=1e-5)
    assert float(sched(50)) == pytest.approx(1e-3, rel=1e-3)

    cfg, params, opt_state, step_fn, data, _ = build(
        "granite_3_8b", reduced=True, batch=4, seq=64, steps=40, lr=3e-3
    )
    runner = TrainRunner(
        step_fn, data, CheckpointManager("/tmp/_t_adamw", keep=1),
        ckpt_every=2,
    )
    _, _, hist = runner.run(params, opt_state, 40)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first - 0.1, (first, last)


def test_zero1_pspec_shards_replicated_dim():
    from jax.sharding import PartitionSpec as P
    from repro.optim import zero1_pspec

    s = zero1_pspec(P(None, "model"), (64, 32), ("data",), 16)
    assert s == P("data", "model")
    # nothing divisible -> unchanged
    s2 = zero1_pspec(P("model",), (50,), ("data",), 16)
    assert s2 == P("model")


@pytest.mark.parametrize("arch", ["whisper_large_v3", "hymba_1_5b"])
def test_epoch_server_other_families(arch):
    """Serving engine over enc-dec (cached cross-KV) and hybrid archs."""
    cfg = configs.get_reduced(arch)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    enc = None
    if cfg.encdec:
        enc = jax.random.normal(
            jax.random.PRNGKey(1), (1, cfg.encoder_len, cfg.d_model)
        )
    srv = EpochServer(cfg, params, n_slots=2, max_len=48, enc_frames=enc)
    rng = np.random.RandomState(0)
    for _ in range(4):
        srv.submit(
            Request(
                prompt=rng.randint(3, cfg.vocab, 5).astype(np.int32),
                max_new_tokens=3,
            )
        )
    done = srv.run_to_completion()
    assert len(done) == 4
    for r in done:
        assert len(r.output) == 3
        assert all(0 <= t < cfg.vocab_padded for t in r.output)
