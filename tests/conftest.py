"""Tier-1 test-suite bootstrap.

Two environment guards so `PYTHONPATH=src python -m pytest -x -q` collects
and runs everywhere (dev laptops, CI, hermetic containers):

1. **hypothesis fallback** — the property tests import ``hypothesis`` (a
   dev dependency, see ``requirements-dev.txt``).  Where it cannot be
   installed, a minimal deterministic stub (``tests/_hypothesis_stub.py``)
   is injected into ``sys.modules`` so the modules still collect and the
   property tests run as seeded-random smoke tests.

2. **multi-device gating** — the distributed tests need >= 4 devices
   (they subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count``,
   the SNIPPETS.md idiom) plus a jax new enough for
   ``jax.sharding.AxisType``.  ``multidevice_skip`` centralizes the check;
   the affected modules apply it as a ``skipif`` marker instead of failing.
"""
from __future__ import annotations

import importlib.util
import pathlib
import sys

_HERE = pathlib.Path(__file__).resolve().parent

# ---------------------------------------------------------------- guard 1
if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, str(_HERE))
    import _hypothesis_stub as _stub

    sys.modules["hypothesis"] = _stub  # type: ignore[assignment]
    sys.modules["hypothesis.strategies"] = _stub.strategies


# ---------------------------------------------------------------- guard 2
def multidevice_skip(required: int = 4):
    """(skip?, reason) for tests that need ``required`` devices.

    The subprocess-based tests can force host devices via XLA_FLAGS, but
    only on a jax recent enough to expose ``jax.sharding.AxisType`` (their
    mesh construction uses it); on older jax or genuinely single-device
    environments they must skip rather than fail.
    """
    import jax

    if not hasattr(jax.sharding, "AxisType"):
        return True, "jax.sharding.AxisType unavailable (jax too old)"
    if jax.device_count() < required and jax.default_backend() != "cpu":
        return True, f"needs >= {required} devices (have {jax.device_count()})"
    return False, ""
