"""Gather (dense-frontier) dispatch tests.

The third dispatch mode: a masked fused epoch's *scheduled* lanes are
packed into one contiguous frontier (``kernels.ops.lane_pack``), phase 2
runs over that dense frontier only, and the effects commit through the
shared :func:`~repro.core.tvm.commit_epoch` in packed lane order — which
equals masked lane order restricted to the lanes that matter, so results
are bit-identical while the cross-region hole lanes of a fused fleet are
never launched.  Load-bearing properties:

  * ``lane_pack`` (ref and the type_rank-kernel composition) produces the
    stable pack permutation;
  * solo and fused runs are bit-identical to ``masked`` and ``compacted``;
  * lane utilization is >= masked whenever the fused span has holes
    (fleets with >= 2 active regions), and the skipped holes are accounted
    in ``RunStats.hole_lanes_skipped``;
  * the resident (device) drivers run gather as a fixed-shape segmented
    in-loop pack (DESIGN.md §12) — bit-identical to resident masked, with
    utilization >= masked and strictly fewer launched lanes on >= 2-region
    fleets; only compacted stays refused (it sizes launches from runtime
    populations).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import get_case, get_fleet
from repro.core import DeviceEngine, HostEngine
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.service import (
    DeviceMultiplexer,
    EpochMultiplexer,
    Job,
    JobHandle,
    JobService,
    JobStatus,
)


def _handles(fleet):
    return [
        JobHandle(i, Job(c.program, c.initial, heap_init=dict(c.heap_init),
                         quota=q, name=c.name))
        for i, (c, q) in enumerate(fleet)
    ]


# ------------------------------------------------------------- lane_pack
def test_lane_pack_ref_semantics():
    act = jnp.asarray([False, True, True, False, True, False])
    perm, count = kref.lane_pack_ref(act)
    assert int(count) == 3
    np.testing.assert_array_equal(np.asarray(perm), [1, 2, 4, -1, -1, -1])


def test_lane_pack_empty_and_full():
    perm, count = kref.lane_pack_ref(jnp.zeros((4,), bool))
    assert int(count) == 0
    np.testing.assert_array_equal(np.asarray(perm), [-1] * 4)
    perm, count = kref.lane_pack_ref(jnp.ones((4,), bool))
    assert int(count) == 4
    np.testing.assert_array_equal(np.asarray(perm), [0, 1, 2, 3])


def test_lane_pack_kernel_matches_ref():
    rng = np.random.RandomState(0)
    act = jnp.asarray(rng.rand(257) < 0.3)
    perm_r, count_r = kref.lane_pack_ref(act)
    perm_k, count_k = kops.lane_pack(act, impl="interpret")
    assert int(count_r) == int(count_k)
    np.testing.assert_array_equal(np.asarray(perm_r), np.asarray(perm_k))


# ------------------------------------------------------------- solo engine
@pytest.mark.parametrize("name", ["fib", "nqueens", "mergesort"])
def test_solo_gather_bit_identical(name):
    """Gather on a solo HostEngine matches masked exactly (holes inside a
    coalesced span: lanes whose epoch number moved on)."""
    case = get_case(name)
    hm, vm, sm = case.run(dispatch="masked")
    hg, vg, sg = case.run(dispatch="gather")
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(vg))
    assert set(hm) == set(hg)
    for k in hm:
        np.testing.assert_array_equal(np.asarray(hm[k]), np.asarray(hg[k]),
                                      err_msg=k)
    assert sg.epochs == sm.epochs
    assert sg.tasks_executed == sm.tasks_executed
    assert sg.total_forks == sm.total_forks
    # dense frontier: never launches more lanes than masked, and the
    # skipped lanes are exactly the accounting delta
    assert sg.lanes_launched <= sm.lanes_launched
    assert sg.utilization >= sm.utilization
    assert sg.lanes_launched + sg.hole_lanes_skipped == sm.lanes_launched
    # the pack pass costs one extra dispatch + one count transfer per epoch
    # (map payload launches ride on top, exactly as under masked)
    assert sg.dispatches == 2 * sg.epochs + sg.map_launches
    assert sg.scalar_transfers == 2 * sg.epochs
    assert sm.hole_lanes_skipped == 0


def test_solo_gather_matches_compacted():
    case = get_case("fib")
    _, vc, _ = case.run(dispatch="compacted")
    _, vg, _ = case.run(dispatch="gather")
    np.testing.assert_array_equal(np.asarray(vc), np.asarray(vg))


def test_gather_pack_kernel_plug_point():
    """The pack_fn hook accepts the Pallas composition (interpret mode on
    CPU) and yields the identical schedule."""
    case = get_case("fib")

    def pack_interpret(active):
        return kops.lane_pack(active, impl="interpret")

    _, v_ref, s_ref = case.run(dispatch="gather")
    _, v_pal, s_pal = HostEngine(
        case.program, capacity=case.capacity, dispatch="gather",
        pack_fn=pack_interpret,
    ).run(case.initial, heap_init=dict(case.heap_init) or None)
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_pal))
    assert s_ref.lanes_launched == s_pal.lanes_launched


# ------------------------------------------------------------ fused fleets
@pytest.mark.parametrize("fleet_name", ["mixed3", "mixed4", "fib_fleet"])
def test_fused_gather_bit_identical_to_solo(fleet_name):
    """Acceptance: every registry fleet through the host multiplexer with
    dispatch='gather' is bit-identical per job to the solo runs, with lane
    utilization >= masked (the fused span's cross-region holes are never
    launched) and the skipped holes accounted."""
    fleet = get_fleet(fleet_name)
    solo = {}
    for case, quota in fleet:
        eng = HostEngine(case.program, capacity=quota)
        solo[case.name] = eng.run(
            case.initial, heap_init=dict(case.heap_init) or None
        )

    stats = {}
    for dispatch in ("masked", "gather"):
        handles = _handles(fleet)
        mux = EpochMultiplexer(handles, dispatch=dispatch)
        mux.run()
        for h in handles:
            sh, sv, ss = solo[h.job.name]
            assert h.status is JobStatus.DONE
            np.testing.assert_array_equal(
                np.asarray(h.result.value), np.asarray(sv),
                err_msg=f"{h.job.name}:value:{dispatch}",
            )
            for k in sh:
                np.testing.assert_array_equal(
                    np.asarray(h.result.heap[k]), np.asarray(sh[k]),
                    err_msg=f"{h.job.name}:{k}:{dispatch}",
                )
            assert h.result.stats.epochs == ss.epochs
            assert h.result.stats.tasks_executed == ss.tasks_executed
        stats[dispatch] = mux.stats()

    sm, sg = stats["masked"], stats["gather"]
    assert sg.tasks_executed == sm.tasks_executed
    assert sg.utilization >= sm.utilization
    assert sg.lanes_launched + sg.hole_lanes_skipped == sm.lanes_launched
    if len(fleet) >= 2:
        # cross-region holes exist whenever >= 2 regions fuse: the dense
        # frontier must skip some of them
        assert sg.hole_lanes_skipped > 0
        assert sg.utilization > sm.utilization


def test_gather_matches_compacted_on_fused_fleet():
    fleet = get_fleet("mixed3")
    results = {}
    for dispatch in ("compacted", "gather"):
        handles = _handles(fleet)
        EpochMultiplexer(handles, dispatch=dispatch).run()
        results[dispatch] = {
            h.job.name: np.asarray(h.result.value) for h in handles
        }
    for name in results["gather"]:
        np.testing.assert_array_equal(
            results["gather"][name], results["compacted"][name], err_msg=name
        )


def test_service_gather_dispatch_end_to_end():
    """JobService(dispatch='gather') drives waves + streaming admission on
    the gather path (queue deeper than max_jobs)."""
    from repro.apps import fib

    svc = JobService(capacity=512, max_jobs=2, dispatch="gather")
    ns = (8, 10, 9)
    handles = [
        svc.submit(fib.PROGRAM, fib.initial(n), quota=256) for n in ns
    ]
    svc.drain()
    for h, n in zip(handles, ns):
        assert h.status is JobStatus.DONE
        assert int(np.asarray(h.result.value)[0, 0]) == fib.fib_reference(n)
    assert svc.stats().hole_lanes_skipped > 0


# ------------------------------------------------------- resident gather
def test_resident_drivers_still_reject_compacted():
    """Gather is now traceable on the resident drivers; compacted is not
    (it sizes per-type launches from runtime populations)."""
    case = get_case("fib")
    with pytest.raises(ValueError, match="masked"):
        DeviceEngine(case.program, dispatch="compacted")
    with pytest.raises(ValueError, match="masked"):
        DeviceMultiplexer(_handles(get_fleet("fib_fleet")),
                          dispatch="compacted")
    with pytest.raises(ValueError, match="masked"):
        JobService(engine="device", dispatch="compacted")


@pytest.mark.parametrize("name", ["fib", "mergesort"])
def test_solo_resident_gather_bit_identical(name):
    """DeviceEngine(dispatch='gather') matches the masked resident run
    exactly — values, heap, and ChunkSummary-derived stats — and the
    rung + hole accounting still tiles the full TV every epoch."""
    case = get_case(name)
    em = DeviceEngine(case.program, capacity=case.capacity)
    hm, vm, sm = em.run(case.initial, heap_init=dict(case.heap_init) or None)
    eg = DeviceEngine(case.program, capacity=case.capacity,
                      dispatch="gather")
    hg, vg, sg = eg.run(case.initial, heap_init=dict(case.heap_init) or None)
    np.testing.assert_array_equal(np.asarray(vg), np.asarray(vm))
    for k in hm:
        np.testing.assert_array_equal(np.asarray(hg[k]), np.asarray(hm[k]),
                                      err_msg=k)
    assert sg.epochs == sm.epochs
    assert sg.tasks_executed == sm.tasks_executed
    assert sg.total_forks == sm.total_forks
    # the dense rung never exceeds the span rung, and both tile the TV
    assert sg.lanes_launched <= sm.lanes_launched
    assert sg.utilization >= sm.utilization
    assert (sg.lanes_launched + sg.hole_lanes_skipped
            == sm.lanes_launched + sm.hole_lanes_skipped
            == case.capacity * sm.epochs)
    # map payloads launch over the same scattered full-TV domain
    assert sg.map_elements == sm.map_elements
    assert sg.map_lanes_launched == sm.map_lanes_launched
    assert sg.map_utilization >= sm.map_utilization


@pytest.mark.parametrize("fleet_name", ["mixed3", "mixed4", "fib_fleet"])
def test_resident_fleet_gather_bit_identical(fleet_name):
    """DeviceMultiplexer(dispatch='gather') on every registry fleet is
    bit-identical per job to solo runs, with strictly fewer launched lanes
    than resident masked (the fused span's cross-region holes are packed
    away) and the skipped holes accounted."""
    fleet = get_fleet(fleet_name)
    solo = {}
    for case, quota in fleet:
        eng = HostEngine(case.program, capacity=quota)
        solo[case.name] = eng.run(
            case.initial, heap_init=dict(case.heap_init) or None
        )

    stats = {}
    for dispatch in ("masked", "gather"):
        handles = _handles(fleet)
        mux = DeviceMultiplexer(handles, dispatch=dispatch)
        mux.run()
        for h in handles:
            sh, sv, ss = solo[h.job.name]
            assert h.status is JobStatus.DONE
            np.testing.assert_array_equal(
                np.asarray(h.result.value), np.asarray(sv),
                err_msg=f"{h.job.name}:value:{dispatch}",
            )
            for k in sh:
                np.testing.assert_array_equal(
                    np.asarray(h.result.heap[k]), np.asarray(sh[k]),
                    err_msg=f"{h.job.name}:{k}:{dispatch}",
                )
            assert h.result.stats.epochs == ss.epochs
            assert h.result.stats.tasks_executed == ss.tasks_executed
        stats[dispatch] = mux.stats()

    sm, sg = stats["masked"], stats["gather"]
    capacity = sum(q for _, q in fleet)
    assert sg.epochs == sm.epochs
    assert sg.tasks_executed == sm.tasks_executed
    assert sg.utilization >= sm.utilization
    assert (sg.lanes_launched + sg.hole_lanes_skipped
            == sm.lanes_launched + sm.hole_lanes_skipped
            == capacity * sm.epochs)
    assert sg.map_utilization >= sm.map_utilization
    if len(fleet) >= 2:
        # >= 2 regions fuse: the union span holds cross-region holes the
        # dense pack must skip, so gather strictly wins on lane volume
        assert sg.lanes_launched < sm.lanes_launched
        assert sg.hole_lanes_skipped > sm.hole_lanes_skipped
        assert sg.utilization > sm.utilization


@pytest.mark.parametrize("chunk", [1, 4, None])
def test_service_device_gather_chunked(chunk):
    """JobService(engine='device', dispatch='gather') across the K-ladder:
    values match the masked device service bit-for-bit and the gather rows
    never launch more lanes."""
    from repro.apps import fib

    ns = (8, 10, 9)

    def run(dispatch):
        svc = JobService(capacity=1024, max_jobs=4, engine="device",
                         dispatch=dispatch, chunk=chunk)
        handles = [
            svc.submit(fib.PROGRAM, fib.initial(n), quota=256) for n in ns
        ]
        svc.drain()
        return handles, svc.stats()

    hm, sm = run("masked")
    hg, sg = run("gather")
    for h, g, n in zip(hm, hg, ns):
        assert h.status is JobStatus.DONE and g.status is JobStatus.DONE
        assert int(np.asarray(g.result.value)[0, 0]) == fib.fib_reference(n)
        np.testing.assert_array_equal(
            np.asarray(g.result.value), np.asarray(h.result.value)
        )
    assert sg.epochs == sm.epochs
    assert sg.lanes_launched < sm.lanes_launched
    assert sg.hole_lanes_skipped > sm.hole_lanes_skipped
