"""Dry-run machinery tests: HLO collective parsing, loop calibration math,
and a small-mesh end-to-end lower+compile in a subprocess (the production
512-device sweep runs via `python -m repro.launch.dryrun --all`)."""
import pathlib
import subprocess
import sys

import pytest

from conftest import multidevice_skip

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def test_collective_stats_parser():
    sys.path.insert(0, SRC)
    from repro.launch.dryrun import collective_stats

    hlo = """
  %all-reduce.5 = f32[2048]{0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %ag = bf16[16,512]{1,0} all-gather(%y), replica_groups=[4,2]<=[8], dimensions={1}
  %rs = f32[128]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[64]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %done = f32[2048]{0} all-reduce-done(%all-reduce.5)
"""
    s = collective_stats(hlo)
    ops = s["ops"]
    assert ops["all-reduce"]["count"] == 1  # -done not double counted
    assert ops["all-reduce"]["result_bytes"] == 2048 * 4
    # ring wire bytes: 2*S*(g-1)/g with g=4
    assert abs(ops["all-reduce"]["wire_bytes"] - 2 * 8192 * 3 / 4) < 1
    assert ops["all-gather"]["result_bytes"] == 16 * 512 * 2
    assert ops["reduce-scatter"]["wire_bytes"] == 128 * 4 * 3  # S*(g-1), g=4
    assert ops["collective-permute"]["wire_bytes"] == 64 * 4
    assert s["total_bytes_per_chip"] > 0


def test_loop_calibration_math():
    """corrected = base + sum (eff_trips-1) * per_trip with nesting."""
    sys.path.insert(0, SRC)
    from repro.launch.dryrun import calibrated_stats

    # synthetic program: outer loop L=4 trips, inner loop (child) 3 trips
    # true flops = O + 4*layer_base + 4*3*inner_body
    O, layer_base, inner = 100.0, 10.0, 2.0
    loops = [("layer", 4, None), ("ssd", 3, "layer")]

    def make_fn(unroll):
        class FakeLowered:
            def compile(self):
                return self

            def lower(self, *a):
                return self

            def memory_analysis(self):
                class M:
                    argument_size_in_bytes = 0
                    output_size_in_bytes = 0
                    temp_size_in_bytes = 0
                    alias_size_in_bytes = 0
                return M()

            def cost_analysis(self):
                lu = unroll.get("layer", 1)
                su = unroll.get("ssd", 1)
                # each unrolled copy of the layer body contains su ssd bodies
                f = O + lu * (layer_base + su * inner)
                return {"flops": f, "bytes accessed": f}

            def as_text(self):
                return ""

        return FakeLowered()

    base, corrected, per_trip, trips = calibrated_stats(make_fn, (), loops)
    want = O + 4 * layer_base + 12 * inner
    assert abs(corrected["flops"] - want) < 1e-6, (corrected["flops"], want)
    assert trips["ssd"]["eff"] == 12


_SKIP, _REASON = multidevice_skip(required=8)


@pytest.mark.skipif(_SKIP, reason=_REASON)
def test_small_mesh_cell_lowers():
    """End-to-end: a reduced config lowers+compiles on a 2x4 mesh with the
    same code paths as the production dry-run (subprocess, 8 devices)."""
    script = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.launch import mesh as meshlib
from repro.models.common import finalize, sharding_ctx
from repro.models.model import loss_fn
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = finalize(configs.get_reduced("granite_moe_1b_a400m"), 4)
rules = meshlib.rules_for_mesh(mesh)
pspecs, _ = meshlib.param_shardings(cfg, mesh, rules)
B, S = 8, 64
bsh = NamedSharding(mesh, P("data", None))
batch = {
  "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bsh),
  "labels": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bsh),
}
def step(params, batch):
    with sharding_ctx(mesh, rules):
        return loss_fn(params, cfg, batch)[0]
compiled = jax.jit(step).lower(pspecs, batch).compile()
assert compiled.cost_analysis()["flops"] > 0
print("LOWER_OK", compiled.cost_analysis()["flops"])
"""
    env = {
        "PYTHONPATH": SRC,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PATH": "/usr/bin:/bin",
        "HOME": "/tmp",
    }
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=480, env=env,
    )
    assert r.returncode == 0, r.stderr
    assert "LOWER_OK" in r.stdout
